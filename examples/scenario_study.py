"""Scenario study: drive the router tree + elastic scaling under every
named workload shape (repro.workloads) and compare how the same platform
architecture fares per shape — the RQ-A/RQ-B experiment loop in miniature.

Run:  PYTHONPATH=src python examples/scenario_study.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.config_store import ConfigStore
from repro.core.router import build_leaf, build_tree
from repro.core.simulator import Simulator, SyntheticServiceModel, summarize
from repro.workloads import build_scenario, install_demo_configs


def run_shape(name: str, **overrides):
    wl = build_scenario(name, duration_s=20.0, seed=3, **overrides)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(16, fanout=4, leaf_policy="warm_affinity"),
                    store, SyntheticServiceModel(seed=2), seed=7)
    n = sim.load(wl)
    s = summarize(sim.run())
    print(f"{name:>14s}: n={n:6d} p50={s['p50']*1e3:7.1f}ms "
          f"p99={s['p99']*1e3:7.1f}ms cold={s['cold_rate']:.3f} "
          f"fail={s['fail_rate']:.3f}")
    return s


def elastic_under_flash_crowd():
    """The paper's replicate-recipe applied live, mid-flash-crowd: scale
    out when the burst hits and watch the tail come back down."""
    wl = build_scenario("flash_crowd", base_rps=100.0, burst_rps=2500.0,
                        duration_s=20.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(8, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=16)
    sim.load(wl)
    sim.run(until=8.0)
    mid = summarize(sim.results)
    # scale out live: added workers inherit the configured capacity
    sim.add_branch(build_leaf("leaf-burst", [f"wb{i}" for i in range(8)]))
    sim.run()
    end = summarize(sim.results)
    print(f"\nelastic flash_crowd:  8 workers t<8s  p99={mid['p99']*1e3:.1f}ms"
          f" fail={mid['fail_rate']:.3f}")
    print(f"elastic flash_crowd: 16 workers total p99={end['p99']*1e3:.1f}ms"
          f" fail={end['fail_rate']:.3f}  (branch added live at t=8s)")


def main():
    print("=== same 16-worker warm-affinity tree, four traffic shapes ===")
    run_shape("steady")
    run_shape("flash_crowd")
    run_shape("daily_cycle")
    run_shape("multi_tenant")
    elastic_under_flash_crowd()


if __name__ == "__main__":
    main()

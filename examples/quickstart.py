"""Quickstart: a miniature HyperFaaS-JAX cluster in one process.

Registers two real model "functions", stands up an LB tree over two workers,
sends a burst of batched requests, and prints per-request latencies — the
whole paper Fig. 1 pipeline end to end on live JAX models.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import build_tree
from repro.core.simulator import summarize
from repro.core.types import FunctionConfig, Request
from repro.serving.engine import Engine


def main():
    store = ConfigStore()
    store.put(FunctionConfig(name="tiny-gen", arch="tiny_lm",
                             concurrency=4, gen_tokens=6))
    store.put(FunctionConfig(name="small-gen", arch="small_lm",
                             concurrency=2, gen_tokens=4))

    tree = build_tree(2, fanout=2, leaf_policy="warm_affinity")
    engine = Engine(tree, store, ImageRegistry(), max_len=64)

    print("submitting 8 requests across 2 functions ...")
    reqs = [Request(fn="tiny-gen" if i % 3 else "small-gen",
                    arrival_t=0.0, size=8 + 4 * (i % 2)) for i in range(8)]
    for r in reqs:
        engine.submit(r)
    results = engine.run()

    for r in sorted(results, key=lambda r: r.rid):
        print(f"  req {r.rid:3d} fn={r.fn:10s} worker={r.worker} "
              f"cold={str(r.cold_start):5s} latency={r.latency*1e3:8.1f} ms")
    s = summarize(results)
    print(f"\nok={s['ok']}/{s['n']}  p50={s['p50']*1e3:.1f}ms  "
          f"p99={s['p99']*1e3:.1f}ms  cold_rate={s['cold_rate']:.2f}")
    inst = engine.workers[results[0].worker].instances[results[0].fn][0]
    print(f"sample generated tokens (greedy): "
          f"{inst.generated[results[0].rid][:6]}")


if __name__ == "__main__":
    main()

"""Train a ~110M-param LM for a few hundred steps with the full substrate:
sharded params (local mesh), grad accumulation, checkpoint/restart, data
pipeline. Demonstrates the training side of the platform (function images
are *trained* somewhere before they are served).

Run:  PYTHONPATH=src python examples/train_small.py [--steps N] [--resume]
(defaults small enough for CPU; pass --steps 300 for the full run)
"""
import sys
sys.path.insert(0, "src")
import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.distributed.checkpoint import CheckpointManager
from repro.models import build_model
from repro.train.optimizer import AdamW
from repro.train.schedule import warmup_cosine
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-size", action="store_true",
                    help="use the real 110M config (slow on CPU)")
    ap.add_argument("--ckpt", default="artifacts/train_small")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config("train_100m")
    if not args.full_size:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=2048)
    model = build_model(cfg, attn_block=64)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params as built)")

    opt = AdamW(lr=warmup_cosine(3e-3, 20, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, accum=2,
                                      grad_acc_dtype="float32"))

    mgr = CheckpointManager(args.ckpt, keep=2)
    start, restored = mgr.restore_latest({"p": params, "o": opt_state})
    if restored is not None:
        params, opt_state = restored["p"], restored["o"]
        print(f"resumed from checkpoint step {start}")
    start = start or 0

    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    pf = Prefetcher(stream, start_step=start)
    t0 = time.time()
    try:
        for i in range(start, start + args.steps):
            params, opt_state, m = step_fn(params, opt_state, pf.next())
            if i % 10 == 0 or i == start + args.steps - 1:
                toks = args.batch * args.seq * (i - start + 1)
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({toks/(time.time()-t0):.0f} tok/s)")
            if i and i % 50 == 0:
                mgr.save(i, {"p": params, "o": opt_state})
    finally:
        pf.stop()
    mgr.save(start + args.steps, {"p": params, "o": opt_state})
    mgr.wait()
    print(f"done; checkpoints at {args.ckpt}: steps {mgr.all_steps()}")


if __name__ == "__main__":
    main()

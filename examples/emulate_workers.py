"""RQ-B (paper §III.B, Fig. 2): the full worker-emulation pipeline.

Step 1 — run a REAL worker (live JAX models, repro.serving.engine) under
         artificial load; save invocation metrics.
Step 2 — build a model of the worker: ridge regression AND a small MLP
         (trained with the framework's own AdamW).
Step 3 — run MANY emulated workers from the model.
Step 4 — evaluate: replay the same load, compare latency distributions.

Run:  PYTHONPATH=src python examples/emulate_workers.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.emulation import (EmulatedServiceModel, MLPWorkerModel,
                                  RidgeWorkerModel, fidelity_report,
                                  telemetry_matrix)
from repro.core.router import build_tree
from repro.core.simulator import Simulator, poisson_load, summarize
from repro.core.types import FunctionConfig, Request
from repro.serving.engine import Worker


def main():
    store = ConfigStore()
    for fn, arch, c in (("tiny-gen", "tiny_lm", 4), ("small-gen", "small_lm", 2)):
        store.put(FunctionConfig(name=fn, arch=arch, concurrency=c,
                                 gen_tokens=4, idle_timeout_s=60.0))

    # ---- step 1: real worker under artificial load -----------------------
    print("step 1: profiling a REAL worker (live JAX execution) ...")
    w = Worker("w-real", store, ImageRegistry(), max_len=64)
    rng = np.random.default_rng(0)
    for i in range(24):
        fn = "tiny-gen" if rng.random() < 0.8 else "small-gen"
        w.submit(Request(fn=fn, arrival_t=0.0, size=int(rng.integers(4, 24))))
        if rng.random() < 0.4:
            w.drain()
    w.drain()
    recs = [t for t in w.telemetry if t.latency > 0]
    print(f"  collected {len(recs)} telemetry rows "
          f"(features: {recs[0].FEATURE_NAMES})")

    # ---- step 2: fit worker models ---------------------------------------
    X, y, ok = telemetry_matrix(recs)
    ridge = RidgeWorkerModel.fit(X, y, ok)
    mlp = MLPWorkerModel.fit(X, y, ok, steps=300)
    print(f"step 2: ridge resid_std={ridge.resid_std:.3f}  "
          f"mlp resid_std={mlp.resid_std:.3f}")

    # ---- step 4: fidelity -------------------------------------------------
    # "the same kind of answer within the same timeframes": per-row held-out
    # prediction error of the worker model (the honest fidelity measure on a
    # time-shared single-core container, where absolute latencies are
    # compile-contention-dominated; the controlled ground-truth loop lives in
    # tests/test_emulation.py::test_emulated_sim_fidelity with p50_err < 25%)
    rng2 = np.random.default_rng(7)
    for name, model in (("ridge", ridge), ("mlp", mlp)):
        errs = []
        for i in range(0, len(recs), 3):          # held-out-ish rows
            pred, _ = model.predict(X[i], rng2)
            errs.append(abs(pred - y[i]) / max(y[i], 1e-9))
        print(f"step 4 [{name:5s}]: per-row median rel err "
              f"{np.median(errs):.2%}  (p90 {np.percentile(errs, 90):.2%})")

    # scale-out: 1024 emulated workers from one real profile
    big = Simulator(build_tree(1024, fanout=16), store,
                    EmulatedServiceModel(ridge, seed=2), seed=4)
    n = poisson_load(big, fn="tiny-gen", rps=5000, duration_s=4, seed=6)
    s = summarize(big.run())
    print(f"step 3 at scale: {n} requests over 1024 EMULATED workers -> "
          f"p50={s['p50']*1e3:.1f}ms p99={s['p99']*1e3:.1f}ms "
          f"fail={s['fail_rate']:.3f} (one real server's profile, "
          f"1024x the fleet)")


if __name__ == "__main__":
    main()

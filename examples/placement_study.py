"""Placement study: memory-aware bin packing x deadline-aware routing.

On the memory-skewed `multi_tenant` scenario (chat 256 MB, embed 512 MB,
batch 1536 MB replicas on 1792 MB workers) a batch replica monopolises a
worker's memory, so *where* replicas start decides whether the other
tenants can start at all. The study runs the placer x routing matrix
under slo_aware autoscaling and reports per-tenant p95 vs SLO plus
worker-seconds — showing how `best_fit_memory` + `deadline_aware`
(branch-level ETA scoring, memory-blocked cold starts penalised) meets
every SLO at lower cost than the paper-recipe `first_fit` +
`least_loaded` baseline, which strands embed/batch traffic behind
memory-full workers. It ends with a placement decision-log excerpt —
byte-identical across same-seed runs (`tests/test_placement.py` pins
the digests).

Run:  PYTHONPATH=src python examples/placement_study.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.autoscale import Autoscaler, build_pool, get_autoscaler
from repro.core.config_store import ConfigStore
from repro.core.placement import list_placers
from repro.core.simulator import Simulator, SyntheticServiceModel, summarize
from repro.workloads import build_scenario, install_demo_configs

# the ISSUE-4 acceptance surface; `benchmarks/run.py` (bench_placement)
# imports CELLS/run_cell so the CI bench and this study can never drift
CELLS = [
    ("first_fit", "least_loaded", "random"),        # PR 3-style baseline
    ("first_fit", "deadline_aware", "deadline_aware"),
    ("best_fit_memory", "least_loaded", "random"),
    ("best_fit_memory", "deadline_aware", "deadline_aware"),
    ("spread", "deadline_aware", "deadline_aware"),
]


def run_cell(placer: str, leaf: str, inner: str, *, record=False):
    """One matrix cell: memory-skewed multi_tenant under slo_aware
    autoscaling. Returns (sim, scaler, results, per_fn {fn: (p95, slo)})."""
    wl = build_scenario("multi_tenant", rps=60.0, duration_s=20.0, seed=3,
                        memory_skew=True)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(1, 2, leaf_policy=leaf, inner_policy=inner),
                    store, SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=8, worker_memory_mb=1792,
                    placer=placer, record_decisions=record)
    pol = get_autoscaler("slo_aware", slo_p95_s=wl.slo_targets())
    scaler = Autoscaler(pol, interval_s=0.25, window_s=2.0, min_replicas=1,
                        max_replicas=8, workers_per_replica=2, cooldown_s=2.0,
                        leaf_policy=leaf)
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    results = sim.run()
    per_fn = {}
    for fn, slo in sorted(wl.slo_targets().items()):
        lat = np.array([r.latency for r in results if r.ok and r.fn == fn])
        p95 = float(np.percentile(lat, 95)) if len(lat) else float("nan")
        per_fn[fn] = (p95, slo)
    return sim, scaler, results, per_fn


def main():
    print(f"registered placers: {', '.join(list_placers())}")
    print("memory-skewed multi_tenant, 1792 MB workers, slo_aware "
          "autoscaling (max 8x2 workers)\n")
    excerpt = None
    for placer, leaf, inner in CELLS:
        record = (placer, leaf) == ("best_fit_memory", "deadline_aware")
        sim, scaler, results, per_fn = run_cell(placer, leaf, inner,
                                                record=record)
        if record:
            excerpt = sim
        s = summarize(results)
        met = all(p95 < slo for p95, slo in per_fn.values())
        parts = [f"{fn}={p95:6.2f}s/{slo:.1f}s"
                 for fn, (p95, slo) in per_fn.items()]
        print(f"  {placer:>15s} + {leaf:<15s}: "
              f"{'SLO MET ' if met else 'SLO MISS'} "
              f"worker_s={scaler.worker_seconds:5.0f} "
              f"fail={s['fail_rate']:.4f} cold={s['cold_rate']:.3f}  "
              f"p95: {' '.join(parts)}")
    print("\nplacement decision-log excerpt (best_fit_memory + "
          "deadline_aware, byte-identical for the same seed):")
    for line in excerpt.placement_records[:10]:
        print(" ", line)


if __name__ == "__main__":
    main()

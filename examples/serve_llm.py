"""End-to-end serving driver (deliverable b): serve a small LM with batched
requests through the full platform — router tree, worker lifecycle, continuous
batching, measured cold starts — and report throughput/latency.

Run:  PYTHONPATH=src python examples/serve_llm.py [n_requests]
"""
import sys
sys.path.insert(0, "src")
import time

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import build_tree
from repro.core.simulator import summarize
from repro.core.types import FunctionConfig, Request
from repro.serving.engine import Engine


def main(n_requests: int = 24):
    store = ConfigStore()
    store.put(FunctionConfig(name="llm", arch="small_lm", concurrency=4,
                             gen_tokens=8, idle_timeout_s=120.0))
    tree = build_tree(2, fanout=2, leaf_policy="least_loaded")
    engine = Engine(tree, store, ImageRegistry(), max_len=64)

    t0 = time.monotonic()
    for i in range(n_requests):
        engine.submit(Request(fn="llm", arrival_t=0.0, size=8 + 8 * (i % 3)))
    results = engine.run()
    wall = time.monotonic() - t0

    s = summarize(results)
    tel = engine.telemetry()
    tokens = sum(t.gen_tokens for t in tel)
    print(f"served {s['ok']}/{s['n']} requests in {wall:.2f}s "
          f"({tokens / wall:.1f} tok/s, {s['n'] / wall:.2f} req/s)")
    print(f"latency p50={s['p50']*1e3:.0f}ms p95={s['p95']*1e3:.0f}ms "
          f"p99={s['p99']*1e3:.0f}ms  cold_rate={s['cold_rate']:.2f}")
    colds = [w.instances for w in engine.workers.values()]
    n_inst = sum(len(il) for w in engine.workers.values()
                 for il in w.instances.values())
    print(f"instances alive: {n_inst}; per-worker telemetry rows: "
          f"{[len(w.telemetry) for w in engine.workers.values()]}")
    # continuous batching evidence: batch sizes > 1 were used
    bs = [t.batch_size for t in tel]
    print(f"slot occupancy seen: min={min(bs)} max={max(bs)} "
          f"(max>1 proves continuous batching)")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 24)

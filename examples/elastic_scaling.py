"""Elastic scaling demo: the paper's replicate-recipe applied LIVE, plus
worker failures with hedged-request straggler mitigation.

Run:  PYTHONPATH=src python examples/elastic_scaling.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.config_store import ConfigStore
from repro.core.router import build_leaf, build_tree
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  poisson_load, summarize)
from repro.core.types import FunctionConfig


def main():
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))

    # phase 1: 8 workers saturated at 600 rps
    sim = Simulator(build_tree(8, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7, hedge_after_s=0.4)
    poisson_load(sim, fn="fn", rps=600, duration_s=10, seed=3)
    sim.run(until=5.0)
    mid = summarize(sim.results)
    print(f"t<5s   8 workers @600rps: p99={mid['p99']*1e3:7.1f}ms "
          f"fail={mid['fail_rate']:.3f}")

    # phase 2: scale out live — add a replicated branch (paper recipe)
    sim.add_branch(build_leaf("leaf-new0", [f"wn{i}" for i in range(8)]))
    sim.inject_failure("w2", at=6.0, recover_after=2.0)   # and lose a node
    sim.set_straggler("w3", 5.0)                          # and a straggler
    sim.run()
    end = summarize(sim.results)
    print(f"t>5s  16 workers (+failure w2, straggler w3, hedging on): "
          f"p99={end['p99']*1e3:7.1f}ms fail={end['fail_rate']:.3f}")
    print("branch added live; hedging bounds the straggler tail; "
          "failed worker drained and recovered")


if __name__ == "__main__":
    main()

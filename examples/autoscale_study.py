"""Autoscale study: the full policy x scenario matrix, with the paper's
static replicate recipe as the cost/latency baseline.

For each traffic shape the study runs every registered autoscaler policy
(static no-op, reactive thresholds, Knative-style target-concurrency with
panic window, predictive Holt forecast) and reports the elasticity
tradeoff: tail latency vs worker-seconds (the replica-seconds cost
proxy). It ends with a scaling-decision log excerpt — byte-identical
across same-seed runs, which is what `tests/test_autoscale.py` pins.

Run:  PYTHONPATH=src python examples/autoscale_study.py
"""
import sys
sys.path.insert(0, "src")

from repro.autoscale import (Autoscaler, build_pool, get_autoscaler,
                             list_autoscalers)
from repro.core.config_store import ConfigStore
from repro.core.simulator import Simulator, SyntheticServiceModel, summarize
from repro.workloads import build_scenario, install_demo_configs

SHAPES = {
    "flash_crowd": dict(duration_s=30.0, seed=3, base_rps=12.0,
                        burst_rps=1000.0, mean_burst_s=2.0, mean_calm_s=10.0),
    "daily_cycle": dict(duration_s=60.0, seed=3, mean_rps=150.0,
                        period_s=60.0),
    "steady": dict(duration_s=30.0, seed=3, rps=120.0),
}


def run_cell(shape: str, policy: str):
    wl = build_scenario(shape, **SHAPES[shape])
    store = ConfigStore()
    install_demo_configs(store, wl)
    branches = 3 if policy == "static" else 1    # replicate-recipe baseline
    sim = Simulator(build_pool(branches, 2), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=1)
    # slo_aware scales against the scenario's per-function SLO targets
    pol = (get_autoscaler("slo_aware", slo_p95_s=wl.slo_targets())
           if policy == "slo_aware" else policy)
    scaler = Autoscaler(pol, interval_s=0.25, window_s=2.0,
                        min_replicas=1, max_replicas=8,
                        workers_per_replica=2, cooldown_s=2.0)
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    s = summarize(sim.run())
    sm = scaler.summary()
    print(f"  {policy:>20s}: p95={s['p95']*1e3:7.1f}ms "
          f"fail={s['fail_rate']:.4f} cold={s['cold_rate']:.3f} "
          f"worker_s={sm['worker_seconds']:6.0f} "
          f"max_repl={sm['max_replicas_seen']} "
          f"ups={sm['scale_ups']:2d} downs={sm['scale_downs']:2d}")
    return scaler


def main():
    print(f"registered policies: {', '.join(list_autoscalers())}")
    excerpt = None
    for shape in SHAPES:
        print(f"\n=== {shape} ===")
        for policy in list_autoscalers():
            scaler = run_cell(shape, policy)
            if shape == "flash_crowd" and policy == "reactive":
                excerpt = scaler
    print("\nscaling-decision log excerpt (flash_crowd / reactive, "
          "byte-identical for the same seed):")
    lines = excerpt.decision_log().splitlines()
    interesting = [l for l in lines if "action=hold" not in l]
    for line in interesting[:10]:
        print(" ", line)


if __name__ == "__main__":
    main()

"""RQ-A (paper §III.A): within-instance concurrency study.

Same platform, same load, three policies — AWS-Lambda-style c=1,
Knative-style hard limit c=8, Azure-style unlimited-with-replica-scaling —
only the config-store entry changes, which is exactly the fair comparison
the paper says today requires "comparing entirely different platforms".

Run:  PYTHONPATH=src python examples/concurrency_study.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.config_store import ConfigStore
from repro.core.router import build_tree
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  poisson_load, summarize)
from repro.core.types import FunctionConfig

POLICIES = {"lambda (c=1)": 1, "knative (c=8)": 8, "azure (unlimited)": 0}


def run_policy(c: int, rps: float = 400, duration: float = 30.0):
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=c,
                             cold_start_s=0.25, idle_timeout_s=8.0,
                             max_instances_per_worker=16))
    sim = Simulator(build_tree(16, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7)
    poisson_load(sim, fn="fn", rps=rps, duration_s=duration, seed=11)
    res = sim.run()
    s = summarize(res)
    s["instances"] = sum(w.instances_started for w in sim.workers.values())
    s["cold_starts"] = sum(w.cold_starts for w in sim.workers.values())
    util = sum(w.busy_time for w in sim.workers.values()) / (
        len(sim.workers) * max(r.finish_t for r in res))
    s["utilization"] = util
    return s


def main():
    print(f"{'policy':20s} {'p50 ms':>8} {'p99 ms':>8} {'cold%':>7} "
          f"{'fail%':>7} {'instances':>10} {'util':>6}")
    for name, c in POLICIES.items():
        s = run_policy(c)
        print(f"{name:20s} {s['p50']*1e3:8.1f} {s['p99']*1e3:8.1f} "
              f"{100*s['cold_rate']:7.2f} {100*s['fail_rate']:7.2f} "
              f"{s['instances']:10d} {s['utilization']:6.2f}")
    print("\n(cold starts and instance churn drop as within-instance "
          "concurrency rises; latency trades against packing contention)")


if __name__ == "__main__":
    main()

"""LR schedules as pure step->lr callables (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return f


def inverse_sqrt(peak: float, warmup_steps: int):
    def f(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(s / max(warmup_steps, 1),
                                  jnp.sqrt(warmup_steps / s))
    return f

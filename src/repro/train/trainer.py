"""Training step construction: grad-accumulation microbatching, remat-friendly
loss, optional cross-pod gradient compression, and the sharded train loop.

``make_train_step`` is what the dry-run lowers for every ``train_4k`` cell and
what ``launch/train.py`` executes for real on reduced models.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import LM


def _split_microbatches(batch, accum: int):
    def r(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model: LM, optimizer, *, accum: Optional[int] = None,
                    grad_acc_dtype: Optional[str] = None,
                    grad_transform=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    accum: number of gradient-accumulation microbatches (defaults to the
    config's per-arch value). grad_transform: optional fn applied to the mean
    gradients before the optimizer (e.g. cross-pod compressed all-reduce).
    """
    cfg = model.cfg
    accum = accum or cfg.grad_accum
    acc_dt = jnp.dtype(grad_acc_dtype or cfg.opt_state_dtype)

    grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if accum > 1:
            mbs = _split_microbatches(batch, accum)

            def micro(carry, mb):
                gacc, loss_acc = carry
                (loss, _metrics), grads = grad_fn(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(acc_dt), gacc, grads)
                return (gacc, loss_acc + loss), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, loss_sum), _ = jax.lax.scan(micro, (gz, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: (g / accum), gsum)
            loss = loss_sum / accum
        else:
            (loss, _metrics), grads = grad_fn(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return step


def make_eval_step(model: LM):
    def step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return metrics | {"loss": loss}
    return step

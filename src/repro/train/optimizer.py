"""Optimizers built from scratch (no optax in this environment — and the
framework needs sharded/low-precision state control anyway).

* :class:`AdamW` — decoupled weight decay, f32 math, configurable state dtype
  (bf16 state is what lets the 123B/314B/398B train cells fit 16 GB/chip).
* :class:`Adafactor` — factored second moment for matrices (beyond-paper
  memory lever recorded in §Perf).
* :class:`SGDM` — used by the emulation-model fits in ``repro.core``.

All optimizers are pure: ``init(params) -> state``, ``update(grads, state,
params) -> (new_params, new_state)``. State leaves mirror param shapes, so the
param sharding resolver applies verbatim to optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def _cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def init(self, params):
        dt = jnp.dtype(self.state_dtype)
        z = lambda p: jnp.zeros(p.shape, dt)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def state_axes(self, param_axes):
        """Logical-axes tree matching init()'s structure (for sharding)."""
        return {"step": (), "m": param_axes, "v": param_axes}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.state_dtype)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            u = (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * u
            return new_p.astype(p.dtype), _cast(m32, dt), _cast(v32, dt)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}


@dataclass(frozen=True)
class Adafactor:
    """Factored second-moment (Shazeer & Stern). Matrices store row/col stats
    (O(n+m) instead of O(nm)); vectors fall back to full stats."""
    lr: Callable | float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def init(self, params):
        def z(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "stats": jax.tree.map(z, params)}

    def state_axes(self, param_axes):
        def ax(a):
            a = tuple(a)
            if len(a) >= 2:
                return {"r": a[:-1], "c": a[:-2] + a[-1:]}
            return {"v": a}
        return {"step": (),
                "stats": jax.tree.map(ax, param_axes,
                                      is_leaf=lambda x: isinstance(x, tuple))}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** -self.decay

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if g.ndim >= 2:
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), self.eps)
                v = (r[..., None] / denom[..., None]) * c[..., None, :]
                u = g32 / jnp.sqrt(v + self.eps)
                ns = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(v + self.eps)
                ns = {"v": v}
            norm = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, norm / self.clip_threshold)
            new_p = p.astype(jnp.float32) - lr * u
            return new_p.astype(p.dtype), ns

        out = jax.tree.map(upd, grads, state["stats"], params, is_leaf=None)
        # out leaves are (param, stats) tuples
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.unflatten(treedef, [t[0] for t in flat])
        new_stats = jax.tree.unflatten(treedef, [t[1] for t in flat])
        return new_params, {"step": step, "stats": new_stats}


@dataclass(frozen=True)
class SGDM:
    lr: Callable | float = 1e-2
    momentum: float = 0.9

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def init(self, params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def state_axes(self, param_axes):
        return {"step": (), "m": param_axes}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)

        def upd(g, m, p):
            m32 = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m32).astype(p.dtype), m32

        out = jax.tree.map(upd, grads, state["m"], params)
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        return (jax.tree.unflatten(treedef, [t[0] for t in flat]),
                {"step": step,
                 "m": jax.tree.unflatten(treedef, [t[1] for t in flat])})


def make_optimizer(name: str, lr, cfg=None):
    if name == "auto" and cfg is not None:
        name = getattr(cfg, "optimizer", "adamw")
    if name == "adamw":
        sd = cfg.opt_state_dtype if cfg is not None else "float32"
        return AdamW(lr=lr, weight_decay=0.01, state_dtype=sd)
    if name == "adafactor":
        return Adafactor(lr=lr)
    if name == "sgdm":
        return SGDM(lr=lr)
    raise ValueError(name)

"""Mamba-1 (selective SSM) block — falcon-mamba and the jamba hybrid.

Sequence mode uses a *chunked* scan: ``lax.scan`` over chunks carrying the
SSM state, with a numerically-stable ``lax.associative_scan`` inside each
chunk — the state tensor [B, chunk, d_inner, d_state] never exceeds one chunk
(a full-sequence associative scan at 32k × 8192 × 16 would be ~17 GB/device).
This mirrors the VMEM-chunked structure of the Pallas kernel in
``repro.kernels.mamba_scan``.

Decode mode is the O(1) recurrence: one state update per token; the "cache"
is (conv ring window, SSM state) — constant in sequence length, which is why
falcon-mamba/jamba run the long_500k cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import shard_act


def _ssm_chunk_scan(dt: jax.Array, xi: jax.Array, Bc: jax.Array, Cc: jax.Array,
                    A: jax.Array, h0: jax.Array, chunk: int,
                    unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused chunked selective scan:  y_t = C_t · h_t,
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    dt, xi: [B, S, DI] f32; Bc, Cc: [B, S, N] f32; A: [DI, N]; h0: [B, DI, N].
    Returns (y [B, S, DI] f32, h_S).

    a/bx/h are built PER CHUNK inside the scan and y is contracted against C
    before the next chunk — the [B, S, DI, N] tensors never exist at full
    sequence length (an 88-layer jamba prefill materializing them measured
    198 GiB/device; fused: chunk-sized only). Mirrors the Pallas kernel's
    VMEM blocking (repro.kernels.mamba_scan).
    """
    B, S, DI = xi.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    def r(x):
        return x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)
    dtc, xic, Bcc, Ccc = r(dt), r(xi), r(Bc), r(Cc)

    def combine(l, rgt):
        al, bl = l
        ar, br = rgt
        return al * ar, bl * ar + br

    def body(h, inputs):
        dt_c, xi_c, B_c, C_c = inputs                  # [B, c, ...]
        a = jnp.exp(dt_c[..., None] * A)               # [B, c, DI, N]
        bx = (dt_c * xi_c)[..., None] * B_c[:, :, None, :]
        aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_all = aa * h[:, None] + bb                   # [B, c, DI, N]
        y = jnp.einsum("bcen,bcn->bce", h_all, C_c)    # [B, c, DI]
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(body, h0, (dtc, xic, Bcc, Ccc),
                              unroll=nc if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(B, S, DI)
    return y, h_last


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array = None):
    """Depthwise causal conv. x: [B, S, DI]; w: [K, DI]; carry: [B, K-1, DI]."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)             # [B, S+K-1, DI]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_carry = xp[:, -(K - 1):]
    return out, new_carry


def mamba_forward(x: jax.Array, p: dict, cfg, *, chunk: int = 256,
                  unroll: bool = False) -> Tuple[jax.Array, dict]:
    """Sequence mode. x: [B, S, D] -> (y [B, S, D], cache {conv, ssm})."""
    m = cfg.mamba
    B, S, D = x.shape
    xi = jnp.einsum("bsd,de->bse", x, p["in_x"])         # [B, S, DI]
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xi = shard_act(xi, ("act_batch", "act_seq", "act_mlp"))
    xi, conv_carry = _causal_conv(xi, p["conv_w"])
    xi = jax.nn.silu(xi + p["conv_b"])

    bcdt = jnp.einsum("bse,er->bsr", xi, p["x_proj"])    # [B,S,dt_rank+2N]
    dt, Bc, Cc = jnp.split(bcdt, [m.dt_rank, m.dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)          # [B,S,DI]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                      # [DI, N]
    y, h_last = _ssm_chunk_scan(dt, xi.astype(jnp.float32),
                                Bc.astype(jnp.float32),
                                Cc.astype(jnp.float32), A,
                                jnp.zeros((B, m.d_inner, m.d_state),
                                          jnp.float32), chunk, unroll)
    y = (y + xi.astype(jnp.float32) * p["D"].astype(jnp.float32)) \
        * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": conv_carry, "ssm": h_last.astype(jnp.float32)}


def mamba_decode(x: jax.Array, p: dict, cfg, cache: dict) -> Tuple[jax.Array, dict]:
    """One-token mode. x: [B, D]; cache {conv [B,K-1,DI], ssm [B,DI,N]}."""
    m = cfg.mamba
    xi = jnp.einsum("bd,de->be", x, p["in_x"])
    z = jnp.einsum("bd,de->be", x, p["in_z"])
    xi3, conv_carry = _causal_conv(xi[:, None], p["conv_w"], cache["conv"].astype(xi.dtype))
    xi = jax.nn.silu(xi3[:, 0] + p["conv_b"])

    bcdt = jnp.einsum("be,er->br", xi, p["x_proj"])
    dt, Bc, Cc = jnp.split(bcdt, [m.dt_rank, m.dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("br,re->be", dt, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)                                    # [B,DI,N]
    bx = dt[..., None] * Bc[:, None, :].astype(jnp.float32) * xi[..., None].astype(jnp.float32)
    h = a * cache["ssm"] + bx
    y = jnp.einsum("ben,bn->be", h, Cc.astype(jnp.float32))
    y = (y + xi.astype(jnp.float32) * p["D"].astype(jnp.float32)) \
        * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": conv_carry.astype(cache["conv"].dtype), "ssm": h}

"""Attention: blocked-flash reference implementations + decode paths.

Three executable paths, all pure jnp (XLA), all parity-tested against the
plain-einsum oracle in ``repro.kernels.ref``:

* :func:`attend_blocked` — memory-bounded flash attention as a scan over the
  *static list of contributing (q-block, kv-block) pairs*.  For causal masks
  this is the exact lower triangle (no wasted FLOPs on masked-out blocks —
  matters for the roofline's useful-FLOPs ratio at 32k); for sliding-window
  it is the diagonal band; for bidirectional it is the full square.
* :func:`attend_plain` — small-seq einsum path (smoke tests, tiny serving
  functions).
* :func:`attend_decode` — one-token GQA attention against a (possibly
  ring-buffered) KV cache.

On real TPU the Pallas kernels in ``repro.kernels`` replace the first and
third paths (``impl="pallas"``); the dry-run keeps ``ref`` so cost analysis
reflects the XLA program actually being lowered for the mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import shard_act

NEG_INF = -1e30


def _block_pairs(nq: int, nkv: int, *, causal: bool, window_blocks: int) -> np.ndarray:
    """Static (i, j) block pairs that can contribute under the mask."""
    pairs = []
    for i in range(nq):
        lo = 0
        if window_blocks > 0:                       # sliding window band
            lo = max(0, i - window_blocks)
        hi = i + 1 if causal else nkv
        for j in range(lo, hi):
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


def _pair_mask(i, j, block, causal, window):
    karr = jnp.arange(block)
    qpos = i * block + karr
    kpos = j * block + karr
    mask = jnp.ones((block, block), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window and window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    return mask


def attend_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: int = 0, block: int = 512,
                   impl: str = "ref", unroll: bool = False) -> jax.Array:
    """Flash attention over the static list of contributing block pairs.

    q: [B, S, H, hd]; k, v: [B, S, KV, hd]. Returns [B, S, H, hd].
    ``window`` > 0 restricts to a causal sliding window (gemma3 local layers).

    Uses a flash-style custom VJP: the backward recomputes p-blocks from the
    saved (q, k, v, out, logsumexp) instead of letting JAX AD store every
    [bq, bk] probability block of the forward scan (which would cost
    O(S²/block) residual memory and defeat the whole construction).
    """
    if impl == "pallas":  # TPU path (validated separately in interpret mode)
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, window=window)

    B, S, H, hd = q.shape
    block = min(block, S)
    assert S % block == 0, (S, block)

    @partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
    def _attend(q, k, v, causal, window, block, unroll):
        out, _ = _attend_fwd_impl(q, k, v, causal, window, block, unroll)
        return out

    def _fwd(q, k, v, causal, window, block, unroll):
        out, lse = _attend_fwd_impl(q, k, v, causal, window, block, unroll)
        return out, (q, k, v, out, lse)

    def _bwd(causal, window, block, unroll, res, dout):
        return _attend_bwd_impl(res, dout, causal, window, block, unroll)

    _attend.defvjp(_fwd, _bwd)
    return _attend(q, k, v, causal, window, block, unroll)


def _attend_fwd_impl(q, k, v, causal, window, block, unroll):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    nb = S // block
    wb = int(np.ceil(window / block)) if window else 0
    pairs = _block_pairs(nb, nb, causal=causal, window_blocks=wb)
    scale = hd ** -0.5

    qb = q.reshape(B, nb, block, KV, G, hd)
    kb = k.reshape(B, nb, block, KV, hd)
    vb = v.reshape(B, nb, block, KV, hd)

    acc0 = jnp.zeros((B, nb, block, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, nb, block, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nb, block, KV, G), jnp.float32)

    def body(carry, pij):
        acc, m, l = carry
        i, j = pij[0], pij[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)   # [B,bq,KV,G,hd]
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)   # [B,bk,KV,hd]
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj,
                       preferred_element_type=jnp.float32) * scale    # [B,KV,G,bq,bk]
        s = jnp.where(_pair_mask(i, j, block, causal, window), s, NEG_INF)
        blk_m = jnp.moveaxis(jnp.max(s, axis=-1), -1, 1)              # [B,bq,KV,G]
        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        acci = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, blk_m)
        p = jnp.exp(s - jnp.moveaxis(m_new, 1, -1)[..., None])        # [B,KV,G,bq,bk]
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.moveaxis(jnp.sum(p, -1), -1, 1)
        pv = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acci * corr[..., None] + pv
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(pairs),
                                  unroll=len(pairs) if unroll else 1)
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).reshape(B, S, H, hd).astype(q.dtype)
    lse = (m + jnp.log(l)).reshape(B, S, KV, G)                       # logsumexp
    # barrier: `out` is a saved custom-vjp residual; without it XLA sinks the
    # f32->bf16 convert past the layer-scan's residual stacking and stores the
    # f32 accumulator stack instead (2x bytes; +13.6 GiB on the 62L train cell)
    out, lse = jax.lax.optimization_barrier((out, lse))
    return out, lse


def _attend_bwd_impl(res, dout, causal, window, block, unroll):
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    nb = S // block
    wb = int(np.ceil(window / block)) if window else 0
    pairs = _block_pairs(nb, nb, causal=causal, window_blocks=wb)
    scale = hd ** -0.5

    qb = q.reshape(B, nb, block, KV, G, hd)
    kb = k.reshape(B, nb, block, KV, hd)
    vb = v.reshape(B, nb, block, KV, hd)
    dob = dout.reshape(B, nb, block, KV, G, hd)
    lseb = lse.reshape(B, nb, block, KV, G)
    # D_i = rowsum(dO ∘ O) — the softmax-jacobian diagonal term
    Db = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                 axis=-1).reshape(B, nb, block, KV, G)

    dq0 = jnp.zeros((B, nb, block, KV, G, hd), jnp.float32)
    dk0 = jnp.zeros((B, nb, block, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, nb, block, KV, hd), jnp.float32)

    def body(carry, pij):
        dq, dk, dv = carry
        i, j = pij[0], pij[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(dob, i, 1, keepdims=False)
        lsei = jax.lax.dynamic_index_in_dim(lseb, i, 1, keepdims=False)
        Di = jax.lax.dynamic_index_in_dim(Db, i, 1, keepdims=False)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_pair_mask(i, j, block, causal, window), s, NEG_INF)
        p = jnp.exp(s - jnp.moveaxis(lsei, 1, -1)[..., None])         # [B,KV,G,bq,bk]
        pc = p.astype(vj.dtype)
        dvj = jnp.einsum("bkgqt,bqkgd->btkd", pc, doi,
                         preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", doi, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - jnp.moveaxis(Di, 1, -1)[..., None]) * scale    # [B,KV,G,bq,bk]
        dsc = ds.astype(qi.dtype)
        dqi = jnp.einsum("bkgqt,btkd->bqkgd", dsc, kj,
                         preferred_element_type=jnp.float32)
        dkj = jnp.einsum("bkgqt,bqkgd->btkd", dsc, qi,
                         preferred_element_type=jnp.float32)
        dq = jax.lax.dynamic_update_index_in_dim(
            dq, jax.lax.dynamic_index_in_dim(dq, i, 1, keepdims=False) + dqi, i, 1)
        dk = jax.lax.dynamic_update_index_in_dim(
            dk, jax.lax.dynamic_index_in_dim(dk, j, 1, keepdims=False) + dkj, j, 1)
        dv = jax.lax.dynamic_update_index_in_dim(
            dv, jax.lax.dynamic_index_in_dim(dv, j, 1, keepdims=False) + dvj, j, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), jnp.asarray(pairs),
                                   unroll=len(pairs) if unroll else 1)
    return (dq.reshape(B, S, H, hd).astype(q.dtype),
            dk.reshape(B, S, KV, hd).astype(k.dtype),
            dv.reshape(B, S, KV, hd).astype(v.dtype))


def attend_plain(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: int = 0) -> jax.Array:
    """Materialized-scores reference (small sequences / oracle)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window and window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  positions: jax.Array, *, ring: bool = False,
                  impl: str = "ref") -> jax.Array:
    """One-token attention against the cache.

    q: [B, H, hd]; caches: [B, W, KV, hd]; positions: [B] (current absolute
    position, i.e. index of the token being generated).  ``ring=True`` means
    the cache is a ring buffer of width W over a longer stream (local layers):
    slot s holds absolute token  pos - ((pos - s) mod W)  and every slot
    written so far is in-window by construction.
    """
    if impl == "pallas":
        from repro.kernels import ops
        return ops.decode_attention(q, k_cache, v_cache, positions, ring=ring)

    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    slot = jnp.arange(W)
    if ring:
        # valid once written: slot s valid iff s <= pos or the ring has wrapped
        valid = (slot[None, :] <= positions[:, None]) | (positions[:, None] >= W)
    else:
        valid = slot[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + qk_norm + cache handling)
# ---------------------------------------------------------------------------


def attn_forward(x: jax.Array, p: dict, cfg, layer_local: bool,
                 positions: jax.Array, *, theta: float,
                 block: int = 512, impl: str = "ref",
                 unroll: bool = False) -> Tuple[jax.Array, dict]:
    """Sequence-mode attention (train/prefill). Returns (out, new_cache_entry).

    x: [B, S, D]. Cache entry: k/v [B, W, KV, hd] where W = window for local
    layers else S.
    """
    from repro.models.layers import head_rms_norm, rope
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    # flat [D, H*hd] projections: divisible by the model axis for every arch
    # (H*hd, KV*hd are multiples of 128), so weights/optimizer shard fully
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.causal:  # encoders use absolute positions added at the input
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    q = shard_act(q, ("act_batch", "act_seq", "act_heads", None))
    k = shard_act(k, ("act_batch", "act_seq", "act_kv_heads", None))
    window = cfg.sliding_window if layer_local else 0
    blk = min(block, S)
    if S % blk != 0:
        blk = S                        # single-block fallback (odd smoke shapes)
    out = attend_blocked(q, k, v, causal=cfg.causal, window=window,
                         block=blk, impl=impl, unroll=unroll)
    out = shard_act(out, ("act_batch", "act_seq", "act_heads", None))
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), p["wo"])
    # cache entry for prefill (ring-truncate local layers to the window)
    if layer_local and cfg.sliding_window and S > cfg.sliding_window:
        W = cfg.sliding_window
        # last W tokens, placed at their ring slots (slot = pos % W)
        tail_k, tail_v = k[:, -W:], v[:, -W:]
        start = S - W
        roll = -(start % W)
        cache_k = jnp.roll(tail_k, roll, axis=1)
        cache_v = jnp.roll(tail_v, roll, axis=1)
    else:
        cache_k, cache_v = k, v
    return y, {"k": cache_k, "v": cache_v}


def attn_decode(x: jax.Array, p: dict, cfg, layer_local: bool, cache: dict,
                positions: jax.Array, *, theta: float,
                impl: str = "ref") -> Tuple[jax.Array, dict]:
    """One-token attention. x: [B, D]; cache k/v [B, W, KV, hd]; positions [B]."""
    from repro.models.layers import head_rms_norm, rope
    B, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,de->be", x, p["wq"]).reshape(B, H, hd)
    k = jnp.einsum("bd,de->be", x, p["wk"]).reshape(B, KV, hd)
    v = jnp.einsum("bd,de->be", x, p["wv"]).reshape(B, KV, hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q[:, None], positions[:, None], theta)[:, 0]
    k = rope(k[:, None], positions[:, None], theta)[:, 0]
    W = cache["k"].shape[1]
    ring = bool(layer_local and cfg.sliding_window and W == cfg.sliding_window)
    slot = positions % W if ring else positions
    k_cache = _update_cache(cache["k"], k, slot)
    v_cache = _update_cache(cache["v"], v, slot)
    k_cache = shard_act(k_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    v_cache = shard_act(v_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    out = attend_decode(q, k_cache, v_cache, positions, ring=ring, impl=impl)
    y = jnp.einsum("be,ed->bd", out.reshape(B, H * hd), p["wo"])
    return y, {"k": k_cache, "v": v_cache}


def _update_cache(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Scatter new [B, KV, hd] into cache [B, W, KV, hd] at per-batch slots."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slot].set(new.astype(cache.dtype))

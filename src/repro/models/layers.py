"""Core layers, parameter specs, and the logical-axis annotation system.

Params are described once by :class:`ParamSpec` trees; ``abstract_params``,
``init_params`` and ``logical_axes`` all derive from the same spec so shapes,
shardings and initializers can never drift apart.

Activation sharding: models call :func:`shard_act` with *logical* dim names;
when a sharding context (mesh + rules) is active — set by the trainer or the
dry-run harness — this becomes ``with_sharding_constraint``; on bare CPU it is
the identity, so the same model code runs in unit tests.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (w_* names)
    init: str = "normal"                 # normal | zeros | ones | mamba_a | mamba_dt
    scale: float = 1.0                   # fan-in style scale for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def abstract_param(spec: ParamSpec, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(spec.shape, dtype)


def init_param(spec: ParamSpec, rng, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "mamba_a":
        # A_log init: log of [1..d_state] broadcast over d_inner (mamba1 S4D-real)
        n = spec.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, spec.shape).astype(dtype)
    if spec.init == "mamba_dt":
        # dt_proj bias: softplus^-1 of dt in [1e-3, 1e-1] log-uniform
        u = jax.random.uniform(rng, spec.shape, jnp.float32)
        dt = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if spec.init == "normal":
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        if len(spec.shape) >= 3:  # stacked [L, fan_in, ...] or [E, fan_in, ...]
            fan_in = spec.shape[-2]
        std = spec.scale / np.sqrt(fan_in)
        return (std * jax.random.normal(rng, spec.shape, jnp.float32)).astype(dtype)
    raise ValueError(spec.init)


def build_params(specs, rng, dtype):
    """Materialize a ParamSpec pytree into real arrays (reduced configs only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [init_param(s, r, dtype) for s, r in zip(leaves, rngs)])


def build_abstract(specs, dtype):
    return jax.tree.map(lambda s: abstract_param(s, dtype), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def build_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_context(resolver: Callable):
    """resolver(shape, logical_names) -> NamedSharding | None."""
    prev = getattr(_CTX, "resolver", None)
    _CTX.resolver = resolver
    try:
        yield
    finally:
        _CTX.resolver = prev


def shard_act(x: jax.Array, names: Tuple[Optional[str], ...]) -> jax.Array:
    resolver = getattr(_CTX, "resolver", None)
    if resolver is None:
        return x
    s = resolver(x.shape, names)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# Primitive layers (pure functions)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    # variance as a dot with f32 accumulation: no f32 x-shaped tensor may
    # appear in the HLO at all, else XLA's LICM hoists the convert of the
    # residual stack into the backward while-loop carry (+13.6 GiB measured
    # on the 62-layer train cell). Scaling applies in the compute dtype.
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None] / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + w).astype(x.dtype)


def rms_norm_f32(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """Full-f32 reference (oracle for tests)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """qwen3 qk_norm: RMSNorm over the trailing head_dim, weight shared across heads."""
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None] / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + w).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd] (hd even), positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # [..., S, 1, half]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, dtype) -> jax.Array:
    """Absolute sinusoidal position table (hubert frontend-stub positions)."""
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    tab = np.concatenate([np.sin(ang), np.cos(ang)], -1)
    return jnp.asarray(tab, dtype)


def mlp(x: jax.Array, p: dict, gated: bool) -> jax.Array:
    """SwiGLU (gated) or GELU (plain) MLP. Weights: wi [D,F] (+wg), wo [F,D]."""
    if gated:
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wg"])) \
            * jnp.einsum("...d,df->...f", x, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]))
    h = shard_act(h, ("act_batch", "act_seq", "act_mlp"))
    return jnp.einsum("...f,fd->...d", h, p["wo"])

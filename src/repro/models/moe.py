"""Mixture-of-Experts layer: top-k routing, batch-local gather dispatch.

Design notes (DESIGN.md §5):

* Dispatch is gather/scatter-based, not the GShard one-hot-einsum: the
  one-hot dispatch matmul costs T·E·C·D FLOPs (~2x the expert compute for
  moonshot) and would poison the roofline's useful-FLOPs ratio. Sorting
  tokens and gathering is memory traffic instead of FLOPs — the same trade
  the TPU grouped-matmul kernel (``repro.kernels.moe_gmm``) makes.
* Routing, sorting and capacity are **batch-local** (every op keeps the
  leading batch dim): the batch dim stays sharded over (pod, data) through
  the whole layer, so expert compute splits over data x model and the
  EP exchange lowers to the standard MoE all-to-all. A global flatten-and-
  argsort formulation loses data parallelism entirely (measured 11x FLOP
  bloat on grok before this rewrite).
* Capacity: C = ceil(S·k/E · capacity_factor) per batch row; overflow drops
  to the residual path (Switch behaviour).
* Sharding: experts over ``model`` (EP) when E divides it (moonshot 64,
  jamba 16); otherwise expert_ff TP-shards (grok: 8 experts on a 16-way axis)
  — emergent from rule divisibility, see distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import shard_act


def moe_forward(x: jax.Array, p: dict, cfg, unroll: bool = False) -> jax.Array:
    """x: [B, S, D] (or [B, D] for decode) -> same shape."""
    e = cfg.moe
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]
    B, S, D = x.shape
    E, k = e.num_experts, e.top_k
    C = int(max(1, -(-S * k // E) * e.capacity_factor))
    C = min(C, S * k)

    # --- routing (f32 router, standard for stability) ----------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [B, S, E]
    gate, eidx = jax.lax.top_k(probs, k)                         # [B, S, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- batch-local slot assignment ---------------------------------------
    flat_e = eidx.reshape(B, S * k)                              # [B, Sk]
    flat_t = jnp.repeat(jnp.arange(S), k)[None, :]               # [1, Sk]
    flat_g = gate.reshape(B, S * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)            # group by expert
    se = jnp.take_along_axis(flat_e, order, -1)                  # [B, Sk]
    st = jnp.take_along_axis(jnp.broadcast_to(flat_t, (B, S * k)), order, -1)
    sg = jnp.take_along_axis(flat_g, order, -1)
    # rank within expert: position − start offset of that expert's run
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    pos_in_e = jnp.arange(S * k)[None, :] - jnp.take_along_axis(starts, se, -1)
    valid = pos_in_e < C
    slot = jnp.where(valid, se * C + pos_in_e, E * C)            # pad slot

    # token index per (expert, capacity) slot; pad slots -> row S (zeros).
    # vmap'd scatters: batch becomes an operand-batching dim, so GSPMD keeps
    # these sharded over (pod, data) — explicit `at[rows, slot]` indexing
    # makes dim0 an *indexed* dim and replicates the destination per device
    # (measured 32 GiB/buffer on the jamba prefill cell).
    slot_tok = jax.vmap(
        lambda s_, t_: jnp.full((E * C + 1,), S, jnp.int32)
        .at[s_].set(t_.astype(jnp.int32), mode="drop"))(slot, st)
    slot_gate = jax.vmap(
        lambda s_, g_: jnp.zeros((E * C + 1,), jnp.float32)
        .at[s_].set(g_, mode="drop"))(slot, sg)
    slot_tok, slot_gate = slot_tok[:, :-1], slot_gate[:, :-1]

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], 1)

    # --- expert SwiGLU over CAPACITY CHUNKS ---------------------------------
    # The [B, E, C, D] expert buffers are the prefill memory hot-spot
    # (jamba: 2.5-5 GiB per tensor per layer). Chunking the capacity dim
    # bounds the live set to one chunk while preserving the expert dim for
    # EP sharding; the combine scatter-adds chunk partial sums. Each chunk is
    # batch-local and top_k-disjoint, so accumulation in compute dtype is ok.
    def expert_chunk(y, slots_c):
        tok_c, gate_c = slots_c                                  # [B, E*Cg]
        xin = jnp.take_along_axis(xpad, tok_c[..., None], axis=1)
        xin = xin.reshape(B, E, -1, D)
        xin = shard_act(xin, ("act_batch", "act_expert", None, None))
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["wg"])) \
            * jnp.einsum("becd,edf->becf", xin, p["wi"])
        h = shard_act(h, ("act_batch", "act_expert", None, "act_mlp"))
        out = jnp.einsum("becf,efd->becd", h, p["wo"])           # [B,E,Cg,D]
        out = shard_act(out, ("act_batch", "act_expert", None, None))
        flat = (out.reshape(B, -1, D) * gate_c[..., None].astype(out.dtype)
                ).astype(y.dtype)
        y = jax.vmap(lambda yb, t_, o_: yb.at[t_].add(o_))(y, tok_c, flat)
        return y, None

    # chunk count: keep each [B, E, Cg, D] tile under ~1 GiB globally/shard
    GROUPS = 1
    tile = B * E * C * D * 2
    while GROUPS < C and tile // GROUPS > 2 ** 32:
        GROUPS *= 2
    while C % GROUPS:
        GROUPS //= 2
    y0 = jnp.zeros((B, S + 1, D), x.dtype)
    if GROUPS <= 1:
        y, _ = expert_chunk(y0, (slot_tok, slot_gate))
    else:
        tok_g = slot_tok.reshape(B, E, GROUPS, C // GROUPS) \
            .transpose(2, 0, 1, 3).reshape(GROUPS, B, -1)
        gate_g = slot_gate.reshape(B, E, GROUPS, C // GROUPS) \
            .transpose(2, 0, 1, 3).reshape(GROUPS, B, -1)
        y, _ = jax.lax.scan(expert_chunk, y0, (tok_g, gate_g),
                            unroll=GROUPS if unroll else 1)
    y = shard_act(y[:, :-1], ("act_batch", "act_seq", None)).astype(x.dtype)
    return y[:, 0] if squeeze else y


def moe_aux_loss(x: jax.Array, p: dict, cfg) -> jax.Array:
    """Load-balancing auxiliary loss (Switch): E * sum(f_e * P_e)."""
    e = cfg.moe
    xt = x.reshape(-1, x.shape[-1])
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, e.num_experts, dtype=jnp.float32), 0)
    P = jnp.mean(probs, 0)
    return e.num_experts * jnp.sum(f * P)

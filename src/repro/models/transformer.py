"""Unified LM covering all ten assigned architectures.

The layer stack is executed as ``lax.scan`` over *periods*: the period P is
the LCM of the arch's interleave patterns (jamba attn:mamba 1:7 and MoE-every-2
=> P=8; gemma3 local:global 5:1 => P=6; homogeneous archs => P=1).  Params are
stored per period-slot with a stacked ``[K = L/P, ...]`` leading dim, so the
HLO stays compact (~P blocks) regardless of depth — a 512-device compile of
the 88-layer mistral takes seconds, not minutes.

Modes:
* ``forward_seq``  — train / prefill: [B, S] -> last-token or full logits + cache
* ``decode_step``  — serve_step: one token against the cache (assigned decode
  shapes) — local-attention slots keep *ring-buffer* caches of width
  ``sliding_window`` (gemma3's 500k decode cache is 1024 wide on local slots).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BLOCK_ATTN, ModelConfig, ShapeConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (ParamSpec, build_abstract, build_axes,
                                 build_params, mlp, rms_norm, shard_act,
                                 sinusoidal_pos)

AUX_LOSS_COEF = 0.01


@dataclass(frozen=True)
class SlotKind:
    kind: str          # attn | mamba
    is_moe: bool
    is_local: bool     # sliding-window attention
    theta: float       # rope base (gemma3: 10k local / 1M global)


class LM:
    """Functional model: all methods are pure; params/caches are pytrees."""

    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "ref",
                 attn_block: int = 512, mamba_chunk: int = 256,
                 unroll: bool = False):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.attn_block = attn_block
        self.mamba_chunk = mamba_chunk
        # unroll=True: every lax.scan (layers, attention block pairs, ssm
        # chunks, chunked CE) is fully unrolled so compiled cost_analysis
        # counts true totals (XLA counts while-loop bodies ONCE).  Used by the
        # dry-run's shallow probes; production keeps compact scans.
        self.unroll = unroll
        self.period = self._period(cfg)
        assert cfg.num_layers % self.period == 0, (cfg.name, self.period)
        self.num_periods = cfg.num_layers // self.period
        self.slots: List[SlotKind] = []
        for s in range(self.period):
            kind = cfg.block_kind(s)
            local = cfg.is_local_attn(s)
            theta = cfg.rope_theta
            if cfg.sliding_window and local:
                theta = 10000.0                      # gemma3 local layers
            self.slots.append(SlotKind(kind, cfg.is_moe_layer(s), local, theta))

    @staticmethod
    def _period(cfg: ModelConfig) -> int:
        p = 1
        if cfg.mamba is not None and not cfg.attention_free:
            p = math.lcm(p, cfg.attn_every)
        if cfg.moe is not None:
            p = math.lcm(p, cfg.moe.every)
        if cfg.sliding_window > 0:
            p = math.lcm(p, cfg.swa_period)
    # NB: for every assigned arch this divides num_layers (asserted above).
        return p

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------
    @cached_property
    def param_specs(self) -> Dict[str, Any]:
        c = self.cfg
        K, D = self.num_periods, c.d_model
        specs: Dict[str, Any] = {}
        if c.frontend != "frames":
            specs["embed"] = ParamSpec((c.vocab_size, D), ("w_vocab", "w_embed"))
            if not c.tie_embeddings:
                specs["unembed"] = ParamSpec((c.vocab_size, D), ("w_vocab", "w_embed"))
        else:
            specs["unembed"] = ParamSpec((c.vocab_size, D), ("w_vocab", "w_embed"))
        if c.frontend == "patches":
            specs["patch_proj"] = ParamSpec((D, D), ("w_embed", None))
        specs["final_norm"] = ParamSpec((D,), (None,), init="zeros")
        slot_specs = []
        for s, sk in enumerate(self.slots):
            ps: Dict[str, Any] = {"norm1": ParamSpec((K, D), ("w_layers", None), init="zeros")}
            if sk.kind == BLOCK_ATTN:
                H, KV, hd = c.num_heads, c.num_kv_heads, c.head_dim
                # flat projections: H*hd / KV*hd divide the model axis for
                # every assigned arch even when H doesn't (deepseek H=56)
                ps["wq"] = ParamSpec((K, D, H * hd), ("w_layers", "w_embed", "w_qdim"))
                ps["wk"] = ParamSpec((K, D, KV * hd), ("w_layers", "w_embed", "w_kvdim"))
                ps["wv"] = ParamSpec((K, D, KV * hd), ("w_layers", "w_embed", "w_kvdim"))
                ps["wo"] = ParamSpec((K, H * hd, D), ("w_layers", "w_qdim", "w_embed"))
                if c.qk_norm:
                    ps["q_norm"] = ParamSpec((K, hd), ("w_layers", None), init="zeros")
                    ps["k_norm"] = ParamSpec((K, hd), ("w_layers", None), init="zeros")
            else:
                m = c.mamba
                DI = m.d_inner
                ps["in_x"] = ParamSpec((K, D, DI), ("w_layers", "w_embed", "w_dinner"))
                ps["in_z"] = ParamSpec((K, D, DI), ("w_layers", "w_embed", "w_dinner"))
                ps["conv_w"] = ParamSpec((K, m.d_conv, DI), ("w_layers", None, "w_dinner"))
                ps["conv_b"] = ParamSpec((K, DI), ("w_layers", "w_dinner"), init="zeros")
                ps["x_proj"] = ParamSpec((K, DI, m.dt_rank + 2 * m.d_state),
                                         ("w_layers", "w_dinner", None))
                ps["dt_proj"] = ParamSpec((K, m.dt_rank, DI), ("w_layers", None, "w_dinner"))
                ps["dt_bias"] = ParamSpec((K, DI), ("w_layers", "w_dinner"), init="mamba_dt")
                ps["A_log"] = ParamSpec((K, DI, m.d_state),
                                        ("w_layers", "w_dinner", "w_state"), init="mamba_a")
                ps["D"] = ParamSpec((K, DI), ("w_layers", "w_dinner"), init="ones")
                ps["out_proj"] = ParamSpec((K, DI, D), ("w_layers", "w_dinner", "w_embed"))
            ps["norm2"] = ParamSpec((K, D), ("w_layers", None), init="zeros")
            if sk.is_moe:
                e = c.moe
                E, F = e.num_experts, e.expert_ff
                ps["router"] = ParamSpec((K, D, E), ("w_layers", "w_embed", None))
                ps["moe_wi"] = ParamSpec((K, E, D, F), ("w_layers", "w_expert", "w_embed", "w_moe_mlp"))
                ps["moe_wg"] = ParamSpec((K, E, D, F), ("w_layers", "w_expert", "w_embed", "w_moe_mlp"))
                ps["moe_wo"] = ParamSpec((K, E, F, D), ("w_layers", "w_expert", "w_moe_mlp", "w_embed"))
            elif c.d_ff > 0:
                ps["wi"] = ParamSpec((K, D, c.d_ff), ("w_layers", "w_embed", "w_mlp"))
                if c.gated_mlp:
                    ps["wg"] = ParamSpec((K, D, c.d_ff), ("w_layers", "w_embed", "w_mlp"))
                ps["wo_mlp"] = ParamSpec((K, c.d_ff, D), ("w_layers", "w_mlp", "w_embed"))
            slot_specs.append(ps)
        specs["slots"] = slot_specs
        return specs

    def abstract_params(self):
        return build_abstract(self.param_specs, jnp.dtype(self.cfg.dtype))

    def param_axes(self):
        return build_axes(self.param_specs)

    def init_params(self, rng):
        return build_params(self.param_specs, rng, jnp.dtype(self.cfg.dtype))

    # ------------------------------------------------------------------
    # Input embedding
    # ------------------------------------------------------------------
    def embed_input(self, params, batch) -> jax.Array:
        c = self.cfg
        if c.frontend == "frames":
            x = batch["frames"].astype(jnp.dtype(c.dtype))
            S = x.shape[1]
            return x + sinusoidal_pos(S, c.d_model, x.dtype)[None]
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if c.frontend == "patches" and "patch_embeds" in batch:
            pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype),
                            params["patch_proj"])
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        if not c.causal:
            x = x + sinusoidal_pos(x.shape[1], c.d_model, x.dtype)[None]
        return x

    def logits(self, params, x) -> jax.Array:
        head = params.get("unembed", params.get("embed"))
        out = jnp.einsum("...d,vd->...v", x, head)
        names = ("act_batch", "act_seq", "act_vocab") if out.ndim == 3 \
            else ("act_batch", "act_vocab")
        return shard_act(out, names)

    # ------------------------------------------------------------------
    # Blocks
    # ------------------------------------------------------------------
    def _block_seq(self, x, p, sk: SlotKind, positions):
        c = self.cfg
        h = rms_norm(x, p["norm1"], c.norm_eps)
        if sk.kind == BLOCK_ATTN:
            h, cache = attn_mod.attn_forward(
                h, p, c, sk.is_local, positions, theta=sk.theta,
                block=self.attn_block, impl=self.attn_impl, unroll=self.unroll)
        else:
            h, cache = mamba_mod.mamba_forward(h, p, c, chunk=self.mamba_chunk,
                                               unroll=self.unroll)
        x = x + h
        x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
        h = rms_norm(x, p["norm2"], c.norm_eps)
        if sk.is_moe:
            x = x + moe_mod.moe_forward(h, {"router": p["router"], "wi": p["moe_wi"],
                                            "wg": p["moe_wg"], "wo": p["moe_wo"]}, c,
                                        unroll=self.unroll)
        elif c.d_ff > 0:
            x = x + mlp(h, {"wi": p["wi"], "wg": p.get("wg"), "wo": p["wo_mlp"]},
                        c.gated_mlp)
        x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
        return x, cache

    def _block_decode(self, x, p, sk: SlotKind, cache, positions):
        c = self.cfg
        h = rms_norm(x, p["norm1"], c.norm_eps)
        if sk.kind == BLOCK_ATTN:
            h, cache = attn_mod.attn_decode(h, p, c, sk.is_local, cache,
                                            positions, theta=sk.theta,
                                            impl=self.attn_impl)
        else:
            h, cache = mamba_mod.mamba_decode(h, p, c, cache)
        x = x + h
        h = rms_norm(x, p["norm2"], c.norm_eps)
        if sk.is_moe:
            x = x + moe_mod.moe_forward(h, {"router": p["router"], "wi": p["moe_wi"],
                                            "wg": p["moe_wg"], "wo": p["moe_wo"]}, c)
        elif c.d_ff > 0:
            x = x + mlp(h[:, None], {"wi": p["wi"], "wg": p.get("wg"),
                                     "wo": p["wo_mlp"]}, c.gated_mlp)[:, 0]
        return x, cache

    # ------------------------------------------------------------------
    # Sequence mode (train / prefill)
    # ------------------------------------------------------------------
    def forward_seq(self, params, batch, *, want_cache: bool,
                    remat: Optional[bool] = None):
        c = self.cfg
        use_remat = c.remat if remat is None else remat
        x = self.embed_input(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = shard_act(x, ("act_batch", "act_seq", "act_embed"))

        def period_body(xc, slot_params):
            # barrier: stops XLA hoisting the rms_norm bf16->f32 convert of the
            # carry out of the backward while-loop, which would materialize an
            # f32 copy of the whole [K, B, S, D] residual stack (measured 2x).
            xc = jax.lax.optimization_barrier(xc)
            caches = []
            for s, sk in enumerate(self.slots):
                xc, cache = self._block_seq(xc, slot_params[s], sk, positions)
                caches.append(cache if want_cache else jnp.zeros((), x.dtype))
            return xc, caches

        # prevent_cse=False: inside scan the while-loop already blocks CSE;
        # the default barriers would pin ~3x the carry per layer (measured).
        body = jax.remat(period_body, prevent_cse=False) if use_remat \
            else period_body
        if self.unroll:
            all_caches = []
            for k in range(self.num_periods):
                pk = jax.tree.map(lambda a: a[k], params["slots"])
                x, caches = body(x, pk)
                all_caches.append(caches)
            if want_cache:
                caches = jax.tree.map(lambda *xs: jnp.stack(xs), *all_caches)
            else:
                caches = None
            x = rms_norm(x, params["final_norm"], c.norm_eps)
            return x, caches
        x, caches = jax.lax.scan(body, x, params["slots"])
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        return x, caches if want_cache else None

    def loss_fn(self, params, batch):
        """Mean CE (+ MoE aux). batch: tokens/frames, labels, optional loss_mask."""
        c = self.cfg
        x, _ = self.forward_seq(params, batch, want_cache=False)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        head = params.get("unembed", params.get("embed"))
        chunk = c.logits_chunk
        if chunk and labels.shape[1] % chunk == 0 and labels.shape[1] > chunk:
            loss_sum = _chunked_ce(x, head, labels, mask, chunk,
                                   unroll=self.unroll)
        else:
            logits = self.logits(params, x).astype(jnp.float32)
            lp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
            loss_sum = -jnp.sum(ll * mask)
        loss = loss_sum / jnp.maximum(jnp.sum(mask), 1.0)
        metrics = {"ce": loss}
        if c.moe is not None:
            # aux loss on the input embedding stream (cheap proxy over layers)
            aux = self._aux_loss(params, batch)
            metrics["aux"] = aux
            loss = loss + AUX_LOSS_COEF * aux
        return loss, metrics

    def _aux_loss(self, params, batch):
        c = self.cfg
        x = self.embed_input(params, batch)
        # first MoE slot, first period — representative balance signal
        for s, sk in enumerate(self.slots):
            if sk.is_moe:
                p0 = jax.tree.map(lambda a: a[0], params["slots"][s])
                return moe_mod.moe_aux_loss(x, {"router": p0["router"]}, c)
        return jnp.zeros((), jnp.float32)

    def prefill(self, params, batch):
        """Returns (last-token logits [B, V], cache)."""
        # no grad => no remat: the checkpoint wrapper only blocks XLA's
        # buffer reuse across the period's layers (measured +4x live set on
        # the jamba MoE prefill cell)
        x, caches = self.forward_seq(params, batch, want_cache=True,
                                     remat=False)
        logits = self.logits(params, x[:, -1])
        return logits, {"slots": caches}

    # ------------------------------------------------------------------
    # Decode mode (serve_step)
    # ------------------------------------------------------------------
    def decode_step(self, params, cache, batch):
        """batch: {token: [B] int32, pos: [B] int32}. Returns (logits, cache)."""
        c = self.cfg
        x = jnp.take(params["embed"], batch["token"], axis=0)
        positions = batch["pos"]
        x = shard_act(x, ("act_batch", "act_embed"))

        def period_body(xc, inputs):
            slot_params, cache_k = inputs
            new_caches = []
            for s, sk in enumerate(self.slots):
                xc, nc = self._block_decode(xc, slot_params[s], sk,
                                            cache_k[s], positions)
                new_caches.append(nc)
            return xc, new_caches

        if self.unroll:
            new_caches = []
            for k in range(self.num_periods):
                pk = jax.tree.map(lambda a: a[k], params["slots"])
                ck = jax.tree.map(lambda a: a[k], cache["slots"])
                x, nc = period_body(x, (pk, ck))
                new_caches.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            x, new_cache = jax.lax.scan(period_body, x,
                                        (params["slots"], cache["slots"]))
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        return self.logits(params, x), {"slots": new_cache}

    # ------------------------------------------------------------------
    # Caches
    # ------------------------------------------------------------------
    def _cache_width(self, sk: SlotKind, max_len: int) -> int:
        if sk.is_local and self.cfg.sliding_window:
            return min(self.cfg.sliding_window, max_len)
        return max_len

    def cache_specs(self, batch_size: int, max_len: int):
        """ShapeDtypeStruct pytree + logical-axes pytree for the decode cache."""
        c = self.cfg
        K = self.num_periods
        dt = jnp.dtype(c.dtype)
        specs, axes = [], []
        for sk in self.slots:
            if sk.kind == BLOCK_ATTN:
                W = self._cache_width(sk, max_len)
                sh = (K, batch_size, W, c.num_kv_heads, c.head_dim)
                ax = ("w_layers", "act_batch", "act_kv_seq", "act_kv_heads", None)
                specs.append({"k": jax.ShapeDtypeStruct(sh, dt),
                              "v": jax.ShapeDtypeStruct(sh, dt)})
                axes.append({"k": ax, "v": ax})
            else:
                m = c.mamba
                specs.append({
                    "conv": jax.ShapeDtypeStruct((K, batch_size, m.d_conv - 1, m.d_inner), dt),
                    "ssm": jax.ShapeDtypeStruct((K, batch_size, m.d_inner, m.d_state), jnp.float32),
                })
                axes.append({
                    "conv": ("w_layers", "act_batch", None, "act_mlp"),
                    "ssm": ("w_layers", "act_batch", "act_mlp", None),
                })
        return {"slots": specs}, {"slots": axes}

    def init_cache(self, batch_size: int, max_len: int):
        specs, _ = self.cache_specs(batch_size, max_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    # ------------------------------------------------------------------
    # Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        """Returns (batch_specs, batch_axes) for the given assigned shape."""
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(c.dtype)
        if shape.mode in ("train", "prefill"):
            if c.frontend == "frames":
                specs = {"frames": jax.ShapeDtypeStruct((B, S, c.d_model), dt),
                         "labels": jax.ShapeDtypeStruct((B, S), i32),
                         "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
                axes = {"frames": ("act_batch", "act_seq", None),
                        "labels": ("act_batch", "act_seq"),
                        "loss_mask": ("act_batch", "act_seq")}
            else:
                specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                         "labels": jax.ShapeDtypeStruct((B, S), i32)}
                axes = {"tokens": ("act_batch", "act_seq"),
                        "labels": ("act_batch", "act_seq")}
                if c.frontend == "patches":
                    specs["patch_embeds"] = jax.ShapeDtypeStruct(
                        (B, c.num_patches, c.d_model), dt)
                    axes["patch_embeds"] = ("act_batch", None, None)
            if shape.mode == "prefill":
                specs.pop("labels", None)
                axes.pop("labels", None)
            return specs, axes
        # decode / long_decode: one token + positions; cache comes separately
        specs = {"token": jax.ShapeDtypeStruct((B,), i32),
                 "pos": jax.ShapeDtypeStruct((B,), i32)}
        axes = {"token": ("act_batch",), "pos": ("act_batch",)}
        return specs, axes


def _chunked_ce(x, head, labels, mask, chunk, unroll: bool = False):
    """Cross-entropy summed over the sequence without materializing full logits."""
    B, S, D = x.shape
    nc = S // chunk
    xs = (x.reshape(B, nc, chunk, D).swapaxes(0, 1),
          labels.reshape(B, nc, chunk).swapaxes(0, 1),
          mask.reshape(B, nc, chunk).swapaxes(0, 1))

    def body(tot, inp):
        xc, lc, mc = inp
        logits = jnp.einsum("bsd,vd->bsv", xc, head).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(lp, lc[..., None], axis=-1)[..., 0]
        return tot - jnp.sum(ll * mc), None

    body = jax.remat(body)
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs,
                          unroll=nc if unroll else 1)
    return tot


def build_model(cfg: ModelConfig, **kw) -> LM:
    return LM(cfg, **kw)

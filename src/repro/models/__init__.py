from repro.models.transformer import LM, build_model

__all__ = ["LM", "build_model"]

"""Pluggable arrival processes for the testbed's load generator.

The paper's testbed exists to "quickly evaluate the impact of different
architectures" — but architectures only diverge under realistic traffic
shapes (SeBS; Barcelona-Pons & Garcia-Lopez). This module supplies the
shapes: steady Poisson, bursty MMPP on/off, diurnal rate envelopes, and
exact replay of inter-arrival-time (IAT) traces, Azure-Functions-style.

Determinism contract: every process is a pure function of its parameters
and the ``random.Random`` handed to :meth:`ArrivalProcess.times` — the
same seed always yields the same arrival stream, byte for byte. Processes
never hold hidden RNG state of their own.

Each process also has a vectorized batch path,
:meth:`ArrivalProcess.times_array`, drawing from a
``numpy.random.Generator`` instead. The numpy stream cannot reproduce
the Mersenne scalar stream, so the batch path carries its *own*
determinism contract (same seed ⇒ byte-identical array, pinned by the
``RequestBatch`` golden digests in tests/test_bulk.py) while matching
the scalar path in distribution; the scalar contract is untouched.

All processes yield absolute arrival times strictly inside
``[0, duration_s)`` — except :class:`TraceArrivals`, which replays its
trace verbatim (pass ``duration_s=None`` to replay everything).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Type

import numpy as np

ARRIVALS: Dict[str, Type["ArrivalProcess"]] = {}


def register_arrival(cls):
    """Class decorator: add an ArrivalProcess subclass to the registry."""
    ARRIVALS[cls.kind] = cls
    return cls


def get_arrival(kind: str, **params) -> "ArrivalProcess":
    """Construct a registered arrival process by name: the config hook."""
    if kind not in ARRIVALS:
        raise KeyError(f"arrival process {kind!r} not registered "
                       f"(have: {sorted(ARRIVALS)})")
    return ARRIVALS[kind](**params)


def _poisson_times(rate: float, span: float, np_rng) -> np.ndarray:
    """Arrival times of a homogeneous Poisson(rate) process on
    ``[0, span)``, drawn in vectorized chunks: overdraw the expected
    count by ~4 sigma, cumsum, and top up from the last arrival on the
    (rare) shortfall — memorylessness makes the continuation exact."""
    if rate <= 0.0 or span <= 0.0:
        return np.empty(0, dtype=np.float64)
    scale = 1.0 / rate
    chunks = []
    t_last = 0.0
    while True:
        lam = rate * (span - t_last)
        m = int(lam + 4.0 * math.sqrt(lam + 1.0)) + 16
        ts = t_last + np.cumsum(np_rng.exponential(scale, m))
        if ts[-1] >= span:
            chunks.append(ts[ts < span])
            break
        chunks.append(ts)
        t_last = float(ts[-1])
    return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)


class ArrivalProcess:
    """Base interface: yield absolute arrival times given an RNG."""

    kind = "base"

    def times(self, duration_s: Optional[float],
              rng: random.Random) -> Iterator[float]:
        raise NotImplementedError

    def times_array(self, duration_s: Optional[float],
                    np_rng: np.random.Generator) -> np.ndarray:
        """Vectorized counterpart of :meth:`times`: the full arrival
        stream as one ascending float64 array, drawn from a numpy
        ``Generator`` (the bulk path's own determinism contract — it
        does not reproduce the scalar Mersenne stream, only its
        distribution). Subclasses must override to join the bulk
        generation fast path (``MixedWorkload.generate_bulk``)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no vectorized times_array; "
            "implement it to use the bulk generation fast path")

    def mean_rate(self) -> float:
        """Long-run average arrivals/s (for envelope sanity checks)."""
        raise NotImplementedError


@register_arrival
@dataclass
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process: i.i.d. exponential inter-arrivals."""

    rate: float
    kind = "poisson"

    def times(self, duration_s, rng):
        t = 0.0
        while True:
            t += rng.expovariate(self.rate)
            if duration_s is not None and t >= duration_s:
                return
            yield t

    def times_array(self, duration_s, np_rng):
        if duration_s is None:
            raise ValueError("times_array needs a finite duration_s")
        return _poisson_times(self.rate, duration_s, np_rng)

    def mean_rate(self):
        return self.rate


@register_arrival
@dataclass
class BurstyArrivals(ArrivalProcess):
    """MMPP on/off: Poisson bursts at ``rate_on`` during exponentially
    distributed ON dwells, background ``rate_off`` between them.

    This is the canonical two-state Markov-modulated Poisson process;
    Poisson memorylessness lets each dwell restart its own exponential
    clock without biasing the stream.
    """

    rate_on: float
    rate_off: float = 0.0
    mean_on_s: float = 1.0
    mean_off_s: float = 9.0
    start_on: bool = False
    kind = "bursty"

    def times(self, duration_s, rng):
        on = self.start_on
        seg_start = 0.0
        while duration_s is None or seg_start < duration_s:
            dwell = rng.expovariate(
                1.0 / (self.mean_on_s if on else self.mean_off_s))
            seg_end = seg_start + dwell
            rate = self.rate_on if on else self.rate_off
            if rate > 0.0:
                t = seg_start
                while True:
                    t += rng.expovariate(rate)
                    if t >= seg_end or (duration_s is not None
                                        and t >= duration_s):
                        break
                    yield t
            seg_start = seg_end
            on = not on

    def times_array(self, duration_s, np_rng):
        # per-phase segments: each dwell is one exponential draw, each
        # ON/OFF span one vectorized Poisson batch (memorylessness lets
        # every dwell restart its own clock, exactly like the scalar
        # path)
        if duration_s is None:
            raise ValueError("times_array needs a finite duration_s")
        out = []
        on = self.start_on
        seg_start = 0.0
        while seg_start < duration_s:
            dwell = float(np_rng.exponential(
                self.mean_on_s if on else self.mean_off_s))
            rate = self.rate_on if on else self.rate_off
            span = min(seg_start + dwell, duration_s) - seg_start
            if rate > 0.0 and span > 0.0:
                seg = _poisson_times(rate, span, np_rng)
                if len(seg):
                    out.append(seg_start + seg)
            seg_start += dwell
            on = not on
        if not out:
            return np.empty(0, dtype=np.float64)
        return out[0] if len(out) == 1 else np.concatenate(out)

    def mean_rate(self):
        tot = self.mean_on_s + self.mean_off_s
        return (self.rate_on * self.mean_on_s
                + self.rate_off * self.mean_off_s) / tot


@register_arrival
@dataclass
class DiurnalArrivals(ArrivalProcess):
    """Non-homogeneous Poisson with a sinusoidal rate envelope:

        rate(t) = base_rate * (1 + amplitude * sin(2*pi*t/period + phase))

    Generated by Lewis-Shedler thinning against the peak rate, so the
    instantaneous intensity tracks the envelope exactly.
    """

    base_rate: float
    amplitude: float = 0.8             # 0..1; 1 => troughs reach zero
    period_s: float = 86400.0          # one "day" (compress for studies)
    phase: float = 0.0
    kind = "diurnal"

    def rate_at(self, t: float) -> float:
        return self.base_rate * (
            1.0 + self.amplitude
            * math.sin(2.0 * math.pi * t / self.period_s + self.phase))

    def times(self, duration_s, rng):
        peak = self.base_rate * (1.0 + abs(self.amplitude))
        t = 0.0
        while True:
            t += rng.expovariate(peak)
            if duration_s is not None and t >= duration_s:
                return
            if rng.random() * peak < self.rate_at(t):
                yield t

    def times_array(self, duration_s, np_rng):
        # batch Lewis-Shedler thinning: one Poisson(peak) candidate
        # batch, the sinusoidal envelope evaluated vectorized, one
        # uniform accept batch
        if duration_s is None:
            raise ValueError("times_array needs a finite duration_s")
        peak = self.base_rate * (1.0 + abs(self.amplitude))
        cand = _poisson_times(peak, duration_s, np_rng)
        if not len(cand):
            return cand
        rate = self.base_rate * (1.0 + self.amplitude * np.sin(
            2.0 * np.pi * cand / self.period_s + self.phase))
        keep = np_rng.random(len(cand)) * peak < rate
        return cand[keep]

    def mean_rate(self):
        return self.base_rate


@register_arrival
@dataclass
class TraceArrivals(ArrivalProcess):
    """Replay an inter-arrival-time trace exactly (Azure-Functions-style:
    one IAT in seconds per line; blank lines and ``#`` comments skipped).

    The replay is verbatim — no RNG is consumed — so a written trace
    round-trips to the identical arrival stream. ``loop=True`` tiles the
    trace until ``duration_s``; ``period_s`` (optional) is the full
    cycle length for looping — without it, tiling restarts immediately
    after the *last arrival*, silently dropping any idle tail between
    that arrival and the end of the traced window (and inflating the
    replayed rate for traces with sparse late traffic, e.g. most Azure
    day traces). Converters that know the trace horizon should set it.
    """

    iats: Sequence[float] = field(default_factory=list)
    loop: bool = False
    period_s: Optional[float] = None
    kind = "trace"

    @classmethod
    def from_file(cls, path: str, *, loop: bool = False,
                  period_s: Optional[float] = None) -> "TraceArrivals":
        return cls(iats=read_trace(path), loop=loop, period_s=period_s)

    def times(self, duration_s, rng):
        t = 0.0
        while True:
            start = t
            for iat in self.iats:
                t += iat
                if duration_s is not None and t >= duration_s:
                    return
                yield t
            if not self.loop or not self.iats:
                return
            if self.period_s is not None:
                # restore the cycle's idle tail (never move backwards if
                # a caller passed a period shorter than the trace span)
                t = max(t, start + self.period_s)

    def times_array(self, duration_s, np_rng=None):
        # verbatim replay consumes no RNG; looping tiles cycle offsets
        # (cycle = max(trace span, period_s), matching the scalar
        # idle-tail restoration). Absolute times come from per-cycle
        # offset + cumsum rather than one running float sum, so the two
        # paths can differ in the last ulp — covered by the bulk
        # contract, not the scalar goldens.
        base = np.cumsum(np.asarray(self.iats, dtype=np.float64))
        if not self.loop or not len(base):
            return base if duration_s is None else base[base < duration_s]
        if duration_s is None:
            raise ValueError("looped trace replay needs a finite "
                             "duration_s")
        cycle = (base[-1] if self.period_s is None
                 else max(float(base[-1]), self.period_s))
        if cycle <= 0.0:
            raise ValueError("looped trace with zero span never advances")
        reps = int(math.ceil(duration_s / cycle)) + 1
        tiled = (np.arange(reps, dtype=np.float64)[:, None] * cycle
                 + base[None, :]).ravel()
        return tiled[tiled < duration_s]

    def mean_rate(self):
        total = (self.period_s if self.loop and self.period_s is not None
                 else sum(self.iats))
        return len(self.iats) / total if total > 0 else 0.0


def read_trace(path: str) -> List[float]:
    """Read one IAT (seconds) per line; '#' comments and blanks skipped."""
    iats: List[float] = []
    with open(path) as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if line:
                iats.append(float(line))
    return iats


def write_trace(path: str, iats: Sequence[float]) -> None:
    """Write IATs with full float precision so replay is bit-exact."""
    with open(path, "w") as fh:
        for iat in iats:
            fh.write(f"{iat!r}\n")


def iats_from_times(times: Sequence[float]) -> List[float]:
    """Convert absolute arrival times back into an IAT trace."""
    out: List[float] = []
    prev = 0.0
    for t in times:
        out.append(t - prev)
        prev = t
    return out

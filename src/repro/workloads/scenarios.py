"""Named workload scenarios — the testbed's one-line experiment menu.

Each scenario is a factory registered under a short name; overrides are
plain keyword arguments, so configs / CLIs can build any shape with one
call::

    wl = build_scenario("flash_crowd", duration_s=20.0, seed=3)
    wl.submit_to(sim)

Scenario -> paper mapping: ``steady``/``flash_crowd``/``daily_cycle``
stress RQ-A's within-instance concurrency policies under shapes where
cold-start amortisation differs; ``multi_tenant`` gives the RQ-B worker
model heterogeneous (fn, prompt-size) cost classes to learn;
``trace_replay`` grounds both in recorded production traffic.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.types import FunctionConfig
from repro.workloads.arrivals import (BurstyArrivals, DiurnalArrivals,
                                      PoissonArrivals, TraceArrivals)
from repro.workloads.workload import FunctionProfile, MixedWorkload, SizeDist

SCENARIOS: Dict[str, Callable[..., MixedWorkload]] = {}


def register_scenario(name: str):
    def deco(fn):
        SCENARIOS[name] = fn
        return fn
    return deco


def build_scenario(name: str, **overrides) -> MixedWorkload:
    if name not in SCENARIOS:
        raise KeyError(f"scenario {name!r} not registered "
                       f"(have: {sorted(SCENARIOS)})")
    return SCENARIOS[name](**overrides)


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


@register_scenario("steady")
def steady(*, fn: str = "fn", rps: float = 200.0, duration_s: float = 30.0,
           prompt_tokens: int = 16, seed: int = 1, slo_p95_s: float = 0.5,
           rid_base: Optional[int] = 0) -> MixedWorkload:
    """Baseline homogeneous Poisson load on a single function."""
    return MixedWorkload(
        PoissonArrivals(rps),
        [FunctionProfile(fn, size=SizeDist.const(prompt_tokens),
                         slo_p95_s=slo_p95_s)],
        duration_s=duration_s, seed=seed, rid_base=rid_base)


@register_scenario("flash_crowd")
def flash_crowd(*, fn: str = "fn", base_rps: float = 50.0,
                burst_rps: float = 1500.0, mean_burst_s: float = 2.0,
                mean_calm_s: float = 10.0, duration_s: float = 30.0,
                seed: int = 1, slo_p95_s: float = 1.0,
                rid_base: Optional[int] = 0) -> MixedWorkload:
    """MMPP on/off: calm background traffic punctured by sharp spikes —
    the shape that punishes slow cold starts and stale LB state."""
    return MixedWorkload(
        BurstyArrivals(rate_on=burst_rps, rate_off=base_rps,
                       mean_on_s=mean_burst_s, mean_off_s=mean_calm_s),
        [FunctionProfile(fn, size=SizeDist.lognormal(24, 0.5),
                         slo_p95_s=slo_p95_s)],
        duration_s=duration_s, seed=seed, rid_base=rid_base)


@register_scenario("daily_cycle")
def daily_cycle(*, fn: str = "fn", mean_rps: float = 150.0,
                amplitude: float = 0.9, period_s: float = 60.0,
                duration_s: float = 60.0, seed: int = 1,
                slo_p95_s: float = 0.8,
                rid_base: Optional[int] = 0) -> MixedWorkload:
    """Sinusoidal diurnal envelope, compressed to ``period_s`` per "day"
    so a full peak/trough cycle fits in one simulator run."""
    return MixedWorkload(
        DiurnalArrivals(base_rate=mean_rps, amplitude=amplitude,
                        period_s=period_s),
        [FunctionProfile(fn, size=SizeDist.const(16), slo_p95_s=slo_p95_s)],
        duration_s=duration_s, seed=seed, rid_base=rid_base)


@register_scenario("multi_tenant")
def multi_tenant(*, rps: float = 300.0, duration_s: float = 30.0,
                 seed: int = 1, memory_skew: bool = False,
                 rid_base: Optional[int] = 0) -> MixedWorkload:
    """Three tenants with distinct cost classes: chat (frequent, small),
    embed (mid), batch (rare, huge prompts). Feeds RQ-B two+ cost
    classes and exercises warm-affinity routing. ``memory_skew=True``
    additionally gives the tenants heterogeneous replica footprints
    (chat small, batch huge) — the shape where placement quality shows."""
    # per-tenant SLOs: interactive chat is tight, embedding mid, batch loose
    mem = {"chat": 256, "embed": 512, "batch": 1536} if memory_skew else {}
    profiles = [
        FunctionProfile("chat", weight=6.0, size=SizeDist.lognormal(32, 0.6),
                        slo_p95_s=0.5, memory_mb=mem.get("chat")),
        FunctionProfile("embed", weight=3.0, size=SizeDist.uniform(8, 64),
                        slo_p95_s=1.0, memory_mb=mem.get("embed")),
        FunctionProfile("batch", weight=1.0,
                        size=SizeDist.choice([256, 512, 1024],
                                             [0.5, 0.3, 0.2]),
                        slo_p95_s=5.0, memory_mb=mem.get("batch")),
    ]
    return MixedWorkload(PoissonArrivals(rps), profiles,
                         duration_s=duration_s, seed=seed, rid_base=rid_base)


@register_scenario("multi_tenant_memory")
def multi_tenant_memory(**overrides) -> MixedWorkload:
    """The memory-skewed ``multi_tenant`` variant as a first-class name:
    heterogeneous per-tenant replica footprints for placement studies."""
    overrides.setdefault("memory_skew", True)
    return multi_tenant(**overrides)


@register_scenario("zone_outage")
def zone_outage(*, rps: float = 150.0, duration_s: float = 12.0,
                seed: int = 1, outage_at: float = 4.0,
                outage_zone: str = "z0", outage_duration_s: float = 4.0,
                slo_p95_s: float = 1.0, lost_finish_p: float = 0.0,
                rid_base: Optional[int] = 0) -> MixedWorkload:
    """Chaos scenario: steady two-tenant traffic with one failure domain
    scripted to go dark mid-run. The workload carries its fault plan as
    ``wl.faults`` (a ``FaultConfig``), which ``Simulator.load`` attaches
    — run it against a ``Simulator(zones=...)`` so ``outage_zone``
    exists. The canonical A/B: ``spread_zones`` placement + a retry
    budget rides through the outage; zone-blind ``spread`` + no retries
    loses its warm capacity and its in-flight work in one event."""
    from repro.core.faults import FaultConfig
    profiles = [
        FunctionProfile("chat", weight=4.0, size=SizeDist.const(24),
                        slo_p95_s=slo_p95_s),
        FunctionProfile("embed", weight=1.0, size=SizeDist.const(32),
                        slo_p95_s=2 * slo_p95_s),
    ]
    wl = MixedWorkload(PoissonArrivals(rps), profiles,
                       duration_s=duration_s, seed=seed, rid_base=rid_base)
    wl.faults = FaultConfig(
        seed=seed, lost_finish_p=lost_finish_p,
        scheduled=((outage_at, outage_zone, outage_duration_s),))
    return wl


@register_scenario("retry_storm")
def retry_storm(*, rps: float = 400.0, duration_s: float = 10.0,
                seed: int = 1, outage_at: float = 3.0,
                outage_zones: tuple = ("z0", "z1"),
                outage_duration_s: float = 2.0, slo_p95_s: float = 1.0,
                rid_base: Optional[int] = 0) -> MixedWorkload:
    """Chaos scenario: high-rate traffic with *most* of the fleet
    (every zone named in ``outage_zones``) failing at once — the shape
    where a retry budget without a storm guard re-offers the whole
    blast wave back into the survivors. Exercises the simulator's
    ``retry_storm_cap`` shedding."""
    from repro.core.faults import FaultConfig
    wl = MixedWorkload(
        PoissonArrivals(rps),
        [FunctionProfile("chat", size=SizeDist.const(24),
                         slo_p95_s=slo_p95_s)],
        duration_s=duration_s, seed=seed, rid_base=rid_base)
    wl.faults = FaultConfig(
        seed=seed,
        scheduled=tuple((outage_at, z, outage_duration_s)
                        for z in outage_zones))
    return wl


@register_scenario("noisy_neighbor")
def noisy_neighbor(*, rps: float = 120.0, flood_x: float = 10.0,
                   duration_s: float = 12.0, seed: int = 1,
                   gateway: bool = True, flood_rate: float = 40.0,
                   flood_burst: float = 20.0, max_inflight: int = 64,
                   batch_share: float = 0.5,
                   rid_base: Optional[int] = 0) -> MixedWorkload:
    """Front-door scenario: two well-behaved interactive tenants plus a
    ``flood`` batch tenant offering ``flood_x`` times their combined
    load. Without a gateway the flood queues the shared fleet to its
    timeout horizon and everyone's p95 blows through SLO; with the
    carried :class:`~repro.core.gateway.GatewayConfig` (``wl.gateway``,
    attached by ``Simulator.load`` like a fault plan) the flood is
    rate-limited to ``flood_rate`` rps and the admission ceiling sheds
    batch first, so the interactive tenants ride through within SLO.
    ``gateway=False`` builds the no-gateway baseline for the A/B."""
    from repro.core.gateway import GatewayConfig, TenantQuota
    profiles = [
        FunctionProfile("chat", weight=3.0, size=SizeDist.const(24),
                        slo_p95_s=0.5, priority="interactive"),
        FunctionProfile("embed", weight=1.0, size=SizeDist.const(32),
                        slo_p95_s=1.0, priority="interactive"),
        FunctionProfile("flood", weight=4.0 * flood_x,
                        size=SizeDist.const(24), slo_p95_s=5.0,
                        priority="batch"),
    ]
    wl = MixedWorkload(PoissonArrivals(rps * (1.0 + flood_x)), profiles,
                       duration_s=duration_s, seed=seed, rid_base=rid_base)
    if gateway:
        wl.gateway = GatewayConfig(
            quotas={"flood": TenantQuota(rate=flood_rate,
                                         burst=flood_burst,
                                         priority="batch")},
            max_inflight=max_inflight, batch_share=batch_share)
    return wl


@register_scenario("ml_pipeline")
def ml_pipeline(*, rps: float = 30.0, duration_s: float = 20.0,
                seed: int = 1, slo_s: float = 2.0, audit_prob: float = 0.3,
                rid_base: int = 0, prewarm_next: bool = True):
    """Workflow scenario: the canonical inference chain. ``preprocess →
    infer → postprocess`` is the critical path (``infer`` dominates);
    ``audit`` is a conditional side branch off ``preprocess`` that only
    some instances take. The shape where critical-path-aware routing
    diverges from stage-blind deadline routing: warm capacity for the
    heavy middle stage is scarce, and a cold start there moves the
    end-to-end deadline one-for-one."""
    from repro.workloads.workflows import (StageSpec, WorkflowSpec,
                                           WorkflowWorkload)
    spec = WorkflowSpec("ml_pipeline", stages=(
        StageSpec("preprocess", fn="preprocess",
                  size=SizeDist.uniform(8, 24), weight=1.0),
        StageSpec("infer", fn="infer", deps=("preprocess",),
                  size=SizeDist.lognormal(48, 0.4), weight=4.0),
        StageSpec("postprocess", fn="postprocess", deps=("infer",),
                  size=SizeDist.const(16), weight=1.0),
        StageSpec("audit", fn="audit", deps=("preprocess",),
                  size=SizeDist.const(8), weight=0.5, prob=audit_prob),
    ), slo_s=slo_s)
    return WorkflowWorkload(PoissonArrivals(rps), spec,
                            duration_s=duration_s, seed=seed,
                            rid_base=rid_base, prewarm_next=prewarm_next)


@register_scenario("etl_fanout")
def etl_fanout(*, rps: float = 12.0, duration_s: float = 20.0,
               seed: int = 1, maps: int = 8, slo_s: float = 2.5,
               rid_base: int = 0, prewarm_next: bool = True):
    """Workflow scenario: map-reduce. ``split`` fans out to ``maps``
    parallel ``map`` tasks whose join gates ``reduce`` — end-to-end
    latency is the *slowest* map task, so one straggling cold start on
    the fan-out blows the whole instance's deadline."""
    from repro.workloads.workflows import (StageSpec, WorkflowSpec,
                                           WorkflowWorkload)
    spec = WorkflowSpec("etl_fanout", stages=(
        StageSpec("split", fn="split", size=SizeDist.const(32), weight=1.0),
        StageSpec("map", fn="map", deps=("split",), fanout=maps,
                  size=SizeDist.uniform(16, 64), weight=2.0),
        StageSpec("reduce", fn="reduce", deps=("map",),
                  size=SizeDist.const(48), weight=1.5),
    ), slo_s=slo_s)
    return WorkflowWorkload(PoissonArrivals(rps), spec,
                            duration_s=duration_s, seed=seed,
                            rid_base=rid_base, prewarm_next=prewarm_next)


@register_scenario("trace_replay")
def trace_replay(*, path: str, fn: str = "fn", fmt: str = "iat",
                 duration_s: Optional[float] = None, loop: bool = False,
                 prompt_tokens: int = 16, seed: int = 1,
                 function: Optional[str] = None, time_scale: float = 1.0,
                 aggregate: bool = False,
                 rid_base: Optional[int] = 0) -> MixedWorkload:
    """Replay a recorded trace file exactly.

    ``fmt="iat"`` reads one inter-arrival time per line; ``fmt="azure"``
    ingests the Azure Functions public-trace CSV (per-minute invocation
    counts) through ``repro.workloads.azure`` — pick a function by hash
    prefix with ``function=``, replay the whole file's load shape with
    ``aggregate=True``, and compress the traced day with ``time_scale``.
    """
    if fmt == "iat":
        if function is not None or aggregate or time_scale != 1.0:
            raise ValueError(
                "function=/aggregate=/time_scale= only apply to the Azure "
                "trace format — pass fmt='azure' (fmt='iat' would silently "
                "replay the wrong stream)")
        arrivals = TraceArrivals.from_file(path, loop=loop)
    elif fmt == "azure":
        from repro.workloads.azure import azure_trace_arrivals
        arrivals = azure_trace_arrivals(path, function=function,
                                        time_scale=time_scale,
                                        aggregate=aggregate, loop=loop)
    else:
        raise ValueError(f"unknown trace format {fmt!r} "
                         f"(have: 'iat', 'azure')")
    return MixedWorkload(
        arrivals,
        [FunctionProfile(fn, size=SizeDist.const(prompt_tokens))],
        duration_s=duration_s, seed=seed, rid_base=rid_base)


# defaults used when a scenario function name has no explicit config:
# (arch, concurrency, cold_start_s) per well-known tenant name.
_DEMO_CFG = {
    "chat": ("tiny_lm", 4, 0.15),
    "embed": ("tiny_lm", 8, 0.10),
    "batch": ("small_lm", 1, 0.40),
    # noisy_neighbor's flooding batch tenant
    "flood": ("tiny_lm", 4, 0.20),
    # workflow stage functions (ml_pipeline / etl_fanout): the heavy
    # middle stages carry the expensive cold starts
    "preprocess": ("tiny_lm", 4, 0.15),
    "infer": ("small_lm", 2, 0.45),
    "postprocess": ("tiny_lm", 4, 0.15),
    "audit": ("tiny_lm", 2, 0.25),
    "split": ("tiny_lm", 4, 0.20),
    "map": ("tiny_lm", 4, 0.30),
    "reduce": ("tiny_lm", 2, 0.35),
}


def install_demo_configs(store, workload: MixedWorkload) -> None:
    """Register a sensible FunctionConfig for every fn in the mix that the
    store does not already know — lets examples/benches run any scenario
    without per-function boilerplate. A profile's ``memory_mb`` (if set)
    carries through to the config, so memory-skewed scenarios reach the
    placement layer with no extra wiring."""
    for p in workload.profiles:
        try:
            store.get(p.fn)
            continue
        except KeyError:
            pass
        arch, conc, cold = _DEMO_CFG.get(p.fn, ("tiny_lm", 4, 0.2))
        mem = {} if p.memory_mb is None else {"memory_mb": p.memory_mb}
        store.put(FunctionConfig(name=p.fn, arch=arch, concurrency=conc,
                                 cold_start_s=cold, **mem))

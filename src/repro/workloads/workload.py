"""Multi-function workload mixes layered on top of an arrival process.

A :class:`MixedWorkload` binds one arrival process to a weighted set of
:class:`FunctionProfile`\\ s, each with its own prompt-size distribution —
the heterogeneous-tenant traffic under which platform architectures
actually diverge. Two independent RNG streams (arrivals vs. mix) are
derived from one seed, so adding a function to the mix never perturbs the
arrival times.

Determinism contract: same seed => byte-identical ``Request`` stream
(including ``rid``\\ s when ``rid_base`` is set, the default), and hence a
byte-identical ``RequestResult`` stream out of a seeded ``Simulator``.

The vectorized bulk path (:meth:`MixedWorkload.generate_bulk` →
:class:`RequestBatch`) draws from numpy ``Generator`` streams instead
and carries its *own* contract: same seed ⇒ byte-identical
``RequestBatch`` (pinned by golden digests in tests/test_bulk.py),
matching the scalar path in distribution but not byte-for-byte — the
numpy stream cannot reproduce the Mersenne one. The scalar path above
is untouched.
"""
from __future__ import annotations

import hashlib
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import Request
from repro.workloads.arrivals import ArrivalProcess


@dataclass(frozen=True)
class SizeDist:
    """Seeded prompt-size sampler. Kinds: const | uniform | lognormal |
    choice. Construct via the classmethods; ``sample`` draws from the
    workload's mix RNG so it stays on the determinism contract."""

    dist: str = "const"
    a: float = 16.0                    # const value / lo / median
    b: float = 0.0                     # hi / sigma
    values: Sequence[int] = ()
    weights: Sequence[float] = ()

    @classmethod
    def const(cls, n: int) -> "SizeDist":
        return cls("const", a=n)

    @classmethod
    def uniform(cls, lo: int, hi: int) -> "SizeDist":
        return cls("uniform", a=lo, b=hi)

    @classmethod
    def lognormal(cls, median: float, sigma: float = 0.6) -> "SizeDist":
        return cls("lognormal", a=median, b=sigma)

    @classmethod
    def choice(cls, values: Sequence[int],
               weights: Optional[Sequence[float]] = None) -> "SizeDist":
        return cls("choice", values=tuple(values),
                   weights=tuple(weights or [1.0] * len(values)))

    def sample(self, rng: random.Random) -> int:
        if self.dist == "const":
            return int(self.a)
        if self.dist == "uniform":
            return rng.randint(int(self.a), int(self.b))
        if self.dist == "lognormal":
            return max(1, round(self.a * math.exp(
                rng.gauss(0.0, self.b))))
        if self.dist == "choice":
            return rng.choices(self.values, weights=self.weights, k=1)[0]
        raise ValueError(f"unknown size distribution {self.dist!r}")

    def sample_array(self, n: int, np_rng: np.random.Generator) -> np.ndarray:
        """Vectorized counterpart of :meth:`sample`: ``n`` int64 draws
        from a numpy ``Generator`` (the bulk path's own determinism
        contract — same distribution as the scalar path, different
        stream)."""
        if self.dist == "const":
            return np.full(n, int(self.a), dtype=np.int64)
        if self.dist == "uniform":
            return np_rng.integers(int(self.a), int(self.b) + 1, size=n,
                                   dtype=np.int64)
        if self.dist == "lognormal":
            draws = self.a * np.exp(np_rng.normal(0.0, self.b, size=n))
            return np.maximum(1, np.rint(draws)).astype(np.int64)
        if self.dist == "choice":
            w = np.asarray(self.weights, dtype=np.float64)
            return np_rng.choice(np.asarray(self.values, dtype=np.int64),
                                 size=n, p=w / w.sum())
        raise ValueError(f"unknown size distribution {self.dist!r}")


@dataclass
class RequestBatch:
    """Columnar (struct-of-arrays) request batch from
    :meth:`MixedWorkload.generate_bulk` — the bulk-ingest counterpart of
    a ``Request`` list, without the per-request object churn. Columns
    are parallel arrays in ascending arrival order; ``fn_idx`` indexes
    into ``fns``; a NaN ``deadline_t`` means "no deadline" (maps to
    ``Request.deadline_t=None``)."""

    fns: Tuple[str, ...]
    arrival_t: np.ndarray              # float64, ascending
    fn_idx: np.ndarray                 # int32 index into fns
    size: np.ndarray                   # int64 prompt sizes
    rid: np.ndarray                    # int64 request ids
    deadline_t: np.ndarray             # float64; NaN => no deadline

    def __len__(self) -> int:
        return len(self.arrival_t)

    def digest(self) -> str:
        """sha256 over the raw column bytes (fixed dtypes/endianness):
        the bulk determinism contract's byte-identity witness."""
        h = hashlib.sha256(repr(self.fns).encode())
        for col, dt in ((self.arrival_t, "<f8"), (self.fn_idx, "<i4"),
                        (self.size, "<i8"), (self.rid, "<i8"),
                        (self.deadline_t, "<f8")):
            h.update(np.ascontiguousarray(col, dtype=dt).tobytes())
        return h.hexdigest()[:16]

    def slice(self, lo: int, hi: int) -> "RequestBatch":
        return RequestBatch(self.fns, self.arrival_t[lo:hi],
                            self.fn_idx[lo:hi], self.size[lo:hi],
                            self.rid[lo:hi], self.deadline_t[lo:hi])

    def iter_chunks(self, chunk: int) -> Iterator["RequestBatch"]:
        """Views (no copies) of ``chunk`` consecutive requests each —
        the streaming unit ``Simulator.load_bulk`` pushes per bulk run."""
        for lo in range(0, len(self), chunk):
            yield self.slice(lo, lo + chunk)

    def to_requests(self) -> List[Request]:
        """Materialize ``Request`` objects (the simulator's payload
        type) in arrival order."""
        fns = self.fns
        out: List[Request] = []
        ap = out.append
        for t, fi, sz, rid, dl in zip(
                self.arrival_t.tolist(), self.fn_idx.tolist(),
                self.size.tolist(), self.rid.tolist(),
                self.deadline_t.tolist()):
            ap(Request(fn=fns[fi], arrival_t=t, size=sz, rid=rid,
                       deadline_t=None if dl != dl else dl))  # NaN check
        return out


@dataclass(frozen=True)
class FunctionProfile:
    """One tenant function in a mix: routing weight + prompt-size shape +
    latency objective + replica memory footprint."""

    fn: str
    weight: float = 1.0
    size: SizeDist = field(default_factory=lambda: SizeDist.const(16))
    # per-function p95 latency SLO the slo_aware autoscaler targets and
    # deadline_aware routing derives request deadlines from;
    # None => no explicit objective for this tenant
    slo_p95_s: Optional[float] = None
    # per-replica memory the placement layer bin-packs against worker
    # capacity; None => the FunctionConfig default (512 MB)
    memory_mb: Optional[int] = None
    # gateway priority class ("interactive" | "batch") stamped onto every
    # request this tenant emits; None => the front door falls back to the
    # tenant quota's class (core/gateway.py), ultimately "interactive"
    priority: Optional[str] = None


class MixedWorkload:
    """Weighted multi-function request stream over an arrival process.

    ``rid_base`` (default 0) assigns request ids deterministically from
    that base, which is what makes two same-seed runs byte-identical.
    Pass ``rid_base=None`` to fall back to the process-global id counter
    (legacy ``poisson_load`` behaviour), or distinct bases when
    submitting several workloads into one simulator.
    """

    def __init__(self, arrivals: ArrivalProcess,
                 profiles: Sequence[FunctionProfile], *,
                 duration_s: Optional[float], seed: int = 1,
                 rid_base: Optional[int] = 0):
        if not profiles:
            raise ValueError("MixedWorkload needs at least one profile")
        self.arrivals = arrivals
        self.profiles = list(profiles)
        self.duration_s = duration_s
        self.seed = seed
        self.rid_base = rid_base
        self._weights = [p.weight for p in self.profiles]

    def fns(self) -> List[str]:
        return [p.fn for p in self.profiles]

    def slo_targets(self) -> dict:
        """Per-function p95 SLOs declared by the mix (fns without an
        explicit objective are omitted) — feed to ``slo_aware``."""
        return {p.fn: p.slo_p95_s for p in self.profiles
                if p.slo_p95_s is not None}

    def requests(self) -> Iterator[Request]:
        arr_rng = random.Random(self.seed)
        mix_rng = random.Random(f"mix-{self.seed}")
        rids = itertools.count(self.rid_base) if self.rid_base is not None \
            else None
        single = self.profiles[0] if len(self.profiles) == 1 else None
        for t in self.arrivals.times(self.duration_s, arr_rng):
            p = single if single is not None else mix_rng.choices(
                self.profiles, weights=self._weights, k=1)[0]
            size = p.size.sample(mix_rng)
            # slo_p95_s doubles as the request's completion deadline —
            # what deadline_aware routing scores branches against
            deadline = (t + p.slo_p95_s if p.slo_p95_s is not None
                        else None)
            if rids is None:
                yield Request(fn=p.fn, arrival_t=t, size=size,
                              deadline_t=deadline, priority=p.priority)
            else:
                yield Request(fn=p.fn, arrival_t=t, size=size,
                              rid=next(rids), deadline_t=deadline,
                              priority=p.priority)

    def generate(self) -> List[Request]:
        return list(self.requests())

    def generate_bulk(self) -> RequestBatch:
        """Vectorized counterpart of :meth:`generate`: the whole stream
        as one columnar :class:`RequestBatch`, drawn from two numpy
        ``Generator`` streams (arrivals vs. mix, spawned from one
        ``SeedSequence`` so adding a function never perturbs arrival
        times — same independence property as the scalar path). Own
        determinism contract: same seed ⇒ byte-identical batch; the
        scalar Mersenne stream is not reproduced, only its
        distribution."""
        if self.rid_base is None:
            raise ValueError(
                "generate_bulk needs a deterministic rid_base (the "
                "process-global id counter cannot be assigned in bulk)")
        arr_ss, mix_ss = np.random.SeedSequence(self.seed % 2**64).spawn(2)
        times = self.arrivals.times_array(
            self.duration_s, np.random.default_rng(arr_ss))
        times = np.ascontiguousarray(times, dtype=np.float64)
        mix_rng = np.random.default_rng(mix_ss)
        n = len(times)
        k = len(self.profiles)
        if k == 1:
            fn_idx = np.zeros(n, dtype=np.int32)
            sizes = self.profiles[0].size.sample_array(n, mix_rng)
        else:
            w = np.asarray(self._weights, dtype=np.float64)
            fn_idx = mix_rng.choice(k, size=n,
                                    p=w / w.sum()).astype(np.int32)
            sizes = np.empty(n, dtype=np.int64)
            for i, p in enumerate(self.profiles):
                mask = fn_idx == i
                sizes[mask] = p.size.sample_array(int(mask.sum()), mix_rng)
        deadlines = np.full(n, np.nan)
        for i, p in enumerate(self.profiles):
            if p.slo_p95_s is not None:
                mask = fn_idx == i
                deadlines[mask] = times[mask] + p.slo_p95_s
        rid0 = self.rid_base
        return RequestBatch(fns=tuple(p.fn for p in self.profiles),
                            arrival_t=times, fn_idx=fn_idx, size=sizes,
                            rid=np.arange(rid0, rid0 + n, dtype=np.int64),
                            deadline_t=deadlines)

    def submit_to(self, sim) -> int:
        """Feed every request into a Simulator; returns the count."""
        n = 0
        for req in self.requests():
            sim.submit(req)
            n += 1
        return n

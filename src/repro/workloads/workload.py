"""Multi-function workload mixes layered on top of an arrival process.

A :class:`MixedWorkload` binds one arrival process to a weighted set of
:class:`FunctionProfile`\\ s, each with its own prompt-size distribution —
the heterogeneous-tenant traffic under which platform architectures
actually diverge. Two independent RNG streams (arrivals vs. mix) are
derived from one seed, so adding a function to the mix never perturbs the
arrival times.

Determinism contract: same seed => byte-identical ``Request`` stream
(including ``rid``\\ s when ``rid_base`` is set, the default), and hence a
byte-identical ``RequestResult`` stream out of a seeded ``Simulator``.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence

from repro.core.types import Request
from repro.workloads.arrivals import ArrivalProcess


@dataclass(frozen=True)
class SizeDist:
    """Seeded prompt-size sampler. Kinds: const | uniform | lognormal |
    choice. Construct via the classmethods; ``sample`` draws from the
    workload's mix RNG so it stays on the determinism contract."""

    dist: str = "const"
    a: float = 16.0                    # const value / lo / median
    b: float = 0.0                     # hi / sigma
    values: Sequence[int] = ()
    weights: Sequence[float] = ()

    @classmethod
    def const(cls, n: int) -> "SizeDist":
        return cls("const", a=n)

    @classmethod
    def uniform(cls, lo: int, hi: int) -> "SizeDist":
        return cls("uniform", a=lo, b=hi)

    @classmethod
    def lognormal(cls, median: float, sigma: float = 0.6) -> "SizeDist":
        return cls("lognormal", a=median, b=sigma)

    @classmethod
    def choice(cls, values: Sequence[int],
               weights: Optional[Sequence[float]] = None) -> "SizeDist":
        return cls("choice", values=tuple(values),
                   weights=tuple(weights or [1.0] * len(values)))

    def sample(self, rng: random.Random) -> int:
        if self.dist == "const":
            return int(self.a)
        if self.dist == "uniform":
            return rng.randint(int(self.a), int(self.b))
        if self.dist == "lognormal":
            import math
            return max(1, round(self.a * math.exp(
                rng.gauss(0.0, self.b))))
        if self.dist == "choice":
            return rng.choices(self.values, weights=self.weights, k=1)[0]
        raise ValueError(f"unknown size distribution {self.dist!r}")


@dataclass(frozen=True)
class FunctionProfile:
    """One tenant function in a mix: routing weight + prompt-size shape +
    latency objective + replica memory footprint."""

    fn: str
    weight: float = 1.0
    size: SizeDist = field(default_factory=lambda: SizeDist.const(16))
    # per-function p95 latency SLO the slo_aware autoscaler targets and
    # deadline_aware routing derives request deadlines from;
    # None => no explicit objective for this tenant
    slo_p95_s: Optional[float] = None
    # per-replica memory the placement layer bin-packs against worker
    # capacity; None => the FunctionConfig default (512 MB)
    memory_mb: Optional[int] = None


class MixedWorkload:
    """Weighted multi-function request stream over an arrival process.

    ``rid_base`` (default 0) assigns request ids deterministically from
    that base, which is what makes two same-seed runs byte-identical.
    Pass ``rid_base=None`` to fall back to the process-global id counter
    (legacy ``poisson_load`` behaviour), or distinct bases when
    submitting several workloads into one simulator.
    """

    def __init__(self, arrivals: ArrivalProcess,
                 profiles: Sequence[FunctionProfile], *,
                 duration_s: Optional[float], seed: int = 1,
                 rid_base: Optional[int] = 0):
        if not profiles:
            raise ValueError("MixedWorkload needs at least one profile")
        self.arrivals = arrivals
        self.profiles = list(profiles)
        self.duration_s = duration_s
        self.seed = seed
        self.rid_base = rid_base
        self._weights = [p.weight for p in self.profiles]

    def fns(self) -> List[str]:
        return [p.fn for p in self.profiles]

    def slo_targets(self) -> dict:
        """Per-function p95 SLOs declared by the mix (fns without an
        explicit objective are omitted) — feed to ``slo_aware``."""
        return {p.fn: p.slo_p95_s for p in self.profiles
                if p.slo_p95_s is not None}

    def requests(self) -> Iterator[Request]:
        arr_rng = random.Random(self.seed)
        mix_rng = random.Random(f"mix-{self.seed}")
        rids = itertools.count(self.rid_base) if self.rid_base is not None \
            else None
        single = self.profiles[0] if len(self.profiles) == 1 else None
        for t in self.arrivals.times(self.duration_s, arr_rng):
            p = single if single is not None else mix_rng.choices(
                self.profiles, weights=self._weights, k=1)[0]
            size = p.size.sample(mix_rng)
            # slo_p95_s doubles as the request's completion deadline —
            # what deadline_aware routing scores branches against
            deadline = (t + p.slo_p95_s if p.slo_p95_s is not None
                        else None)
            if rids is None:
                yield Request(fn=p.fn, arrival_t=t, size=size,
                              deadline_t=deadline)
            else:
                yield Request(fn=p.fn, arrival_t=t, size=size,
                              rid=next(rids), deadline_t=deadline)

    def generate(self) -> List[Request]:
        return list(self.requests())

    def submit_to(self, sim) -> int:
        """Feed every request into a Simulator; returns the count."""
        n = 0
        for req in self.requests():
            sim.submit(req)
            n += 1
        return n

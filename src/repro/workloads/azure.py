"""Azure Functions public-trace ingestion → replayable arrival streams.

The Azure Functions 2019 trace (Shahrad et al., ATC'20 — the dataset
both SeBS and the FaaS-benchmarking literature replay) ships per-function
*invocation counts per minute*: CSV rows of

    HashOwner,HashApp,HashFunction,Trigger,1,2,...,1440

with one integer column per minute of the day. This module converts that
format into the testbed's exact-IAT replay substrate
(:class:`~repro.workloads.arrivals.TraceArrivals`):

- :func:`load_azure_trace` — parse the CSV into per-function minute
  vectors (comment/blank tolerant, header optional).
- :func:`azure_trace_iats` — deterministically expand one function's
  minute counts into inter-arrival times: ``n`` invocations in a minute
  are spread evenly across its 60 seconds (the maximum-entropy placement
  given only a count — and deterministic, so the same CSV always yields
  the same stream). ``time_scale`` compresses wall time (0.01 ⇒ a full
  traced day replays in ~14.4 min of virtual time).
- :func:`azure_trace_arrivals` — the one-call converter to a
  ``TraceArrivals`` process, wired into the ``trace_replay`` scenario
  via ``build_scenario("trace_replay", path=..., fmt="azure")``.

Determinism contract: no RNG anywhere — the expansion is a pure function
of the CSV bytes, so replay is byte-identical across runs and machines.
"""
from __future__ import annotations

import csv
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.workloads.arrivals import TraceArrivals

#: seconds per trace bin (the Azure trace bins by minute)
BIN_S = 60.0


@dataclass(frozen=True)
class AzureTraceRow:
    """One function's day of traffic: identity hashes + minute counts."""

    owner: str
    app: str
    func: str
    trigger: str
    counts: tuple                      # invocations per minute bin

    @property
    def total(self) -> int:
        return sum(self.counts)

    def key(self) -> str:
        """Short stable id (func-hash prefix) for selection/reporting."""
        return self.func[:8]


def load_azure_trace(path: str) -> List[AzureTraceRow]:
    """Parse an Azure-format invocations CSV into trace rows.

    Tolerates the official header row (detected by non-numeric minute
    columns), ``#`` comment lines, and blank lines. Raises ValueError on
    rows with no minute columns — silently dropping malformed traffic
    would skew every replay built on the file."""
    rows: List[AzureTraceRow] = []
    with open(path, newline="") as fh:
        for lineno, rec in enumerate(csv.reader(fh), start=1):
            if not rec or rec[0].lstrip().startswith("#"):
                continue
            if rec[0].strip().lower() == "hashowner":
                continue                   # the official header row (its
                                           # minute columns are "1","2",…
                                           # — numeric, so detect by name)
            if len(rec) < 5:
                raise ValueError(
                    f"{path}:{lineno}: expected HashOwner,HashApp,"
                    f"HashFunction,Trigger,<minute counts...>, got {rec!r}")
            head, mins = rec[:4], rec[4:]
            try:
                counts = tuple(int(c or 0) for c in mins)
            except ValueError:
                raise ValueError(
                    f"{path}:{lineno}: non-integer minute count in {rec!r}")
            rows.append(AzureTraceRow(owner=head[0], app=head[1],
                                      func=head[2], trigger=head[3],
                                      counts=counts))
    if not rows:
        raise ValueError(f"{path}: no trace rows found")
    return rows


def select_function(rows: List[AzureTraceRow],
                    function: Optional[str] = None) -> AzureTraceRow:
    """Pick one function's row: by func-hash prefix when ``function`` is
    given, else the busiest function (ties broken by hash for
    determinism)."""
    if function is not None:
        matches = [r for r in rows if r.func.startswith(function)]
        if not matches:
            raise KeyError(f"no function hash starts with {function!r} "
                           f"(have: {sorted(r.key() for r in rows)})")
        if len(matches) > 1:
            raise KeyError(f"function prefix {function!r} is ambiguous: "
                           f"{sorted(r.key() for r in matches)}")
        return matches[0]
    return max(rows, key=lambda r: (r.total, r.func))


def minute_counts_to_iats(counts, *, time_scale: float = 1.0,
                          bin_s: float = BIN_S) -> List[float]:
    """Expand per-minute counts into deterministic inter-arrival times.

    A minute holding ``n`` invocations places them at the centres of
    ``n`` equal slices of the (scaled) minute — even spacing is the
    maximum-entropy reconstruction given only a count, and keeps the
    instantaneous rate inside every bin equal to the traced rate."""
    if time_scale <= 0:
        raise ValueError(f"time_scale must be positive, got {time_scale}")
    width = bin_s * time_scale
    times: List[float] = []
    for b, n in enumerate(counts):
        if n <= 0:
            continue
        start = b * width
        slot = width / n
        for i in range(n):
            times.append(start + (i + 0.5) * slot)
    iats: List[float] = []
    prev = 0.0
    for t in times:
        iats.append(t - prev)
        prev = t
    return iats


def _trace_counts(path: str, function: Optional[str],
                  aggregate: bool) -> List[int]:
    rows = load_azure_trace(path)
    if aggregate:
        n_bins = max(len(r.counts) for r in rows)
        counts = [0] * n_bins
        for r in rows:
            for b, n in enumerate(r.counts):
                counts[b] += n
        return counts
    return list(select_function(rows, function).counts)


def azure_trace_iats(path: str, *, function: Optional[str] = None,
                     time_scale: float = 1.0,
                     aggregate: bool = False) -> List[float]:
    """CSV → IAT list for one function (or ``aggregate=True``: the whole
    file's traffic summed per minute — the app-level load shape)."""
    return minute_counts_to_iats(_trace_counts(path, function, aggregate),
                                 time_scale=time_scale)


def azure_trace_arrivals(path: str, *, function: Optional[str] = None,
                         time_scale: float = 1.0, aggregate: bool = False,
                         loop: bool = False) -> TraceArrivals:
    """One-call converter: Azure CSV → exact-replay arrival process.

    ``loop=True`` tiles whole traced *days*: the cycle period is the
    full bin horizon (``n_bins × 60 s × time_scale``), so the idle tail
    after the day's last invocation is preserved and the looped rate
    equals the traced rate (a prefix-only tiling would replay sparse
    functions at a multiple of their real load)."""
    counts = _trace_counts(path, function, aggregate)
    return TraceArrivals(
        iats=minute_counts_to_iats(counts, time_scale=time_scale),
        loop=loop,
        period_s=len(counts) * BIN_S * time_scale)


#: trigger type → (priority class, p95 SLO seconds) heuristic for
#: per-row profiles: user-facing triggers get interactive latency
#: objectives, pipeline/background triggers run as batch with loose or
#: no objectives. Unknown triggers fall back to interactive/1.0 s.
TRIGGER_CLASSES: Dict[str, tuple] = {
    "http": ("interactive", 0.5),
    "event": ("interactive", 1.0),
    "queue": ("batch", 5.0),
    "storage": ("batch", 5.0),
    "timer": ("batch", None),
    "orchestration": ("batch", None),
    "others": ("batch", None),
}


def azure_trace_streams(path: str, *, time_scale: float = 1.0,
                        loop: bool = False,
                        duration_s: Optional[float] = None,
                        min_total: int = 1,
                        max_functions: Optional[int] = None,
                        rid_stride: Optional[int] = None,
                        seed: int = 1):
    """CSV → one per-row tenant stream each: a list of single-profile
    ``MixedWorkload``s, so one trace file yields a multi-function mix.

    Each trace row becomes its own workload — exact-IAT replay of that
    function's minute counts (:func:`azure_trace_arrivals` semantics),
    a :class:`~repro.workloads.workload.FunctionProfile` named by the
    row's stable ``key()`` with ``weight=row.total`` and a
    trigger-derived priority class / p95 SLO (:data:`TRIGGER_CLASSES`),
    and a disjoint request-id range: stream ``i`` gets
    ``rid_base = i * rid_stride`` (stride defaults to the next power of
    ten above the busiest row's total, so ids also *read* as
    stream-tagged). Disjoint per-stream rid ranges and per-stream seeds
    are exactly the shape ``repro.parallel.partition_streams`` buckets
    across partitions — every stream is self-contained, so any subset
    replays identically.

    Rows are ordered busiest-first (ties by function hash) for
    determinism; ``min_total`` drops all-idle rows and
    ``max_functions`` truncates to the heaviest N. ``duration_s``
    overrides each stream's generation horizon (defaults to the traced
    day — required when ``loop=True``, which otherwise never ends).
    """
    from repro.workloads.workload import FunctionProfile, MixedWorkload
    rows = [r for r in load_azure_trace(path) if r.total >= min_total]
    rows.sort(key=lambda r: (-r.total, r.func))
    if max_functions is not None:
        rows = rows[:max_functions]
    if not rows:
        raise ValueError(f"{path}: no rows with >= {min_total} invocations")
    if rid_stride is None:
        rid_stride = 10
        while rid_stride <= max(r.total for r in rows):
            rid_stride *= 10
    streams = []
    for i, row in enumerate(rows):
        pri, slo = TRIGGER_CLASSES.get(row.trigger.strip().lower(),
                                       ("interactive", 1.0))
        n_bins = len(row.counts)
        horizon = n_bins * BIN_S * time_scale
        arrivals = TraceArrivals(
            iats=minute_counts_to_iats(row.counts, time_scale=time_scale),
            loop=loop, period_s=horizon)
        profile = FunctionProfile(
            fn=row.key(), weight=float(row.total),
            slo_p95_s=None if slo is None else slo * time_scale,
            priority=pri)
        streams.append(MixedWorkload(
            arrivals, [profile],
            duration_s=horizon if duration_s is None else duration_s,
            seed=seed + i, rid_base=i * rid_stride))
    return streams


def trace_functions(path: str) -> Dict[str, int]:
    """func-hash prefix → total invocations (exploration helper)."""
    return {r.key(): r.total for r in load_azure_trace(path)}

"""Workflow layer: stage DAGs composed on top of the workload substrate.

Real platform traffic is composed *workflows*, not single invocations —
chains, parallel fan-out/fan-in with map-reduce joins, and conditional
branches are exactly where routing and cold-start policy diverge
(paper §I; "Characterizing FaaS Workflows on Public Clouds"). This
module adds that layer without touching the data path:

- :class:`StageSpec` / :class:`WorkflowSpec` — a workflow is a DAG of
  named stages, each invoking one function with its own prompt-size
  distribution, fan-out width, and path weight. Specs validate
  structure up front (stages declared after their dependencies, so
  declaration order is a topological order) and precompute the
  longest-weight-path decomposition: per-stage critical-path membership
  and the fraction of the end-to-end SLO each stage's subpath earns.
- :class:`WorkflowWorkload` — binds an arrival process to a spec:
  each arrival is one workflow *instance* with deterministically
  pre-drawn task sizes, conditional-branch activations, and a
  contiguous rid block (same seed ⇒ byte-identical streams, the same
  contract as :class:`~repro.workloads.workload.MixedWorkload`).
- :class:`WorkflowEngine` — the runtime: stage completions arrive as
  ``workflow_done`` simulator events, joins count down deterministically,
  and successor stages are submitted as ordinary :class:`Request`\\ s
  stamped with DAG context (``wf``/``stage``/``wf_critical``/
  ``wf_affinity`` + the stage's share of the workflow deadline) that
  ``workflow_aware`` routing and the control plane's stage-lookahead
  prewarm consume.

End-to-end outcomes land in ``sim.workflow_results`` (one
:class:`WorkflowResult` per instance); ``summarize_workflows`` reduces
them to the latency summary ``bench_workflows`` reports.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.types import Request
from repro.workloads.arrivals import ArrivalProcess
from repro.workloads.workload import FunctionProfile, SizeDist


@dataclass(frozen=True)
class StageSpec:
    """One stage of a workflow DAG: which function it invokes, which
    stages must complete first, and how wide it fans out.

    ``fanout`` submits that many parallel tasks for the stage; the stage
    completes (and its successors' joins count down) only when *all* of
    them finish — the map side of a map-reduce. ``prob`` makes the stage
    a conditional branch: each workflow instance draws once at
    generation time, and an inactive stage completes instantly without
    running (its successors still join through it). ``weight`` is the
    stage's relative duration on the DAG's longest-path decomposition —
    it prices the critical path and the stage's share of the end-to-end
    SLO, it does not change service times."""

    name: str
    fn: str
    deps: Tuple[str, ...] = ()
    fanout: int = 1
    size: SizeDist = field(default_factory=lambda: SizeDist.const(16))
    weight: float = 1.0
    prob: float = 1.0
    memory_mb: Optional[int] = None


@dataclass
class WorkflowSpec:
    """A validated stage DAG plus its precomputed critical-path math.

    Stages must be declared after every stage they depend on, so the
    declaration order *is* a topological order (and cycles are
    impossible by construction). ``slo_s`` is the end-to-end workflow
    latency objective; it is decomposed over the longest weighted path:
    a stage whose longest root-path carries fraction ``f`` of the total
    critical-path weight gets the absolute deadline ``arrival + slo_s *
    f`` stamped onto its tasks.
    """

    name: str
    stages: Tuple[StageSpec, ...]
    slo_s: Optional[float] = None

    def __post_init__(self):
        self.stages = tuple(self.stages)
        if not self.stages:
            raise ValueError("a workflow needs at least one stage")
        if self.slo_s is not None and self.slo_s <= 0:
            raise ValueError("slo_s must be positive")
        self._by_name: Dict[str, StageSpec] = {}
        for s in self.stages:
            if s.name in self._by_name:
                raise ValueError(f"duplicate stage name {s.name!r}")
            if s.fanout < 1:
                raise ValueError(f"stage {s.name!r}: fanout must be >= 1")
            if s.weight <= 0:
                raise ValueError(f"stage {s.name!r}: weight must be > 0")
            if not 0.0 < s.prob <= 1.0:
                raise ValueError(f"stage {s.name!r}: prob must be in (0, 1]")
            for d in s.deps:
                if d not in self._by_name:
                    raise ValueError(
                        f"stage {s.name!r} depends on {d!r}, which is not "
                        f"declared before it (declare stages after their "
                        f"dependencies; cycles are impossible that way)")
            self._by_name[s.name] = s
        self.roots: Tuple[str, ...] = tuple(
            s.name for s in self.stages if not s.deps)
        self.successors: Dict[str, Tuple[str, ...]] = {
            s.name: tuple(t.name for t in self.stages if s.name in t.deps)
            for s in self.stages}
        # longest-weight-path decomposition: l_in includes the stage
        # itself (fan-out tasks run in parallel, so a stage counts its
        # weight once regardless of width)
        l_in: Dict[str, float] = {}
        for s in self.stages:
            l_in[s.name] = s.weight + max(
                (l_in[d] for d in s.deps), default=0.0)
        l_out: Dict[str, float] = {}
        for s in reversed(self.stages):
            l_out[s.name] = s.weight + max(
                (l_out[c] for c in self.successors[s.name]), default=0.0)
        self.path_weight: float = max(l_in.values())
        # a stage is critical iff some longest path runs through it
        self.critical: frozenset = frozenset(
            n for n, li in l_in.items()
            if li + l_out[n] - self._by_name[n].weight
            >= self.path_weight - 1e-9)
        self.deadline_frac: Dict[str, float] = {
            n: li / self.path_weight for n, li in l_in.items()}
        # contiguous per-instance rid block: stage tasks get
        # rid = instance_base + rid_offset[stage] + task_index
        self.rid_offset: Dict[str, int] = {}
        off = 0
        for s in self.stages:
            self.rid_offset[s.name] = off
            off += s.fanout
        self.tasks_per_instance: int = off

    def stage(self, name: str) -> StageSpec:
        return self._by_name[name]


@dataclass(frozen=True)
class WorkflowResult:
    """End-to-end outcome of one workflow instance."""

    wf: int
    name: str
    ok: bool
    arrival_t: float
    finish_t: float
    tasks: int                  # stage tasks that actually ran
    error: str = ""

    @property
    def latency(self) -> float:
        return self.finish_t - self.arrival_t


@dataclass
class WorkflowInstance:
    """Runtime state of one in-flight workflow (engine-internal)."""

    wf: int                              # instance id == its rid block base
    spec: WorkflowSpec
    arrival_t: float
    sizes: Dict[str, Tuple[int, ...]]    # stage -> per-task prompt sizes
    active: frozenset                    # conditional stages drawn "taken"

    def __post_init__(self):
        self.deps_left = {s.name: len(s.deps) for s in self.spec.stages}
        self.tasks_left = {s.name: s.fanout for s in self.spec.stages}
        self.remaining = len(self.spec.stages)
        self.tasks_run = 0
        self.failed = False
        self.finished = False


class WorkflowEngine:
    """Deterministic DAG runtime bound to one simulator.

    Stage *task* completions are reported synchronously by the
    simulator's result-recording paths (:meth:`on_stage_done`); when a
    stage's last task lands, the engine pushes a ``workflow_done``
    event, and the handler (:meth:`fire`) advances the DAG — so stage
    triggering rides the event engine's deterministic ordering, never a
    side channel. Inactive conditional stages complete instantly in the
    same event (their successors still join through them). A failed
    task fails the whole instance (remaining in-flight siblings still
    drain through the simulator but no successors are submitted).
    """

    def __init__(self, *, prewarm_next: bool = True):
        self.instances: Dict[int, WorkflowInstance] = {}
        #: byte-stable stage event log (submit/skip/done/fail lines in
        #: event order) — the determinism projection the property
        #: driver compares across same-seed runs
        self.stage_log: List[str] = []
        #: prewarm stage N+1's function while stage N runs
        self.prewarm_next = prewarm_next
        self.tasks_submitted = 0
        self.prewarms = 0

    def add_instance(self, inst: WorkflowInstance) -> None:
        if inst.wf in self.instances:
            raise ValueError(f"duplicate workflow instance id {inst.wf} "
                             f"(overlapping rid_base blocks?)")
        self.instances[inst.wf] = inst

    # ------------------------------------------------- simulator callbacks
    def fire(self, sim, payload) -> None:
        """Handle one ``workflow_done`` event: ``(wf, None, None)`` is
        the instance's arrival (submit its root stages); ``(wf, stage,
        worker)`` is a stage completion (advance the joins)."""
        wf, stage, worker = payload
        inst = self.instances.get(wf)
        if inst is None or inst.finished:
            return
        if stage is None:
            for s in inst.spec.roots:
                self._trigger(sim, inst, s, None)
            return
        self._complete_stage(sim, inst, stage, worker)

    def on_stage_done(self, sim, req: Request, ok: bool,
                      worker: Optional[str]) -> None:
        """One stage *task* finished (called from the simulator's result
        paths, once per primary — hedge races are already resolved)."""
        inst = self.instances.get(req.wf)
        if inst is None or inst.finished:
            return
        if not ok:
            inst.failed = True
            inst.finished = True
            self._log(sim, inst.wf, req.stage, "fail")
            sim.workflow_results.append(WorkflowResult(
                wf=inst.wf, name=inst.spec.name, ok=False,
                arrival_t=inst.arrival_t, finish_t=sim.now,
                tasks=inst.tasks_run,
                error=f"stage {req.stage} failed"))
            return
        inst.tasks_run += 1
        inst.tasks_left[req.stage] -= 1
        if inst.tasks_left[req.stage] == 0:
            # the join is full: trigger successors through the event
            # engine (deterministic ordering with everything else at now)
            sim._push(sim.now, "workflow_done", (inst.wf, req.stage, worker))

    # ------------------------------------------------------- DAG mechanics
    def _complete_stage(self, sim, inst: WorkflowInstance, stage: str,
                        worker: Optional[str]) -> None:
        self._log(sim, inst.wf, stage, "done")
        inst.remaining -= 1
        for succ in inst.spec.successors[stage]:
            inst.deps_left[succ] -= 1
            if inst.deps_left[succ] == 0:
                self._trigger(sim, inst, succ, worker)
        if inst.remaining == 0 and not inst.finished:
            inst.finished = True
            sim.workflow_results.append(WorkflowResult(
                wf=inst.wf, name=inst.spec.name, ok=True,
                arrival_t=inst.arrival_t, finish_t=sim.now,
                tasks=inst.tasks_run))

    def _trigger(self, sim, inst: WorkflowInstance, stage: str,
                 worker: Optional[str]) -> None:
        spec = inst.spec.stage(stage)
        if stage not in inst.active:
            # conditional branch not taken: completes instantly, in the
            # same event, so successors join through it deterministically
            self._log(sim, inst.wf, stage, "skip")
            self._complete_stage(sim, inst, stage, worker)
            return
        self._log(sim, inst.wf, stage, "submit")
        affinity = (None if worker is None
                    else (worker, sim._leaf_of.get(worker)))
        deadline = (inst.arrival_t
                    + inst.spec.slo_s * inst.spec.deadline_frac[stage]
                    if inst.spec.slo_s is not None else None)
        rid0 = inst.wf + inst.spec.rid_offset[stage]
        critical = stage in inst.spec.critical
        sizes = inst.sizes[stage]
        for k in range(spec.fanout):
            sim.submit(Request(
                fn=spec.fn, arrival_t=sim.now, size=sizes[k], rid=rid0 + k,
                deadline_t=deadline, wf=inst.wf, stage=stage, wf_task=k,
                wf_critical=critical, wf_affinity=affinity))
        self.tasks_submitted += spec.fanout
        if self.prewarm_next:
            # stage-lookahead: warm the successors' functions while this
            # stage runs, so the DAG edge doesn't eat a cold start
            for succ in inst.spec.successors[stage]:
                if succ in inst.active:
                    if sim.control.workflow_prewarm(
                            inst.spec.stage(succ).fn) is not None:
                        self.prewarms += 1

    def _log(self, sim, wf: int, stage: Optional[str], event: str) -> None:
        self.stage_log.append(
            f"t={sim.now:.6f} wf={wf} stage={stage} {event}")


class WorkflowWorkload:
    """Workflow instances over an arrival process (the composed-traffic
    sibling of :class:`~repro.workloads.workload.MixedWorkload`).

    Determinism contract: two RNG streams are derived from one seed —
    arrival times vs. per-instance draws (task sizes, conditional-branch
    activations) — and request ids come in contiguous per-instance
    blocks from ``rid_base``, so the same seed yields byte-identical
    request, result, and stage-log streams. ``submit_to`` counts as one
    unit per *instance* (stage tasks are generated by the engine as the
    DAG advances, not up front).
    """

    def __init__(self, arrivals: ArrivalProcess, spec: WorkflowSpec, *,
                 duration_s: Optional[float], seed: int = 1,
                 rid_base: int = 0, prewarm_next: bool = True):
        self.arrivals = arrivals
        self.spec = spec
        self.duration_s = duration_s
        self.seed = seed
        self.rid_base = rid_base
        self.prewarm_next = prewarm_next
        self.faults = None              # chaos scenarios may attach a plan

    def fns(self) -> List[str]:
        out: List[str] = []
        for s in self.spec.stages:
            if s.fn not in out:
                out.append(s.fn)
        return out

    @property
    def profiles(self) -> List[FunctionProfile]:
        """Per-function profiles derived from the stages (first-declared
        size shape; the *tightest* per-stage deadline share when several
        stages invoke one function) — what ``install_demo_configs`` and
        SLO-aware autoscaling consume."""
        seen: Dict[str, FunctionProfile] = {}
        for s in self.spec.stages:
            share = (self.spec.slo_s * self.spec.deadline_frac[s.name]
                     if self.spec.slo_s is not None else None)
            p = seen.get(s.fn)
            if p is None:
                seen[s.fn] = FunctionProfile(s.fn, size=s.size,
                                             slo_p95_s=share,
                                             memory_mb=s.memory_mb)
            elif share is not None and (p.slo_p95_s is None
                                        or share < p.slo_p95_s):
                seen[s.fn] = FunctionProfile(s.fn, size=p.size,
                                             slo_p95_s=share,
                                             memory_mb=p.memory_mb)
        return list(seen.values())

    def slo_targets(self) -> dict:
        return {p.fn: p.slo_p95_s for p in self.profiles
                if p.slo_p95_s is not None}

    def instances(self) -> Iterator[WorkflowInstance]:
        arr_rng = random.Random(self.seed)
        mix_rng = random.Random(f"wfmix-{self.seed}")
        spec = self.spec
        for i, t in enumerate(self.arrivals.times(self.duration_s, arr_rng)):
            # fixed-shape draws per instance (every stage, active or
            # not) keep the mix stream alignment independent of the
            # activation outcomes
            sizes = {s.name: tuple(s.size.sample(mix_rng)
                                   for _ in range(s.fanout))
                     for s in spec.stages}
            active = frozenset(
                s.name for s in spec.stages
                if s.prob >= 1.0 or mix_rng.random() < s.prob)
            yield WorkflowInstance(
                wf=self.rid_base + i * spec.tasks_per_instance, spec=spec,
                arrival_t=t, sizes=sizes, active=active)

    def generate(self) -> List[WorkflowInstance]:
        return list(self.instances())

    def submit_to(self, sim) -> int:
        """Register every instance with the simulator's workflow engine
        (attaching one if needed) and schedule its arrival; returns the
        instance count."""
        engine = sim.workflows
        if engine is None:
            engine = sim.attach_workflows(
                WorkflowEngine(prewarm_next=self.prewarm_next))
        n = 0
        for inst in self.instances():
            engine.add_instance(inst)
            sim._push(inst.arrival_t, "workflow_done", (inst.wf, None, None))
            n += 1
        return n


def summarize_workflows(results: List[WorkflowResult]) -> dict:
    """End-to-end workflow latency summary (nearest-rank percentiles —
    byte-stable, no numpy dependency on this path)."""
    import math
    out: dict = {"n": len(results)}
    if not results:
        return out
    ok = [r for r in results if r.ok]
    out["ok"] = len(ok)
    out["fail_rate"] = 1.0 - len(ok) / len(results)
    out["tasks"] = sum(r.tasks for r in results)
    if ok:
        lats = sorted(r.latency for r in ok)

        def pct(p: float) -> float:
            return lats[max(0, math.ceil(p / 100.0 * len(lats)) - 1)]

        out.update(p50=pct(50.0), p95=pct(95.0), p99=pct(99.0),
                   mean=sum(lats) / len(lats))
    return out

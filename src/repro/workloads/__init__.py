"""Workload scenario subsystem: arrival processes, multi-function mixes,
and a named-scenario registry. See ROADMAP.md ("Workload scenarios") for
the extension guide."""
from repro.workloads.arrivals import (ARRIVALS, ArrivalProcess,
                                      BurstyArrivals, DiurnalArrivals,
                                      PoissonArrivals, TraceArrivals,
                                      get_arrival, iats_from_times,
                                      read_trace, register_arrival,
                                      write_trace)
from repro.workloads.azure import (azure_trace_arrivals, azure_trace_iats,
                                   azure_trace_streams, load_azure_trace,
                                   trace_functions)
from repro.workloads.scenarios import (SCENARIOS, build_scenario,
                                       install_demo_configs, list_scenarios,
                                       register_scenario)
from repro.workloads.workflows import (StageSpec, WorkflowEngine,
                                       WorkflowResult, WorkflowSpec,
                                       WorkflowWorkload,
                                       summarize_workflows)
from repro.workloads.workload import (FunctionProfile, MixedWorkload,
                                      RequestBatch, SizeDist)

__all__ = [
    "ARRIVALS", "ArrivalProcess", "PoissonArrivals", "BurstyArrivals",
    "DiurnalArrivals", "TraceArrivals", "get_arrival", "register_arrival",
    "read_trace", "write_trace", "iats_from_times",
    "load_azure_trace", "azure_trace_arrivals", "azure_trace_iats",
    "azure_trace_streams", "trace_functions",
    "SCENARIOS", "build_scenario", "list_scenarios", "register_scenario",
    "install_demo_configs",
    "FunctionProfile", "MixedWorkload", "RequestBatch", "SizeDist",
    "StageSpec", "WorkflowSpec", "WorkflowWorkload", "WorkflowEngine",
    "WorkflowResult", "summarize_workflows",
]

"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407]
bf16 optimizer state + 16-way grad accumulation so the train_4k cell fits
16 GB/chip on the 256-chip pod (see EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral_large_123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1000000.0,
    tie_embeddings=False,
    opt_state_dtype="bfloat16",
    grad_accum=16,
))

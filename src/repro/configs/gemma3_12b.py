"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local(sliding-window 1024):global attention interleave, head_dim=256,
128k context. [hf:google/gemma-3-*-pt]
Sliding-window makes it sub-quadratic => the long_500k cell RUNS for this arch.
Large vocab => chunked cross-entropy.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3_12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1000000.0,       # global layers (locals use 10k; see models.attention)
    sliding_window=1024,
    swa_local=5,
    swa_period=6,
    tie_embeddings=True,
    grad_accum=8,
    logits_chunk=1024,
))

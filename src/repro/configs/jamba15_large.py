"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887]

Layer pattern: within each 8-layer period, layers 0-6 are Mamba blocks and
layer 7 is attention (1 attn : 7 mamba). MoE replaces the dense MLP on every
2nd layer (16 experts, top-2, expert_ff = d_ff). Hybrid => long_500k RUNS
(mamba state is O(1); the 9 attention layers are decode-linear).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="jamba15_large",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=24576, every=2, sharding="ep"),
    mamba=MambaConfig(d_inner=16384, d_state=16, d_conv=4, dt_rank=512),
    tie_embeddings=False,
    opt_state_dtype="bfloat16",
    fsdp_pod=True,
    grad_accum=16,
    logits_chunk=1024,
))

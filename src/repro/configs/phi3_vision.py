"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.

phi3-mini backbone + CLIP frontend. [hf:microsoft/Phi-3-vision-128k-instruct]
The CLIP image tower is a STUB per assignment: ``input_specs`` provides
precomputed patch embeddings (B, 576, d_model) that the model scatters over
reserved image-token positions at the head of the sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3_vision",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    tie_embeddings=False,
    frontend="patches",
    num_patches=576,
    grad_accum=4,
))

"""Configuration system for HyperFaaS-JAX.

Every assigned architecture is described by a :class:`ModelConfig`; the four
assigned input shapes by :class:`ShapeConfig`.  Configs are plain frozen
dataclasses so they hash, compare, and serialize cleanly (the config store in
``repro.core`` persists them as JSON).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, replace
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

BLOCK_ATTN = "attn"
BLOCK_MAMBA = "mamba"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                     # per-expert hidden dim
    capacity_factor: float = 1.25      # Switch-style capacity
    every: int = 1                     # MoE layer every `every` layers (jamba: 2)
    router_dtype: str = "float32"
    # "ep": shard experts over model axis; "tp": shard expert_ff over model axis.
    sharding: str = "ep"


@dataclass(frozen=True)
class MambaConfig:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0                   # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact assigned values live in configs/<id>.py)."""

    name: str
    family: str                        # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                     # query heads (0 for attention-free)
    num_kv_heads: int                  # GQA kv heads
    d_ff: int                          # dense MLP hidden (0 if none / pure MoE)
    vocab_size: int
    head_dim: int = 128
    # --- architecture flavour flags -------------------------------------
    causal: bool = True                # False => encoder-only (hubert)
    gated_mlp: bool = True             # SwiGLU vs plain GELU MLP (hubert: False)
    qk_norm: bool = False              # qwen3-style per-head RMSNorm on q/k
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    # sliding-window pattern: window size and period "L:G" — every `swa_period`
    # layers, the first `swa_local` are local.  (gemma3: 5 local : 1 global)
    sliding_window: int = 0
    swa_local: int = 0
    swa_period: int = 1
    # hybrid interleave (jamba): attention every `attn_every` layers (index
    # attn_every-1 within each period); 1 => all attention.
    attn_every: int = 1
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # --- modality frontends (stubs per assignment) ----------------------
    frontend: str = "none"             # none | frames (audio) | patches (vlm)
    num_patches: int = 0               # vlm: patch embeddings per example
    # --- numerics / training ------------------------------------------
    dtype: str = "bfloat16"            # activations/params compute dtype
    norm_eps: float = 1e-6
    # optimizer-state dtype: f32 default; big archs use bf16 to fit HBM
    opt_state_dtype: str = "float32"
    optimizer: str = "adamw"           # adafactor for the largest archs
    fsdp_pod: bool = False             # FSDP weights/opt over (pod,data) too
    remat: bool = True
    # microbatches for grad accumulation at the assigned train shape
    grad_accum: int = 1
    logits_chunk: int = 0              # chunked CE loss (0 = off)

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def block_kind(self, layer_idx: int) -> str:
        """attn or mamba for layer `layer_idx` (jamba interleave)."""
        if self.attention_free:
            return BLOCK_MAMBA
        if self.mamba is None:
            return BLOCK_ATTN
        # attention sits at the LAST slot of each `attn_every` period.
        return BLOCK_ATTN if layer_idx % self.attn_every == self.attn_every - 1 else BLOCK_MAMBA

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.every == self.moe.every - 1

    def is_local_attn(self, layer_idx: int) -> bool:
        """Sliding-window vs global attention for this layer (gemma3 5:1)."""
        if self.sliding_window <= 0:
            return False
        return (layer_idx % self.swa_period) < self.swa_local

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the as-built model."""
        c, d = self, self.d_model
        if c.frontend == "frames":
            n = c.vocab_size * d                   # output head only (no tok embed)
        else:
            n = c.vocab_size * d                   # embedding
            if not c.tie_embeddings:
                n += c.vocab_size * d
        if c.frontend == "patches":
            n += d * d                             # patch projector
        for i in range(c.num_layers):
            kind = c.block_kind(i)
            if kind == BLOCK_ATTN:
                n += d * c.q_dim + c.q_dim * d     # wq, wo
                n += 2 * d * c.kv_dim              # wk, wv
                if c.qk_norm:
                    n += 2 * c.head_dim
                n += d                             # pre-attn norm
            else:
                m = c.mamba
                n += d * 2 * m.d_inner             # in_proj (x and z)
                n += m.d_conv * m.d_inner          # conv1d
                n += m.d_inner * (m.dt_rank + 2 * m.d_state)   # x_proj
                n += m.dt_rank * m.d_inner + m.d_inner         # dt_proj + bias
                n += m.d_inner * m.d_state + m.d_inner         # A_log, D
                n += m.d_inner * d                 # out_proj
                n += d                             # pre norm
            # MLP / MoE
            if c.is_moe_layer(i):
                e = c.moe
                n += d * e.num_experts             # router
                n += e.num_experts * 3 * d * e.expert_ff
            elif c.d_ff > 0:
                n += (3 if c.gated_mlp else 2) * d * c.d_ff
            n += d                                 # pre-mlp norm
        n += d                                     # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        c, e, d = self, self.moe, self.d_model
        moe_layers = sum(1 for i in range(c.num_layers) if c.is_moe_layer(i))
        dense_total = c.param_count() - moe_layers * (e.num_experts * 3 * d * e.expert_ff)
        return dense_total + moe_layers * e.top_k * 3 * d * e.expert_ff

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ModelConfig":
        raw = json.loads(s)
        if raw.get("moe"):
            raw["moe"] = MoEConfig(**raw["moe"])
        if raw.get("mamba"):
            raw["mamba"] = MambaConfig(**raw["mamba"])
        return ModelConfig(**raw)


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for every LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # train | prefill | decode | long_decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "long_decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig) -> dict:
    """Which assigned shapes run for this arch; value = None (runs) or skip reason."""
    out = {}
    for s in SHAPES.values():
        reason = None
        if not cfg.causal and s.mode in ("decode", "long_decode"):
            reason = "encoder-only: no decode step"
        elif s.mode == "long_decode" and not _subquadratic(cfg):
            reason = "pure full-attention arch: long_500k needs sub-quadratic attention"
        out[s.name] = reason
    return out


def _subquadratic(cfg: ModelConfig) -> bool:
    return cfg.attention_free or cfg.mamba is not None or cfg.sliding_window > 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    _load_all()
    return sorted(_REGISTRY)


_ASSIGNED = [
    "hubert_xlarge", "deepseek_coder_33b", "mistral_large_123b", "gemma3_12b",
    "qwen3_32b", "moonshot_v1_16b", "grok1_314b", "jamba15_large",
    "falcon_mamba_7b", "phi3_vision",
]


def assigned_archs() -> Sequence[str]:
    return list(_ASSIGNED)


def _load_all() -> None:
    import importlib
    for mod in _ASSIGNED + ["hyperfaas_demo"]:
        importlib.import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 128, seq: int = 0) -> ModelConfig:
    """Shrink a config to smoke-test size while keeping the family shape.

    Preserves: family, interleave patterns, GQA ratio, qk_norm, gating, MoE
    top-k routing (few experts), mamba block structure, frontend kind.
    """
    head_dim = 16
    if cfg.attention_free:
        heads = kv = 0
    else:
        heads = max(4, min(8, cfg.num_heads))
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kv = max(1, heads // ratio)
        heads = kv * ratio
        d_model = max(d_model, heads * head_dim // 2)
    # keep periods intact: round layer count up to cover one full period
    period = 1
    if cfg.mamba is not None and not cfg.attention_free:
        period = max(period, cfg.attn_every)
    if cfg.moe is not None:
        period = max(period, cfg.moe.every)
    if cfg.sliding_window > 0:
        period = max(period, cfg.swa_period)
    layers = max(layers, period)
    moe = None
    if cfg.moe is not None:
        moe = replace(cfg.moe, num_experts=min(8, cfg.moe.num_experts),
                      top_k=min(2, cfg.moe.top_k), expert_ff=d_model * 2)
    mamba = None
    if cfg.mamba is not None:
        mamba = MambaConfig(d_inner=2 * d_model, d_state=8, d_conv=4,
                            dt_rank=max(4, d_model // 16))
    return replace(
        cfg,
        num_layers=layers, d_model=d_model, num_heads=heads, num_kv_heads=kv,
        head_dim=head_dim if heads else cfg.head_dim,
        d_ff=(d_model * 4 if cfg.d_ff else 0), vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        moe=moe, mamba=mamba, num_patches=min(cfg.num_patches, 4),
        grad_accum=1, logits_chunk=0,
    )

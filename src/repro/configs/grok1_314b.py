"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768(expert)
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1]

8 experts do not divide the 16-way model axis => experts are TP-sharded on the
expert_ff dim (32768/16 = 2048/shard) instead of expert-parallel (DESIGN.md §5).
bf16 optimizer state so the train cell fits 16 GB/chip at 314B params.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="grok1_314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=32768, sharding="tp"),
    tie_embeddings=True,
    opt_state_dtype="bfloat16",
    fsdp_pod=True,
    optimizer="adafactor",   # factored 2nd moment: m+v bf16 would be 4.9 GiB/chip
    grad_accum=16,
    logits_chunk=1024,
))

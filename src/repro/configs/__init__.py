from repro.configs.base import (
    ModelConfig, MoEConfig, MambaConfig, ShapeConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    applicable_shapes, assigned_archs, get_config, list_configs, reduced, register,
)

__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "ShapeConfig",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "applicable_shapes", "assigned_archs", "get_config", "list_configs",
    "reduced", "register",
]

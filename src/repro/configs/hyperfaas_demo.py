"""Demo "function images" for the HyperFaaS platform experiments.

These are the paper's analogue of user-supplied functions: small, real models
that workers can actually execute on the CPU device in this container. They are
NOT part of the assigned-architecture matrix; they drive the serving engine,
the concurrency study (RQ-A) and the emulation pipeline (RQ-B).
"""
from repro.configs.base import ModelConfig, register

# ~8M-param LM: the default "user function" for serving experiments.
TINY_LM = register(ModelConfig(
    name="tiny_lm",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab_size=4096,
    tie_embeddings=True,
))

# ~35M-param LM: a "heavier function" so the worker-model sees two cost classes.
SMALL_LM = register(ModelConfig(
    name="small_lm",
    family="dense",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=8192,
    tie_embeddings=True,
))

# ~110M-param LM for examples/train_small.py (the "train ~100M model" driver).
TRAIN_100M = register(ModelConfig(
    name="train_100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32768,
    tie_embeddings=True,
))

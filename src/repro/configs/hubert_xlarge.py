"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504.

Encoder-only (same transformer arch as wav2vec2-XL). [arXiv:2106.07447]
The CNN audio frontend is a STUB per assignment: ``input_specs`` provides
precomputed frame embeddings (B, S, d_model). Non-gated GELU MLP (w2v2-style).
vocab=504 k-means target classes for masked prediction.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert_xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,              # encoder-only: no decode shapes
    gated_mlp=False,           # plain GELU MLP
    tie_embeddings=False,      # input is frames; output head is its own matrix
    frontend="frames",
    grad_accum=4,
))

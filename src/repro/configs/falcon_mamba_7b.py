"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free, vocab=65024,
ssm_state=16 (mamba1 arch). [arXiv:2410.05355]

Pure Mamba-1: d_inner = 2*d_model = 8192, conv4, dt_rank = d_model/16 = 256.
Attention-free => long_500k RUNS; the decode "cache" is (conv window, SSM
state), O(1) in sequence length.
"""
from repro.configs.base import MambaConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    mamba=MambaConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    tie_embeddings=True,
    grad_accum=16,   # mamba backward temporaries are f32 [B,S,DI,N]-shaped
    logits_chunk=1024,
))

"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (MHA kv=16) d_ff=1408(expert),
vocab=163840, MoE 64 experts top-6. [hf:moonshotai/Moonlight-16B-A3B]

Spec taken verbatim from the assignment (48L; the hf checkpoint uses 27L — the
assignment is authoritative, as-built total ~=26.9B / active ~=3.4B + embeddings).
All layers are MoE (d_ff field is the per-expert hidden). 64 experts over the
16-way model axis => expert parallelism (4 experts / shard).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="moonshot_v1_16b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # pure-MoE MLP stack
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, expert_ff=1408, sharding="ep"),
    rope_theta=50000.0,
    tie_embeddings=True,
    grad_accum=8,
    logits_chunk=1024,
))

"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.

qk_norm (per-head RMSNorm on q and k), GQA. [hf:Qwen/Qwen3-*]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3_32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    grad_accum=8,
    logits_chunk=1024,
))

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Materialized-scores attention. q [B,S,H,hd]; k,v [B,S,KV,hd]."""
    from repro.models.attention import attend_plain
    return attend_plain(q, k, v, causal=causal, window=window)


def decode_attention_ref(q, k_cache, v_cache, positions, *, ring=False):
    from repro.models.attention import attend_decode
    return attend_decode(q, k_cache, v_cache, positions, ring=ring, impl="ref")


def mamba_scan_ref(dt, x, B, C, A, D):
    """Sequential recurrence in f64-ish f32. Shapes as kernel wrapper."""
    Bt, S, DI = x.shape
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    bx = (dt * x).astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, :, None, :]

    def step(h, inp):
        at, bxt, ct = inp
        h = at * h + bxt
        return h, jnp.sum(h * ct[:, None, :], axis=-1)

    h0 = jnp.zeros((Bt, DI, A.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1),
                                    C.astype(jnp.float32).swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D.astype(jnp.float32)
    return y.astype(x.dtype)


def grouped_matmul_ref(x, w, block_to_expert, block_t):
    """Row-block i uses expert block_to_expert[i]."""
    T, D = x.shape
    nt = T // block_t
    xb = x.reshape(nt, block_t, D)
    wb = w[block_to_expert]                        # [nt, D, F]
    y = jnp.einsum("ntd,ndf->ntf", xb, wb)
    return y.reshape(T, -1).astype(x.dtype)

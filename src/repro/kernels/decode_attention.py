"""Pallas TPU decode attention: one query token vs a (ring) KV cache.

Memory-bound kernel: the whole cache streams HBM->VMEM once. Grid is
(batch, kv_head, kv_block) with the kv_block dim sequential so the online
softmax state for the G grouped q-heads sits in VMEM scratch. All G q-heads
of one kv head are processed together as a [G, hd] tile (q_per_kv x 128 is
the MXU-friendly packing for GQA decode).

The wrapper passes per-batch ``valid_len`` (= min(pos+1, W); ring buffers are
fully valid once wrapped) so ring and linear caches share one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bw, nw):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = vl_ref[0]
    first = j * bw

    @pl.when(first < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                   # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bw, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        slot = first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot < valid_len, s, NEG_INF)           # [G, bw]
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(j == nw - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     positions: jax.Array, *, ring: bool = False,
                     block_w: int = 512, interpret: bool = True) -> jax.Array:
    """q: [B, H, hd]; caches: [B, W, KV, hd]; positions: [B] -> [B, H, hd]."""
    B, W, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    bw = min(block_w, W)
    while W % bw:
        bw //= 2
    nw = W // bw
    if ring:
        valid_len = jnp.where(positions >= W, W, positions + 1).astype(jnp.int32)
    else:
        valid_len = jnp.minimum(positions + 1, W).astype(jnp.int32)

    qt = q.reshape(B, KV, G, hd)
    kt = k_cache.transpose(0, 2, 1, 3)     # [B, KV, W, hd]
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5, bw=bw, nw=nw)
    from repro.kernels.flash_attention import _dim_semantics, _vmem

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nw),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bw, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bw, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[_vmem((G,), jnp.float32), _vmem((G,), jnp.float32),
                        _vmem((G, hd), jnp.float32)],
        compiler_params=_dim_semantics(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(valid_len, qt, kt, vt)
    return out.reshape(B, H, hd)

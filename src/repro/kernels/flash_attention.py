"""Pallas TPU flash attention (forward): causal / sliding-window / GQA.

TPU-native blocking: the grid is (batch, q_head, q_block, kv_block) with the
kv_block dimension marked "arbitrary" (sequential), so the online-softmax
state (m, l, acc) lives in VMEM scratch across kv steps of the same q tile.
MXU-aligned tiles: q/kv blocks default 128/512, head_dim is the lane dim.
GQA is handled in the k/v index_map (q head h reads kv head h // G).

Masked-out (i, j) tiles are skipped with pl.when — the causal lower triangle
and the sliding-window band cost zero MXU work, matching the exact-triangle
accounting of the jnp reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, bq, bk, nk, g):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile visibility under the mask (static per (i, j) would be nicer; pl.when
    # keeps the skipped tile free of MXU work)
    first_q = i * bq
    last_q = first_q + bq - 1
    first_k = j * bk
    last_k = first_k + bk - 1
    visible = jnp.bool_(True)
    if causal:
        visible &= first_k <= last_q
    if window and window > 0:
        visible &= last_k > first_q - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = first_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window and window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd] -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    nq, nk = S // bq, S // bk

    qt = q.transpose(0, 2, 1, 3)      # [B, H, S, hd]
    kt = k.transpose(0, 2, 1, 3)      # [B, KV, S, hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, g=g)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, hd), jnp.float32),
        ],
        compiler_params=_dim_semantics(("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _dim_semantics(sem):
    from jax.experimental.pallas import tpu as pltpu
    try:
        return pltpu.CompilerParams(dimension_semantics=sem)
    except TypeError:
        return pltpu.TPUCompilerParams(dimension_semantics=sem)

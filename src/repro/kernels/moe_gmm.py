"""Pallas TPU grouped matmul for MoE expert compute (megablocks-style).

Tokens arrive sorted by expert with each group padded to the row-tile size
(the wrapper in ops.py builds this layout from the router output). A
scalar-prefetched ``block_to_expert`` map then lets the weight BlockSpec
index_map select the right expert's tile — so expert weights stream HBM->VMEM
once per used row block and dispatch costs no MXU FLOPs (vs the one-hot
einsum's T·E·C·D).

Grid: (row_block, ff_block, k_block) with k sequential accumulating in VMEM.
128x128x512 default tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(bmap_ref, x_ref, w_ref, y_ref, acc_scr, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _fin():
        y_ref[...] = acc_scr[...].astype(y_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, block_to_expert: jax.Array, *,
                   block_t: int = 128, block_f: int = 128, block_d: int = 512,
                   interpret: bool = True) -> jax.Array:
    """x: [T_pad, D] expert-sorted rows; w: [E, D, F];
    block_to_expert: [T_pad // block_t] int32 -> y [T_pad, F]."""
    T, D = x.shape
    E, _, F = w.shape
    bt = block_t
    while T % bt:
        bt //= 2
    bf = min(block_f, F)
    while F % bf:
        bf //= 2
    bd = min(block_d, D)
    while D % bd:
        bd //= 2
    nt, nf, nk = T // bt, F // bf, D // bd
    assert block_to_expert.shape == (nt,), (block_to_expert.shape, nt)

    from jax.experimental.pallas import tpu as pltpu
    from repro.kernels.flash_attention import _dim_semantics, _vmem

    kernel = functools.partial(_gmm_kernel, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nf, nk),
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j, k, bmap: (i, k)),
            pl.BlockSpec((1, bd, bf), lambda i, j, k, bmap: (bmap[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda i, j, k, bmap: (i, j)),
        scratch_shapes=[_vmem((bt, bf), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        compiler_params=_dim_semantics(("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_to_expert, x, w)

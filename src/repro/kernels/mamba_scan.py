"""Pallas TPU selective-scan (Mamba-1) kernel.

The ref path materializes a = exp(dt*A) and bx = dt*B*x as [B, S, DI, N] f32
tensors in HBM (16x the activation size at N=16) — the dominant memory-roofline
term for the SSM archs. This kernel fuses the whole recurrence: HBM traffic is
just the [B, S, DI]-sized dt/x/y plus [B, S, N] B/C — the SSM state h [bd, N]
never leaves VMEM.

Grid: (batch, d_inner blocks, seq chunks); the chunk dim is sequential with h
carried in VMEM scratch. Inside a chunk the recurrence is a fori_loop over
time steps operating on [bd, N] tiles (bd=256 lanes x N=16 sublanes fills the
VPU; the recurrence is elementwise, not MXU work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mamba_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr,
                  *, chunk, bd, n):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)                       # [bd, N]
    D = d_ref[...].astype(jnp.float32)                       # [bd]

    def step(t, h):
        dt = dt_ref[0, t].astype(jnp.float32)                # [bd]
        x = x_ref[0, t].astype(jnp.float32)                  # [bd]
        Bc = b_ref[0, t].astype(jnp.float32)                 # [N]
        Cc = c_ref[0, t].astype(jnp.float32)                 # [N]
        a = jnp.exp(dt[:, None] * A)                         # [bd, N]
        h = a * h + (dt * x)[:, None] * Bc[None, :]
        y = jnp.sum(h * Cc[None, :], axis=1) + D * x         # [bd]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def mamba_scan(dt: jax.Array, x: jax.Array, B: jax.Array, C: jax.Array,
               A: jax.Array, D: jax.Array, *, chunk: int = 256,
               block_d: int = 256, interpret: bool = True) -> jax.Array:
    """dt, x: [Bt, S, DI]; B, C: [Bt, S, N]; A: [DI, N]; D: [DI] -> y [Bt, S, DI].

    y_t = C_t · h_t + D*x_t  with  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.
    """
    Bt, S, DI = x.shape
    N = A.shape[1]
    ch = min(chunk, S)
    while S % ch:
        ch //= 2
    bd = min(block_d, DI)
    while DI % bd:
        bd //= 2
    nc, nd = S // ch, DI // bd

    kernel = functools.partial(_mamba_kernel, chunk=ch, bd=bd, n=N)
    from repro.kernels.flash_attention import _dim_semantics, _vmem

    return pl.pallas_call(
        kernel,
        grid=(Bt, nd, nc),
        in_specs=[
            pl.BlockSpec((1, ch, bd), lambda b, d, j: (b, j, d)),   # dt
            pl.BlockSpec((1, ch, bd), lambda b, d, j: (b, j, d)),   # x
            pl.BlockSpec((1, ch, N), lambda b, d, j: (b, j, 0)),    # B
            pl.BlockSpec((1, ch, N), lambda b, d, j: (b, j, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, j: (d, 0)),          # A
            pl.BlockSpec((bd,), lambda b, d, j: (d,)),              # D
        ],
        out_specs=pl.BlockSpec((1, ch, bd), lambda b, d, j: (b, j, d)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, DI), x.dtype),
        scratch_shapes=[_vmem((bd, N), jnp.float32)],
        compiler_params=_dim_semantics(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, B, C, A, D)

"""Jit'd dispatch wrappers around the Pallas kernels.

``impl`` selection:
* "pallas"    — pl.pallas_call, interpret=False (real TPU)
* "interpret" — pl.pallas_call, interpret=True (CPU validation; default here)
* "ref"       — pure-jnp oracle (what the dry-run lowers)

The model layer calls these when constructed with attn_impl="pallas".
"""
from __future__ import annotations


import jax

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba_scan import mamba_scan as _mamba_pallas
from repro.kernels.moe_gmm import grouped_matmul as _gmm_pallas

_INTERPRET_DEFAULT = True   # this container is CPU-only; TPU deploys set False


def flash_attention(q, k, v, *, causal=True, window=0, impl="interpret"):
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=(impl != "pallas") or _INTERPRET_DEFAULT)


def decode_attention(q, k_cache, v_cache, positions, *, ring=False,
                     impl="interpret"):
    if impl == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, positions, ring=ring)
    return _decode_pallas(q, k_cache, v_cache, positions, ring=ring,
                          interpret=(impl != "pallas") or _INTERPRET_DEFAULT)


def mamba_scan(dt, x, B, C, A, D, *, impl="interpret"):
    if impl == "ref":
        return _ref.mamba_scan_ref(dt, x, B, C, A, D)
    return _mamba_pallas(dt, x, B, C, A, D,
                         interpret=(impl != "pallas") or _INTERPRET_DEFAULT)


def grouped_matmul(x, w, block_to_expert, *, block_t=128, impl="interpret"):
    if impl == "ref":
        return _ref.grouped_matmul_ref(x, w, block_to_expert, block_t)
    return _gmm_pallas(x, w, block_to_expert, block_t=block_t,
                       interpret=(impl != "pallas") or _INTERPRET_DEFAULT)


def moe_expert_ffn(xin, wg, wi, wo, block_to_expert=None, *, impl="interpret"):
    """SwiGLU expert FFN over expert-sorted rows via three grouped matmuls.

    xin: [T_pad, D] expert-sorted; wg/wi: [E, D, F]; wo: [E, F, D].
    """
    if block_to_expert is None:
        raise ValueError("block_to_expert required")
    g = grouped_matmul(xin, wg, block_to_expert, impl=impl)
    u = grouped_matmul(xin, wi, block_to_expert, impl=impl)
    h = jax.nn.silu(g) * u
    return grouped_matmul(h, wo, block_to_expert, impl=impl)

"""The load-balancer tree (paper Fig. 1 / §II).

Every node exposes the same ``route(request) -> leaf worker id`` interface;
inner nodes pick a child, leaves pick a worker. "To scale the system up by a
factor of two, simply replicate the existing servers and add a load balancer
in front to randomly assign requests to one branch" — that recipe is
:func:`replicate`.

Policies are pluggable and split exactly along the paper's stateless/stateful
axis: stateless ones look only at the request; stateful ones read worker-state
snapshots (queue depth, in-flight, warm instances) through a ``StateView`` —
which the testbed can delay/stale-ify to study the cost of state freshness.
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Sequence

from repro.core.types import Request


@dataclass
class WorkerState:
    """Snapshot a stateful LB reads (possibly stale)."""
    worker: str
    queue_len: int = 0
    inflight: int = 0
    capacity: int = 1                  # slots across warm instances
    warm_fns: frozenset = frozenset()
    healthy: bool = True
    # per-function depth: queued requests and immediately-usable warm
    # slots by fn — what lets least-loaded routing become warm-aware
    fn_queue: Mapping[str, int] = field(default_factory=dict)
    fn_free_slots: Mapping[str, int] = field(default_factory=dict)
    # free replica memory on the worker (inf when uncapped) — the
    # placement layer's routing-visible signal
    mem_free_mb: float = float("inf")

    @property
    def load(self) -> float:
        return (self.queue_len + self.inflight) / max(self.capacity, 1)

    def fn_depth(self, fn: str) -> int:
        """Queued requests for one function on this worker."""
        return self.fn_queue.get(fn, 0)


class StateView:
    """Worker-state source with optional staleness (simulated gRPC lag)."""

    #: fallback per-request service estimate before any completion is seen
    DEFAULT_SERVICE_S = 0.05

    def __init__(self, staleness_s: float = 0.0):
        self.staleness_s = staleness_s
        self._now: Dict[str, WorkerState] = {}
        self._stale: Dict[str, WorkerState] = {}
        self._stale_t: float = -1e30
        # windowed per-fn service-time source (repro.autoscale.metrics.
        # ServiceEstimator); attached by the simulator only when the tree
        # routes with a deadline-aware policy
        self.estimator = None
        self.cold_start_est_s = 0.25   # routing-visible cold-start guess
        # per-function replica footprint (filled by the simulator from the
        # config store): lets deadline routing see that a cold start on a
        # memory-full worker cannot even begin
        self.fn_memory: Dict[str, float] = {}
        # placer-aware pricing of memory-blocked cold starts: when set
        # (Simulator(mem_eta="placer")), deadline routing asks the
        # placement layer for a graded unblock ETA instead of the flat
        # MEM_BLOCKED_PENALTY_S surcharge. None (default) keeps the
        # flat penalty — standalone router use and every pre-existing
        # golden are byte-identical.
        self.mem_eta = None
        # fallback for names with no stored row — the simulator resolves
        # *inner* LB-node names to lazily-aggregated subtree states, so
        # deadline routing stays informed above the leaf level in trees
        # deeper than two levels
        self.node_resolver = None

    def service_est(self, fn: str) -> float:
        """Expected per-request service time for one function (windowed
        observation when an estimator is attached, a flat prior before)."""
        if self.estimator is None:
            return self.DEFAULT_SERVICE_S
        return self.estimator.estimate(fn)

    def update(self, state: WorkerState, t: float = 0.0):
        self._now[state.worker] = state
        if t - self._stale_t >= self.staleness_s:
            self._stale = dict(self._now)
            self._stale_t = t

    def get(self, worker: str, t: float = 0.0) -> WorkerState:
        src = self._now if self.staleness_s == 0 else self._stale
        state = src.get(worker)
        if state is None and self.node_resolver is not None:
            state = self.node_resolver(worker, t)
        # build the empty default lazily: get() runs once per candidate
        # worker on every routing decision
        return state if state is not None else WorkerState(worker)


# ---------------------------------------------------------------------------
# Policies: (request, worker_ids, view, rng, t) -> worker_id
# ---------------------------------------------------------------------------

def random_policy(req, workers, view, rng, t):
    return workers[rng.randrange(len(workers))]


def round_robin_policy():
    state = {"i": 0}

    def policy(req, workers, view, rng, t):
        # post-increment so the very first call lands on workers[0]
        w = workers[state["i"] % len(workers)]
        state["i"] += 1
        return w
    return policy


def hash_policy(req, workers, view, rng, t):
    return workers[hash((req.fn, req.rid // 64)) % len(workers)]


def tenant_index(name: str, n: int) -> int:
    """Stable tenant → bucket assignment (crc32, not Python ``hash`` —
    which is salted per process and would break cross-process
    byte-identity). Shared by :func:`tenant_hash_policy` and the
    parallel partition planner (``repro.parallel``), so a serial tree
    whose root routes with ``tenant_hash`` sends every tenant to
    exactly the branch the partitioned run owns it in."""
    return zlib.crc32(name.encode()) % max(n, 1)


def tenant_hash_policy(req, workers, view, rng, t):
    """Pin each tenant (function) to one child, deterministically and
    with **no RNG and no state**: the exact "tenants don't share
    branches" shape under which partition-local gateway quota splitting
    is equivalent to a global front door (multi_tenant / noisy_neighbor
    / Azure-trace mixes). Consuming no RNG is what makes a serial run
    over the union tree byte-identical to the per-partition runs."""
    return workers[tenant_index(req.fn, len(workers))]


def least_loaded_policy(req, workers, view, rng, t):
    return min(workers, key=lambda w: (view.get(w, t).load, rng.random()))


def pow2_policy(req, workers, view, rng, t):
    """Power of two choices — near-optimal with O(1) state reads."""
    a, b = rng.sample(range(len(workers)), 2) if len(workers) > 1 else (0, 0)
    wa, wb = workers[a], workers[b]
    return wa if view.get(wa, t).load <= view.get(wb, t).load else wb


def warm_affinity_policy(req, workers, view, rng, t):
    """Prefer least-loaded worker holding a warm instance of req.fn."""
    warm = [w for w in workers if req.fn in view.get(w, t).warm_fns]
    pool = warm or workers
    return min(pool, key=lambda w: (view.get(w, t).load, rng.random()))


def warm_least_loaded_policy(req, workers, view, rng, t):
    """Least-loaded among workers with a *free warm slot* for req.fn.

    Sharper than ``warm_affinity`` (which only knows the binary warm set):
    a worker whose replicas of req.fn are all saturated counts as cold
    here, and ties break on the function's own queue depth before the
    worker-wide load — per-function state from the scheduling core."""
    states = [(w, view.get(w, t)) for w in workers]   # one lookup per worker
    warm = [ws for ws in states if ws[1].fn_free_slots.get(req.fn, 0) > 0]
    pool = warm or states
    return min(pool, key=lambda ws: (ws[1].fn_depth(req.fn), ws[1].load,
                                     rng.random()))[0]


# ETA surcharge for a cold start that cannot begin (no replica memory
# free on the worker): finite so a fully-blocked fleet still ranks
# deterministically by backlog, huge so any startable worker wins
MEM_BLOCKED_PENALTY_S = 1e6


def deadline_aware_policy(req, workers, view, rng, t):
    """Route to the branch most likely to meet the request's deadline.

    Predicted completion time on a worker combines warm-replica
    availability with the function's queued backlog priced at the
    windowed per-request service estimate (``view.service_est``, fed by
    ``repro.autoscale.metrics.ServiceEstimator``):

    - free warm slots: own service + backlog draining across those slots
    - warm but saturated: wait a full service turn per queued request
    - no warm replica: the same, plus one cold start

    A cold start on a worker without free replica memory for the
    function cannot even begin until something idles out there — those
    workers take a large ETA penalty instead of masquerading as lightly
    loaded (idle big-footprint replicas otherwise *attract* traffic
    they can never serve).

    The ETA is scored against the request's ``slo_p95_s``-derived
    absolute deadline: workers predicted to *meet* it beat workers
    predicted to blow it, then lower ETA wins, then lower worker-wide
    load. Requests with no deadline degrade to pure ETA routing."""
    svc = view.service_est(req.fn)
    need_mb = view.fn_memory.get(req.fn, 0.0)
    slack = (req.deadline_t - t if req.deadline_t is not None
             else float("inf"))
    scored = []
    for w in workers:
        ws = view.get(w, t)
        free = ws.fn_free_slots.get(req.fn, 0)
        depth = ws.fn_depth(req.fn)
        if free > 0:
            eta = svc * (1.0 + depth / free)
        else:
            eta = svc * (depth + 2.0)
            if req.fn not in ws.warm_fns:
                eta += view.cold_start_est_s
                if ws.mem_free_mb < need_mb:
                    # flat penalty by default; with a placer-aware hook
                    # attached, price the *wait until the deficit frees*
                    # instead — a nearly-free idle worker can then beat
                    # a startable-but-drowning one (carried ROADMAP
                    # follow-on, A/B'd in tests/test_placement.py)
                    if view.mem_eta is None:
                        eta += MEM_BLOCKED_PENALTY_S
                    else:
                        eta += view.mem_eta(need_mb, ws.mem_free_mb, svc,
                                            depth, ws.inflight)
        scored.append((eta > slack, eta, ws.load, rng.random(), w))
    return min(scored)[-1]


# workflow_aware knob: price of a cold start on the DAG's critical
# path, as a multiple of the plain cold-start estimate. A queueing
# delay on the critical path is inherited by every successor stage,
# while a cold start is paid once and buys a replica that serves the
# rest of the run — so the critical path buys capacity *eagerly*
# (multiplier < 1) instead of piling onto the warm hotspot. Measured
# on ml_pipeline/etl_fanout across seeds: 0.2 beats both the neutral
# price (1.0) and wait-for-warm over-pricing (4.0) on e2e p95.
WF_CRITICAL_COLD_MULT = 0.2


def workflow_aware_policy(req, workers, view, rng, t):
    """``deadline_aware`` with DAG context: critical-path-slack routing
    for workflow stage tasks.

    Same ETA model as :func:`deadline_aware_policy`, with three
    workflow-specific asymmetries read off the request's stamped DAG
    context (plain requests carry none of it and degrade to exactly
    the deadline score shape):

    - a stage on the workflow's *critical path* (``wf_critical``)
      prices cold starts at ``WF_CRITICAL_COLD_MULT``× (< 1): queueing
      delay there is inherited one-for-one by every successor stage,
      while a cold start is paid once — the critical path buys fresh
      capacity eagerly rather than stacking onto the warm hotspot;
    - the worker (and leaf branch) that served the triggering
      predecessor (``wf_affinity``) wins *ties*: at equal predicted
      ETA and load, chained stages co-locate onto the already-warm
      path instead of scattering by RNG tiebreak. Affinity never
      overrides a genuine ETA difference — a multiplicative discount
      was tried and herds chains onto stale-view hotspots;
    - fan-out siblings (``wf_task`` = k > 0) place by *waterfill*:
      a map wave's tasks route back-to-back at one timestamp on an
      identical frozen state snapshot (worker rows only refresh after
      the enqueue hop), so stage-blind min-ETA herds the entire
      fan-out onto one worker and the join waits on that self-made
      hotspot. Because every sibling sees the same snapshot and the
      same deterministic rule, sibling k re-derives where siblings
      0..k-1 landed, charges each landing a virtual queue slot, and
      takes the k-th greedy pick — spreading the wave exactly as a
      sequential scheduler with perfect information would.
    """
    svc = view.service_est(req.fn)
    need_mb = view.fn_memory.get(req.fn, 0.0)
    slack = (req.deadline_t - t if req.deadline_t is not None
             else float("inf"))
    cold_price = view.cold_start_est_s * (WF_CRITICAL_COLD_MULT
                                          if req.wf_critical else 1.0)
    aff = req.wf_affinity
    rows = []
    for w in workers:
        ws = view.get(w, t)
        rows.append((w, ws, ws.fn_free_slots.get(req.fn, 0),
                     ws.fn_depth(req.fn), req.fn in ws.warm_fns,
                     ws.mem_free_mb < need_mb, rng.random()))

    def eta_of(row, extra):
        _w, _ws, free, depth, warm, blocked, _r = row
        if free > 0 and depth + extra < free:
            return svc * (1.0 + (depth + extra) / free)
        eta = svc * (depth + extra + 2.0)
        if not warm:
            eta += cold_price
            if blocked:
                eta += MEM_BLOCKED_PENALTY_S
        return eta

    def key_of(row, extra):
        eta = eta_of(row, extra)
        near = 0 if (aff is not None and row[0] in aff) else 1
        return (eta > slack, eta, row[1].load, near, row[6])

    if req.wf_task:
        extra = dict.fromkeys((r[0] for r in rows), 0)
        pick = rows[0][0]
        for _ in range(req.wf_task + 1):
            pick = min(rows, key=lambda r: key_of(r, extra[r[0]]))[0]
            extra[pick] += 1
        return pick
    return min(rows, key=lambda r: key_of(r, 0))[0]


POLICIES: Dict[str, Callable] = {
    "random": lambda: random_policy,
    "round_robin": round_robin_policy,
    "hash": lambda: hash_policy,
    "tenant_hash": lambda: tenant_hash_policy,
    "least_loaded": lambda: least_loaded_policy,
    "pow2": lambda: pow2_policy,
    "warm_affinity": lambda: warm_affinity_policy,
    "warm_least_loaded": lambda: warm_least_loaded_policy,
    "deadline_aware": lambda: deadline_aware_policy,
    "workflow_aware": lambda: workflow_aware_policy,
}

STATELESS = {"random", "round_robin", "hash", "tenant_hash"}


# ---------------------------------------------------------------------------
# Tree
# ---------------------------------------------------------------------------

@dataclass
class LBNode:
    name: str
    policy_name: str
    children: List["LBNode"] = field(default_factory=list)
    workers: List[str] = field(default_factory=list)     # leaf only
    _policy: Callable = None

    def __post_init__(self):
        self._policy = POLICIES[self.policy_name]()
        self._child_names: List[str] = [c.name for c in self.children]
        self._child_idx: Dict[str, "LBNode"] = {c.name: c
                                                for c in self.children}

    @property
    def is_leaf(self) -> bool:
        return bool(self.workers)

    def route(self, req: Request, view: StateView, rng: random.Random,
              t: float = 0.0, _hops: int = 0) -> tuple:
        """Returns (worker_id, hops)."""
        if self.is_leaf:
            return self._policy(req, self.workers, view, rng, t), _hops + 1
        child = self._policy(req, self._child_names, view, rng, t)
        return self._child_idx[child].route(req, view, rng, t, _hops + 1)

    def all_workers(self) -> List[str]:
        if self.is_leaf:
            return list(self.workers)
        out = []
        for c in self.children:
            out.extend(c.all_workers())
        return out

    # ---- elasticity (paper's scaling recipe + live add/remove) ----------
    def add_branch(self, node: "LBNode"):
        assert not self.is_leaf, "cannot add a branch to a leaf"
        self.children.append(node)
        self._child_names.append(node.name)
        self._child_idx[node.name] = node

    def remove_branch(self, name: str):
        self.children = [c for c in self.children if c.name != name]
        self._child_names = [c.name for c in self.children]
        self._child_idx = {c.name: c for c in self.children}


def build_leaf(name: str, workers: Sequence[str],
               policy: str = "least_loaded") -> LBNode:
    return LBNode(name, policy, workers=list(workers))


def build_tree(n_workers: int, fanout: int = 8, *,
               leaf_policy: str = "least_loaded",
               inner_policy: str = "random",
               prefix: str = "lb") -> LBNode:
    """Balanced tree: leaves hold ≤ fanout workers; inner nodes ≤ fanout kids."""
    leaves = []
    for i in range(0, n_workers, fanout):
        ws = [f"w{j}" for j in range(i, min(i + fanout, n_workers))]
        leaves.append(build_leaf(f"{prefix}-leaf{i // fanout}", ws, leaf_policy))
    level = 0
    nodes = leaves
    while len(nodes) > 1:
        level += 1
        nxt = []
        for i in range(0, len(nodes), fanout):
            group = nodes[i:i + fanout]
            nxt.append(LBNode(f"{prefix}-l{level}n{i // fanout}", inner_policy,
                              children=group))
        nodes = nxt
    root = nodes[0]
    if root.is_leaf:
        # always have an inner root LB so branches can be added/removed live
        root = LBNode(f"{prefix}-root", inner_policy, children=[root])
    return root


def replicate(tree: LBNode, times: int = 2, *,
              inner_policy: str = "random") -> LBNode:
    """The paper's scale-by-k recipe: clone the subtree k-1 times (with fresh
    worker ids) and put a stateless LB in front."""
    def clone(node: LBNode, tag: str) -> LBNode:
        if node.is_leaf:
            return LBNode(f"{node.name}-{tag}", node.policy_name,
                          workers=[f"{w}-{tag}" for w in node.workers])
        return LBNode(f"{node.name}-{tag}", node.policy_name,
                      children=[clone(c, tag) for c in node.children])
    branches = [tree] + [clone(tree, f"r{i}") for i in range(1, times)]
    return LBNode("lb-root", inner_policy, children=branches)

"""Seeded fault injection: the testbed's chaos layer.

At millions of users something is always failing, and both SeBS (Copik
et al.) and the FaaS Benchmarking Framework treat reliability behavior
as a benchmark dimension next to performance — yet a simulator-grown
fleet is perfect unless failure is a first-class scenario input. This
module makes it one: a :class:`FaultInjector` drives four fault kinds
through the ordinary event engine (kind ``"fault"``), so every injected
failure interleaves deterministically with arrivals, finishes, and
control-loop ticks:

- **worker crash/restart** — per-worker exponential MTTF/MTTR chains
  (crash → restore → next crash), reusing the simulator's
  ``_on_fail`` / ``_on_recover`` semantics (queued work fails, in-flight
  completions die with the worker, the routing view sees it).
- **zone-correlated outages** — whole failure domains (the ``zone``
  attribute workers gain from ``Simulator(zones=...)``) go down and
  recover together, either on a Poisson schedule (``zone_outage_rate``)
  or at scripted instants (``scheduled``) for reproducible experiments.
- **latency stragglers** — transient multiplicative slowdowns layered
  on the existing per-worker ``slowdown`` factor, restored to the prior
  value when the episode ends (so stacked/configured stragglers keep
  their base factor).
- **lost completions** — with probability ``lost_finish_p`` a service's
  ``finish`` event is dropped; the slot stays busy (a zombie execution)
  until the function's ``timeout_s``, at which point the slot is freed
  and the request fails with ``error="lost completion"`` — the shape
  that retry budgets exist for.

Determinism contract: the injector draws from its *own* seeded RNG (the
simulator's routing/service streams are untouched), all of its events
flow through the engine's ``(t, seq)`` total order, and ``fault_log()``
is a plain event-ordered line list — same seed ⇒ byte-identical fault
log, results, and decision logs. With every knob off (the default
``FaultConfig()``), attaching an injector schedules nothing and draws
nothing: runs are byte-identical to a fault-free simulator (pinned by
``tests/test_faults.py`` against the PR 3–5 golden digests).

``"fault"`` is a *background* event kind (like ``autoscale_tick``):
pending faults never hold the run loop open, and the injector only
re-arms its stochastic processes while real work remains, so ``run()``
still terminates.

Overlap caveat: fault kinds compose freely but naively — a worker
restore scheduled before its zone's outage ends will heal it early.
Scenario authors who need strict containment should use one kind per
experiment (the built-in ``zone_outage`` / ``retry_storm`` scenarios
do).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class FaultConfig:
    """Declarative fault plan for one run. Everything defaults to *off*:
    a default-constructed config is the wired-but-disabled state the
    byte-identity gate pins."""

    seed: int = 0
    # worker crash/restart: exponential mean time to failure / repair,
    # one independent chain per worker. None disables crashes.
    worker_mttf_s: Optional[float] = None
    worker_mttr_s: float = 2.0
    # zone-correlated outages: Poisson rate (outages/s across the fleet)
    # and exponential outage duration. 0.0 disables random outages.
    zone_outage_rate: float = 0.0
    zone_mttr_s: float = 5.0
    # scripted outages: (at_s, zone, duration_s) triples, injected
    # exactly — the reproducible-experiment form the zone_outage
    # scenario uses.
    scheduled: Tuple[Tuple[float, str, float], ...] = ()
    # transient stragglers: Poisson episode rate, multiplicative factor,
    # fixed episode duration. 0.0 disables.
    straggler_rate: float = 0.0
    straggler_factor: float = 8.0
    straggler_duration_s: float = 2.0
    # per-service-completion drop probability (lost finish events)
    lost_finish_p: float = 0.0
    # injection window: no stochastic fault is *initiated* before
    # start_s or after horizon_s (recoveries still complete)
    start_s: float = 0.0
    horizon_s: Optional[float] = None

    @property
    def enabled(self) -> bool:
        return bool(self.worker_mttf_s is not None
                    or self.zone_outage_rate > 0.0
                    or self.straggler_rate > 0.0
                    or self.lost_finish_p > 0.0
                    or self.scheduled)


@dataclass
class FaultStats:
    """Run-wide injection counters (`FaultInjector.stats`)."""

    crashes: int = 0
    restores: int = 0
    zone_outages: int = 0
    zone_recoveries: int = 0
    stragglers: int = 0
    lost_completions: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class FaultInjector:
    """Schedules seeded faults through a simulator's event engine.

    Operates on the same duck-typed simulator surface as the worker
    runtime and control plane: ``now``, ``workers``, ``zone_workers``,
    ``engine.pending_real``, ``_push``, ``_on_fail`` / ``_on_recover``
    (the one crash/heal code path, so the failure semantics the
    bugfix suite pins apply to every injected fault), ``_record_fail``,
    and the runtime's slot accounting for lost completions.
    """

    def __init__(self, sim, config: Optional[FaultConfig] = None):
        self.sim = sim
        self.cfg = config or FaultConfig()
        # independent stream: fault draws never perturb routing/service
        # RNG, which is what keeps faults-off runs byte-identical
        self.rng = random.Random(f"faults-{self.cfg.seed}")
        self.records: List[str] = []
        self.stats = FaultStats()
        self._straggle_prior: dict = {}     # worker -> pre-episode slowdown

    # -------------------------------------------------------------- logging
    def _log(self, line: str) -> None:
        self.records.append(f"t={self.sim.now:.6f} {line}")

    def fault_log(self) -> str:
        """Byte-stable fault log: one line per injected event, in event
        order (same seed ⇒ identical)."""
        return "\n".join(self.records)

    # ------------------------------------------------------------ lifecycle
    def arm(self) -> None:
        """Schedule the first event of every enabled fault process.
        A disabled config arms nothing and draws nothing."""
        cfg = self.cfg
        if not cfg.enabled:
            return
        push = self.sim._push
        for at, zone, duration in cfg.scheduled:
            push(at, "fault", ("zone_down", (zone, duration)))
        if cfg.worker_mttf_s is not None:
            for w in sorted(self.sim.workers):
                push(cfg.start_s + self.rng.expovariate(1.0 / cfg.worker_mttf_s),
                     "fault", ("crash", w))
        if cfg.zone_outage_rate > 0.0:
            push(cfg.start_s + self.rng.expovariate(cfg.zone_outage_rate),
                 "fault", ("zone_outage", None))
        if cfg.straggler_rate > 0.0:
            push(cfg.start_s + self.rng.expovariate(cfg.straggler_rate),
                 "fault", ("straggle", None))

    def _within_horizon(self, t: float) -> bool:
        return self.cfg.horizon_s is None or t <= self.cfg.horizon_s

    def _rearm(self, t: float, payload) -> None:
        """Re-arm a stochastic process — only while real work remains
        (faults are background events: they must never keep ``run()``
        alive on their own) and inside the injection window."""
        if self.sim.engine.pending_real > 0 and self._within_horizon(t):
            self.sim._push(t, "fault", payload)

    # --------------------------------------------------------------- events
    def on_event(self, payload) -> None:
        kind, arg = payload
        getattr(self, "_ev_" + kind)(arg)

    def _ev_crash(self, worker: str) -> None:
        sim = self.sim
        if worker not in sim.workers:
            return                       # scaled away: chain ends
        self.stats.crashes += 1
        self._log(f"crash worker={worker}")
        sim._on_fail(worker)
        sim._push(sim.now + self.rng.expovariate(1.0 / self.cfg.worker_mttr_s),
                  "fault", ("restore", worker))

    def _ev_restore(self, worker: str) -> None:
        sim = self.sim
        if worker not in sim.workers:
            return
        self.stats.restores += 1
        self._log(f"restore worker={worker}")
        sim._on_recover(worker)
        self._rearm(sim.now + self.rng.expovariate(1.0 / self.cfg.worker_mttf_s),
                    ("crash", worker))

    def _ev_zone_outage(self, _arg) -> None:
        """Random zone outage: pick a zone, take it down for an
        exponential duration, re-arm the next outage."""
        sim = self.sim
        zones = sorted(sim.zone_workers)
        if zones:
            zone = self.rng.choice(zones)
            duration = self.rng.expovariate(1.0 / self.cfg.zone_mttr_s)
            self._ev_zone_down((zone, duration))
        self._rearm(sim.now + self.rng.expovariate(self.cfg.zone_outage_rate),
                    ("zone_outage", None))

    def _ev_zone_down(self, arg) -> None:
        zone, duration = arg
        sim = self.sim
        members = [w for w in sim.zone_workers.get(zone, ())
                   if w in sim.workers]
        self.stats.zone_outages += 1
        self._log(f"zone_down zone={zone} workers={len(members)} "
                  f"duration={duration:.3f}")
        for w in members:
            sim._on_fail(w)
        sim._push(sim.now + duration, "fault", ("zone_up", zone))

    def _ev_zone_up(self, zone: str) -> None:
        sim = self.sim
        self.stats.zone_recoveries += 1
        self._log(f"zone_up zone={zone}")
        for w in sim.zone_workers.get(zone, ()):
            if w in sim.workers:
                sim._on_recover(w)

    def _ev_straggle(self, _arg) -> None:
        sim = self.sim
        cfg = self.cfg
        names = sorted(sim.workers)
        # a worker already mid-episode is skipped (the draw still
        # happens, keeping the stream aligned): overlapping episodes
        # would collide in _straggle_prior and strand the factor forever
        if names:
            worker = self.rng.choice(names)
            w = sim.workers[worker]
            if worker not in self._straggle_prior:
                # layer on the existing per-worker slowdown; restore to
                # the *prior* value so configured base stragglers survive
                self._straggle_prior[worker] = w.slowdown
                w.slowdown *= cfg.straggler_factor
                self.stats.stragglers += 1
                self._log(f"straggle worker={worker} "
                          f"factor={cfg.straggler_factor}"
                          f" slowdown={w.slowdown:.2f}")
                sim._push(sim.now + cfg.straggler_duration_s, "fault",
                          ("unstraggle", worker))
        self._rearm(sim.now + self.rng.expovariate(cfg.straggler_rate),
                    ("straggle", None))

    def _ev_unstraggle(self, worker: str) -> None:
        sim = self.sim
        prior = self._straggle_prior.pop(worker, None)
        w = sim.workers.get(worker)
        if w is not None and prior is not None:
            w.slowdown = prior
            self._log(f"unstraggle worker={worker} slowdown={prior:.2f}")

    # ----------------------------------------------------- lost completions
    def drop_finish(self, req, w) -> bool:
        """Called by the worker runtime at service start: True ⇒ this
        service's ``finish`` event is lost. Draws RNG only when the
        fault is enabled, so other fault processes' streams don't shift
        with service volume."""
        p = self.cfg.lost_finish_p
        return p > 0.0 and self.rng.random() < p

    def lose_completion(self, w, inst, req, fn_cfg) -> None:
        """Schedule the delayed fallout of a dropped finish: the slot
        stays busy (zombie execution) until the function's timeout, then
        frees and the request fails as ``lost completion``."""
        sim = self.sim
        self._log(f"lost fn={req.fn} rid={req.rid} worker={w.name} "
                  f"inst={inst.iid}")
        self.stats.lost_completions += 1
        sim._push(sim.now + fn_cfg.timeout_s, "fault",
                  ("lost", (req, w.name, inst.iid)))

    def _ev_lost(self, arg) -> None:
        """The zombie execution hits its timeout: free the slot (the
        platform kills the instance's request) and fail the request —
        which the retry layer may then resurrect."""
        req, wname, iid = arg
        sim = self.sim
        w = sim.workers.get(wname) or sim._draining.get(wname)
        inst = w.iid_index.get(iid) if w is not None else None
        if inst is not None:
            w.note_busy(inst, -1)
            inst.last_used = sim.now
            sim._push(sim.now + sim.store.get(req.fn).idle_timeout_s,
                      "idle_check", (wname, iid))
            if wname in sim.workers:
                sim._dispatch(w)         # the freed slot may serve backlog
        sim._record_fail(req, "lost completion")

    # ------------------------------------------------------------ reporting
    def summary(self) -> dict:
        return self.stats.as_dict()

"""Front-door gateway: per-tenant rate limiting, admission control,
priority classes.

The related FaaS engine design puts a gateway (rate limiter / admission /
auth) *ahead* of the orchestrator; until ISSUE-9 this testbed routed
straight into the LB tree, so a single flooding tenant could queue the
whole platform to its timeout horizon. This layer is that missing stage:
every non-hedge arrival traverses it before the LB tree, and requests it
sheds fail immediately with a terminal error instead of queueing —

- ``"rate limited"``        the tenant's token bucket is empty
  (per-tenant quotas: ``burst`` tokens of headroom refilled at the
  sustained ``rate`` per second),
- ``"admission rejected"``  platform-wide outstanding work is at the
  concurrency ceiling (``max_inflight``), with priority classes: *batch*
  traffic is shed first, at ``batch_share * max_inflight``, so
  interactive tenants keep headroom under pressure.

Determinism contract: the gateway consumes **no RNG** and schedules no
events — a verdict is a pure function of the request stream and virtual
time, so same seed ⇒ byte-identical admit/shed sequences (pinned by
``tests/_prop_drivers.run_gateway_ops``), and a simulator with no
gateway attached is byte-identical to every pre-gateway golden.

Wiring (see ``repro.core.simulator``): the simulator consults
:meth:`Gateway.admit` in ``_on_arrival`` and on retries, releases the
concurrency slot when the request settles (ok, terminal failure, or
hedge resolution), and mirrors every verdict into the control plane's
gateway decision log. Recorded verdicts replay byte-for-byte through
``repro.autoscale.replay.ReplayGateway``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

#: priority classes, in shed order: batch is dropped first under pressure
PRIORITIES = ("interactive", "batch")

#: terminal error strings the gateway produces (deliberately NOT in
#: ``simulator.RETRYABLE_ERRORS``: a shed is a final platform answer,
#: retrying it would re-offer exactly the load the gateway just refused)
RATE_LIMITED = "rate limited"
ADMISSION_REJECTED = "admission rejected"


@dataclass(frozen=True)
class TenantQuota:
    """One tenant's rate contract: ``burst`` tokens of instantaneous
    headroom, refilled continuously at ``rate`` requests/second. A
    request spends one token; an empty bucket means ``rate limited``.
    ``priority`` is the tenant's default class when its requests carry
    none (``Request.priority`` — stamped by ``FunctionProfile.priority``
    — wins when set)."""

    rate: float
    burst: float = 1.0
    priority: str = "interactive"


@dataclass(frozen=True)
class GatewayConfig:
    """Declarative gateway shape a scenario can carry (``wl.gateway``,
    attached by ``Simulator.load`` exactly like a fault plan).

    ``quotas`` maps tenant (function) name → :class:`TenantQuota`;
    tenants without an entry fall back to ``default_quota`` (None ⇒
    unlimited rate). ``max_inflight`` turns on admission control (None
    ⇒ off): per-class concurrency ceilings — *interactive* outstanding
    admitted work is capped at ``max_inflight`` and *batch* at
    ``batch_share * max_inflight``, so a batch flood can never occupy
    the interactive class's headroom (total outstanding is bounded by
    their sum). Capping the batch class's *outstanding* work — not just
    its rate — is what bounds a flooding tenant's replica footprint:
    instances spawn to cover queued work, so ``batch_limit / conc``
    replicas is all a shed-early flood can ever pin. An
    ``enabled=False`` config attaches nothing — the run stays
    byte-identical to a gateway-free one."""

    quotas: Mapping[str, TenantQuota] = field(default_factory=dict)
    default_quota: Optional[TenantQuota] = None
    max_inflight: Optional[int] = None
    batch_share: float = 0.5
    enabled: bool = True


class TokenBucket:
    """Continuous-refill token bucket (the classic shaper, virtual-time
    edition). Never negative: ``take`` only spends when a full token is
    available. Floats keep partial refills exact across arbitrary
    inter-arrival gaps."""

    __slots__ = ("rate", "burst", "level", "last_t")

    def __init__(self, rate: float, burst: float, t0: float = 0.0):
        self.rate = float(rate)
        self.burst = float(burst)
        self.level = float(burst)      # start full: burst headroom at t0
        self.last_t = t0

    def refill(self, now: float) -> None:
        if now > self.last_t:
            self.level = min(self.burst,
                             self.level + (now - self.last_t) * self.rate)
            self.last_t = now

    def take(self, now: float) -> bool:
        self.refill(now)
        if self.level >= 1.0:
            self.level -= 1.0
            return True
        return False


class Gateway:
    """The admission stage itself: verdicts plus per-tenant accounting.

    :meth:`admit` returns ``None`` (admitted) or a terminal error string;
    an admitted request holds one concurrency slot until the simulator
    calls :meth:`release` when it settles. Custom admission policies
    subclass this and override :meth:`decide` — the bookkeeping
    (slot accounting, per-tenant counters, the replayable verdict
    record) stays in :meth:`admit`, so a policy override cannot desync
    the counters the autoscaler metrics read.
    """

    def __init__(self, config: Optional[GatewayConfig] = None, *,
                 record: bool = False):
        self.config = config or GatewayConfig()
        self.record = record             # keep structured verdicts for replay
        self.inflight = 0                # admitted, not yet settled
        self.inflight_by_pri = {p: 0 for p in PRIORITIES}
        self.admitted_total = 0
        self.shed_total = 0
        self.admitted_by_fn: Dict[str, int] = {}
        self.shed_by_fn: Dict[str, int] = {}
        self.shed_by_error: Dict[str, int] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._records: List[Tuple[int, str]] = []   # (rid, verdict) in order

    # ------------------------------------------------------------ policy
    def priority_of(self, req) -> str:
        """Effective class: the request's stamped priority, else its
        tenant quota's, else interactive."""
        pri = getattr(req, "priority", None)
        if pri is not None:
            return pri
        q = self._quota(req.fn)
        return q.priority if q is not None else "interactive"

    def _quota(self, fn: str) -> Optional[TenantQuota]:
        return self.config.quotas.get(fn, self.config.default_quota)

    def _bucket(self, fn: str, quota: TenantQuota,
                now: float) -> TokenBucket:
        b = self._buckets.get(fn)
        if b is None:
            b = self._buckets[fn] = TokenBucket(quota.rate, quota.burst,
                                                t0=now)
        return b

    def set_ceiling(self, max_inflight: Optional[int]) -> None:
        """Re-point the platform-wide concurrency ceiling (the config
        itself is frozen). This is the window-barrier knob the parallel
        runner turns (``repro.parallel``): a global ``max_inflight`` is
        split across partition-local gateways and re-apportioned from
        exchanged occupancy summaries at each barrier. Already-admitted
        work is never evicted — a lowered ceiling only gates *new*
        admits, exactly like a config push on a live front door."""
        from dataclasses import replace
        self.config = replace(self.config, max_inflight=max_inflight)

    def _limit(self, pri: str) -> Optional[int]:
        cap = self.config.max_inflight
        if cap is None:
            return None
        if pri == "batch":
            return int(cap * self.config.batch_share)
        return cap

    def decide(self, req, now: float, *, retry: bool) -> Optional[str]:
        """The admission policy: verdict for one consult (``None`` =
        admit). Override point for custom gateways; must stay a pure
        function of gateway state + the request (no RNG, no events) to
        keep the byte-identity contract."""
        pri = self.priority_of(req)
        limit = self._limit(pri)
        occupied = self.inflight_by_pri.get(pri, 0)
        if retry:
            # the request already holds its slot and already paid its
            # token at arrival; a retry is only refused when its class
            # is saturated (shed early instead of re-queueing into an
            # overloaded platform)
            if limit is not None and occupied > limit:
                return ADMISSION_REJECTED
            return None
        # concurrency admission first: an over-capacity reject must not
        # burn the tenant's rate tokens as well. Per-class occupancy:
        # batch saturating its own ceiling cannot consume interactive's
        if limit is not None and occupied >= limit:
            return ADMISSION_REJECTED
        quota = self._quota(req.fn)
        if quota is not None and not self._bucket(
                req.fn, quota, now).take(now):
            return RATE_LIMITED
        return None

    # ----------------------------------------------------------- wiring
    def admit(self, req, now: float, *, retry: bool = False) -> Optional[str]:
        """One front-door consult; returns None (admitted) or the
        terminal error. Arrival admits take a concurrency slot (released
        by :meth:`release` when the request settles); retry consults
        only re-check saturation."""
        verdict = self.decide(req, now, retry=retry)
        if verdict is None:
            if not retry:
                req._gw_admitted = True
                pri = req._gw_pri = self.priority_of(req)
                self.inflight += 1
                self.inflight_by_pri[pri] = \
                    self.inflight_by_pri.get(pri, 0) + 1
                self.admitted_total += 1
                self.admitted_by_fn[req.fn] = \
                    self.admitted_by_fn.get(req.fn, 0) + 1
        else:
            self.shed_total += 1
            self.shed_by_fn[req.fn] = self.shed_by_fn.get(req.fn, 0) + 1
            self.shed_by_error[verdict] = \
                self.shed_by_error.get(verdict, 0) + 1
        if self.record:
            self._records.append((req.rid, verdict or "admit"))
        return verdict

    def release(self, req, now: float) -> None:
        """A previously admitted request settled (result row recorded or
        terminal failure) — free its concurrency slot. Exactly-once:
        guarded by the admit stamp, so hedge losers and gateway-shed
        requests (never admitted) cannot double-free."""
        if getattr(req, "_gw_admitted", False):
            req._gw_admitted = False
            self.inflight -= 1
            pri = getattr(req, "_gw_pri", "interactive")
            self.inflight_by_pri[pri] = self.inflight_by_pri.get(pri, 1) - 1

    # -------------------------------------------------------- reporting
    def decision_records(self) -> List[dict]:
        """Structured verdict log (plain JSON types), in consult order —
        feed to ``repro.autoscale.replay.ReplayGateway`` (and the same
        ``save_decision_log``/``load_decision_log`` helpers)."""
        return [{"rid": rid, "verdict": v} for rid, v in self._records]

    def summary(self) -> dict:
        return {"admitted": self.admitted_total, "shed": self.shed_total,
                "inflight": self.inflight,
                "shed_by_fn": dict(sorted(self.shed_by_fn.items())),
                "shed_by_error": dict(sorted(self.shed_by_error.items()))}

"""Deterministic discrete-event simulator for the HyperFaaS testbed.

This is what lets the platform be *studied under massive load* (paper §I):
thousands of (emulated) workers, tens of millions of requests, virtual
time. The same router tree / config store / concurrency policies run here
as in the real in-process engine (``repro.serving.engine``); only the
worker execution is replaced by a service-time model — either a synthetic
profile or the learned RQ-B worker model (paper Fig. 2 step 3).

The simulator itself is thin *wiring* over three swappable layers:

- **Event engine** (``repro.core.events``) — the hot loop's priority
  queue behind a backend registry: ``single_heap`` (byte-identical
  reference) or ``sharded`` (calendar queue for ≥10M-request probes).
  Pick with ``Simulator(event_backend="sharded")``.
- **Worker runtime** (``repro.core.worker``) — per-node dispatch,
  admission, service start/completion, driven through the
  ``_dispatch`` / ``_maybe_start_instance`` / ``_start_service`` hook
  seam on this class (tests and custom platforms intercept there).
- **Control plane** (``repro.autoscale.control``) — autoscaler binding,
  per-function prewarm/reap, placer-ranked placement, and the
  byte-stable placement/routing decision logs; ``sim.prewarm`` etc.
  delegate to it.

Fault tolerance features exercised here: worker fail/recover injection,
per-worker straggler slowdowns, hedged requests (tail mitigation), queue
timeouts, and live add/remove of tree branches (elastic scaling).
"""
from __future__ import annotations

import itertools
import random
from typing import Dict, List, Optional

from repro.core.config_store import ConfigStore
from repro.core.events import EventEngine
from repro.core.router import LBNode, StateView, WorkerState
from repro.core.scheduling import Instance
from repro.core.types import FunctionConfig, Request, RequestResult, TelemetryRecord
from repro.core.worker import Worker, WorkerRuntime


# ---------------------------------------------------------------------------
# Service-time models
# ---------------------------------------------------------------------------

class SyntheticServiceModel:
    """Deterministic-plus-noise cost: t = t0 + a*(prompt+gen)*fn_cost, scaled by
    slot contention; lognormal jitter. The 'ground truth' worker for RQ-B."""

    def __init__(self, *, t0=0.004, per_token=0.0008, contention=0.30,
                 jitter=0.08, fail_rate=0.002, seed=0):
        self.t0, self.per_token, self.contention = t0, per_token, contention
        self.jitter, self.fail_rate = jitter, fail_rate
        self.rng = random.Random(seed)

    def sample(self, cfg: FunctionConfig, *, batch_size: int, queue_len: int,
               prompt: int, cold: bool, fn_cost: float):
        base = self.t0 + self.per_token * (prompt + cfg.gen_tokens) * fn_cost
        base *= 1.0 + self.contention * max(batch_size - 1, 0)
        base *= self.rng.lognormvariate(0.0, self.jitter)
        ok = self.rng.random() >= self.fail_rate
        return base, ok


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

# LB policies that read the per-function WorkerState layer; the simulator
# only pays for building those snapshots when the tree routes with one
_FN_STATE_POLICIES = frozenset({"warm_least_loaded", "deadline_aware",
                                "workflow_aware"})

# LB policies that additionally price backlogs with the windowed service
# estimator; the simulator only feeds it when the tree routes with one
_DEADLINE_POLICIES = frozenset({"deadline_aware", "workflow_aware"})


def _tree_uses_fn_state(node) -> bool:
    return (node.policy_name in _FN_STATE_POLICIES
            or any(_tree_uses_fn_state(c) for c in node.children))


def _tree_all_stateless(node) -> bool:
    """True when no policy anywhere in the tree reads WorkerState — the
    simulator can then skip state publication entirely (stateless
    platforms shouldn't pay for state freshness; paper §II)."""
    from repro.core.router import STATELESS
    return (node.policy_name in STATELESS
            and all(_tree_all_stateless(c) for c in node.children))


def _tree_uses_deadline(node) -> bool:
    return (node.policy_name in _DEADLINE_POLICIES
            or any(_tree_uses_deadline(c) for c in node.children))

# Re-exported for callers that patched/inspected the old private names
# (the classes themselves now live in ``repro.core.worker`` /
# ``repro.core.scheduling``; these aliases are the same objects, so
# monkeypatching through them still hits every code path).
_Instance = Instance
_Worker = Worker

# failure modes a retry budget may resurrect: infrastructure faults, not
# per-request outcomes ("queue timeout" is the request's own deadline —
# retrying it would double-spend an already-blown budget)
RETRYABLE_ERRORS = frozenset({"worker died", "lost completion",
                              "no healthy workers"})


class Simulator:
    #: every event kind the run loop dispatches (bound once per run())
    _EVENT_KINDS = ("arrival", "enqueue", "reroute", "retry", "maybe_hedge",
                    "fail", "recover", "fault", "poke", "finish",
                    "idle_check", "autoscale_tick", "workflow_done")

    def __init__(self, tree: LBNode, store: ConfigStore, service_model, *,
                 seed: int = 0, state_staleness_s: float = 0.0,
                 hedge_after_s: Optional[float] = None,
                 cold_start_default_s: float = 0.25,
                 network_hop_s: float = 0.0005,
                 worker_capacity_slots: int = 16,
                 worker_memory_mb: Optional[float] = None,
                 placer="first_fit",
                 mem_eta: str = "flat",
                 record_decisions: bool = False,
                 event_backend="single_heap",
                 collect_telemetry: bool = True,
                 zones=None,
                 retry_budget: int = 0,
                 retry_backoff_s: float = 0.05,
                 retry_backoff_cap_s: float = 1.0,
                 retry_storm_cap: int = 512,
                 faults=None,
                 gateway=None,
                 iid_scope: str = "sim"):
        self.tree = tree
        self.store = store
        self.model = service_model
        self.rng = random.Random(seed)
        self.view = StateView(state_staleness_s)
        self.hedge_after_s = hedge_after_s
        self.cold_default = cold_start_default_s
        self.hop_s = network_hop_s
        self.worker_capacity_slots = worker_capacity_slots
        # None => unlimited replica memory per worker: every placement
        # admission passes and behaviour is byte-identical to the
        # pre-placement simulator (pinned in tests/test_placement.py)
        self.worker_memory_mb = worker_memory_mb
        # "flat" keeps deadline_aware's classic ~infinite penalty on
        # memory-blocked cold starts (golden-pinned); "placer" prices
        # them with the placer's graded unblock ETA instead
        if mem_eta not in ("flat", "placer"):
            raise ValueError(f"mem_eta must be 'flat' or 'placer', "
                             f"got {mem_eta!r}")
        self.mem_eta_mode = mem_eta
        # control plane (autoscaler + placement + decision logs) — lazy
        # import so the core layer has no hard autoscale dependency
        from repro.autoscale.control import ControlPlane
        self.control = ControlPlane(self, placer=placer,
                                    record_decisions=record_decisions)
        self.runtime = WorkerRuntime(self)
        # telemetry rows cost real memory at 10M+ requests; lite probes
        # (benchmarks/run.py bench_event_backends) turn them off — the
        # flag changes no event ordering and consumes no RNG
        self.collect_telemetry = collect_telemetry
        self.workers: Dict[str, Worker] = {
            w: Worker(w, capacity_slots=worker_capacity_slots,
                      memory_mb=worker_memory_mb)
            for w in tree.all_workers()}
        self._worker_list = list(self.workers)   # cache (rebuilt on add/remove)
        self._healthy_count = len(self.workers)  # incremental: O(1) arrivals
        # a fully stateless tree never reads WorkerState rows: skip
        # publication (routing results are unaffected — nothing consumes
        # the rows — and no RNG or event ordering is touched)
        self._view_needed = not _tree_all_stateless(tree)
        self._fn_view_needed = _tree_uses_fn_state(tree)
        self._branch_view_needed = False  # aggregate leaf rows for inner LBs
        self._leaf_members: Dict[str, List[str]] = {}
        self._leaf_of: Dict[str, str] = {}
        self._node_workers: Dict[str, List[str]] = {}   # inner-node subtrees
        self._worker_ancestors: Dict[str, List[str]] = {}
        self._node_dirty: set = set()
        self._node_cache: Dict[str, WorkerState] = {}
        self._node_cache_stale_t = -1e30   # stale-snapshot rotation stamp
        # dirty-lazy leaf rows (staleness == 0 fast path): leaf -> time of
        # its last member event / aggregation version / cached row
        self._leaf_dirty_t: Dict[str, float] = {}
        self._leaf_ver: Dict[str, int] = {}
        self._leaf_cache: Dict[str, tuple] = {}
        # failure domains: zones=N assigns each leaf branch a zone
        # (z0..z{N-1}, round-robin in tree walk order, sticky across
        # topology changes); zones={leaf: zone} maps them explicitly.
        # Zones change no routing or service decision by themselves —
        # only spread_zones placement and zone faults read them.
        self.zones = zones
        self._zone_assign: Dict[str, str] = {}
        self.zone_workers: Dict[str, List[str]] = {}
        self._rebuild_leaf_index()
        if _tree_uses_deadline(tree):
            self._enable_service_est()
        self._draining: Dict[str, Worker] = {}  # removed, in-flight finishing
        self.engine = EventEngine(event_backend,
                                  background=("autoscale_tick", "fault"))
        self._push = self.engine.push      # hot path: skip a delegation hop
        # instance-id allocation scope: "sim" (default) numbers instances
        # from one fleet-wide counter — the historical behaviour every
        # golden digest pins; "worker" numbers per worker, making iids a
        # pure function of that worker's own event sequence — required
        # for serial ≡ K-partition byte-equality (repro.parallel), where
        # a fleet-wide counter would leak the global interleaving into
        # instance names
        if iid_scope not in ("sim", "worker"):
            raise ValueError(f"iid_scope must be 'sim' or 'worker', "
                             f"got {iid_scope!r}")
        self._iid = itertools.count()
        self._iid_by_worker = {} if iid_scope == "worker" else None
        self.now = 0.0
        self.events_processed = 0
        self.arrivals_seen = 0
        self.arrivals_by_fn: Dict[str, int] = {}   # per-fn scaling signal
        self.hedges_seen = 0         # hedge clones, counted apart from demand
        self.cold_starts_total = 0   # survives worker removal (scale-down)
        self.results: List[RequestResult] = []
        self.telemetry: List[TelemetryRecord] = []
        self._finished: set = set()
        self._fn_cost: Dict[str, float] = {}
        # per-request retry budget for RETRYABLE_ERRORS, with capped
        # exponential backoff; retry_budget=0 (default) disables the
        # whole path. The storm guard caps *concurrently pending*
        # retries: a mass failure sheds the excess instead of
        # re-offering the entire blast wave at once.
        self.retry_budget = retry_budget
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self.retry_storm_cap = retry_storm_cap
        self._retries_pending = 0
        self.retries_scheduled = 0
        self.retries_shed = 0
        self.retries_dropped = 0   # backoff expired after a hedge settled
        # workflow layer: None until a WorkflowWorkload (or a direct
        # attach_workflows call) binds a WorkflowEngine
        self.workflows = None
        self.workflow_results: List = []   # WorkflowResult per instance
        # chaos layer: None until a FaultConfig/FaultInjector is
        # attached (directly or via a workload's .faults)
        self.faults = None
        if faults is not None:
            self.attach_faults(faults)
        # front-door gateway: None until a GatewayConfig/Gateway is
        # attached (directly or via a workload's .gateway) — gateway-off
        # runs consume no extra RNG and stay byte-identical to the
        # pre-gateway goldens
        self.gateway = None
        if gateway is not None:
            self.attach_gateway(gateway)

    # --------------------------------------------------- control-plane API
    # Thin delegates: the logic lives on repro.autoscale.control.ControlPlane
    # (sim.control); these names are the stable public surface.
    @property
    def placer(self):
        return self.control.placer

    @property
    def autoscaler(self):
        return self.control.autoscaler

    @property
    def placement_records(self) -> List[str]:
        return self.control.placement_records

    @property
    def routing_records(self) -> List[str]:
        return self.control.routing_records

    def placement_log(self) -> str:
        return self.control.placement_log()

    def routing_log(self) -> str:
        return self.control.routing_log()

    def prewarm(self, worker: str, fn: str) -> bool:
        return self.control.prewarm(worker, fn)

    def reap(self, worker: str, fn: str) -> bool:
        return self.control.reap(worker, fn)

    def place_prewarm(self, fn: str) -> Optional[str]:
        return self.control.place_prewarm(fn)

    def place_reap(self, fn: str) -> Optional[str]:
        return self.control.place_reap(fn)

    def attach_autoscaler(self, scaler, *, first_tick_s: float = None):
        return self.control.attach_autoscaler(scaler,
                                              first_tick_s=first_tick_s)

    def _log_placement(self, kind: str, w: Worker, fn: str) -> None:
        self.control.log_placement(kind, w, fn)

    # ------------------------------------------------------ partition hooks
    def _alloc_iid(self, w) -> str:
        """Next instance id on worker ``w`` (see ``iid_scope``)."""
        if self._iid_by_worker is None:
            return f"{w.name}/i{next(self._iid)}"
        c = self._iid_by_worker.get(w.name)
        if c is None:
            c = self._iid_by_worker[w.name] = itertools.count()
        return f"{w.name}/i{next(c)}"

    def occupancy_summary(self) -> dict:
        """Deterministic snapshot the parallel runner exchanges at window
        barriers (``repro.parallel``): outstanding work plus gateway
        occupancy. A pure function of partition state — no RNG, no
        events — so barrier directives derived from it keep same-seed
        runs byte-identical."""
        queued = inflight = 0
        for w in self.workers.values():
            queued += len(w.queue)
            inflight += w.inflight()
        d = {"now": self.now,
             "pending_real": self.engine.pending_real,
             "queued": queued, "inflight": inflight,
             "arrivals": self.arrivals_seen,
             "results": len(self.results)}
        if self.gateway is not None:
            d["gw_inflight"] = self.gateway.inflight
            d["gw_by_pri"] = dict(self.gateway.inflight_by_pri)
        return d

    # ----------------------------------------------------------- event API
    def submit(self, req: Request):
        self._push(req.arrival_t, "arrival", req)

    def inject_failure(self, worker: str, at: float, recover_after: float):
        self._push(at, "fail", worker)
        self._push(at + recover_after, "recover", worker)

    def set_straggler(self, worker: str, factor: float):
        self.workers[worker].slowdown = factor

    def attach_faults(self, faults) -> None:
        """Attach the chaos layer: accepts a ``FaultConfig`` or a
        prebuilt ``FaultInjector`` and arms it. A disabled config arms
        nothing — the run stays byte-identical to a fault-free one."""
        from repro.core.faults import FaultConfig, FaultInjector
        if isinstance(faults, FaultConfig):
            faults = FaultInjector(self, faults)
        self.faults = faults
        faults.arm()

    def fault_log(self) -> str:
        return "" if self.faults is None else self.faults.fault_log()

    def attach_gateway(self, gateway):
        """Attach the front-door stage (``repro.core.gateway``): accepts
        a ``GatewayConfig`` or a prebuilt ``Gateway``. A disabled config
        attaches nothing — the run stays byte-identical to a
        gateway-free one. Verdict recording follows the simulator's
        ``record_decisions`` flag so recorded runs are replayable
        (``repro.autoscale.replay.ReplayGateway``)."""
        from repro.core.gateway import Gateway, GatewayConfig
        if isinstance(gateway, GatewayConfig):
            if not gateway.enabled:
                return None
            gateway = Gateway(gateway)
        if self._record:
            gateway.record = True
        self.gateway = gateway
        return gateway

    def gateway_log(self) -> str:
        return self.control.gateway_log()

    @property
    def gateway_records(self) -> List[str]:
        return self.control.gateway_records

    def attach_workflows(self, engine):
        """Bind the workflow DAG runtime (``repro.workloads.workflows``).
        ``WorkflowWorkload.submit_to`` attaches one automatically; several
        workflow workloads submitted into one simulator share it."""
        self.workflows = engine
        return engine

    # ------------------------------------------------------------ topology
    def add_branch(self, node: LBNode):
        self.tree.add_branch(node)
        for w in node.all_workers():
            self.workers[w] = Worker(
                w, capacity_slots=self.worker_capacity_slots,
                memory_mb=self.worker_memory_mb)
        self._worker_list = list(self.workers)
        self._recount_healthy()
        self._rebuild_leaf_index()
        self._view_needed = (self._view_needed
                             or not _tree_all_stateless(node))
        self._fn_view_needed = (self._fn_view_needed
                                or _tree_uses_fn_state(node))
        if _tree_uses_deadline(node):
            self._enable_service_est()

    def remove_branch(self, name: str):
        """Remove a branch *safely*: queued requests on its workers are
        re-routed through the shrunk tree, in-flight ones drain to
        completion on a parked worker, and the stale ``self.workers``
        entries are dropped so a later ``add_branch`` cannot resurrect
        routing to dead names (the seed left both dangling)."""
        removed = [c for c in self.tree.children if c.name == name]
        self.tree.remove_branch(name)
        self._worker_list = self.tree.all_workers()
        live = set(self._worker_list)
        for node in removed:
            for wname in node.all_workers():
                if wname in live:           # still reachable via another branch
                    continue
                w = self.workers.pop(wname, None)
                if w is None:
                    continue
                for req in w.queue.drain_all():   # re-route queued work
                    self._push(self.now, "reroute", req)
                if w.inflight() > 0:
                    self._draining[wname] = w
        self._recount_healthy()
        self._rebuild_leaf_index()

    def _recount_healthy(self):
        self._healthy_count = sum(
            1 for w in self._worker_list if self.workers[w].healthy)

    # ------------------------------------------------- state-view publication
    def _enable_service_est(self):
        """Attach the windowed service-time estimator deadline-aware
        routing prices backlogs with (idempotent; lazy import keeps the
        core layer free of a hard autoscale dependency). Deadline routing
        is the one stateful policy meant for *inner* LB nodes too — the
        paper's recipe otherwise scatters across branches statelessly —
        so it also turns on aggregated per-branch state rows."""
        if self.view.estimator is None:
            from repro.autoscale.metrics import ServiceEstimator
            self.view.estimator = ServiceEstimator()
        self.view.cold_start_est_s = self.cold_default
        self.view.node_resolver = self._resolve_node_state
        if self.mem_eta_mode == "placer":
            self.view.mem_eta = self.placer.blocked_cold_eta_s
        self._branch_view_needed = True

    def _rebuild_leaf_index(self):
        """Worker -> leaf / inner-ancestor maps for branch-level state
        rows (leaf rows resolve dirty-lazily through
        ``_resolve_node_state``; inner-node rows likewise)."""
        self._leaf_members = {}
        self._leaf_of = {}
        self._leaf_nodes = {}
        self._node_workers = {}
        ancestors: Dict[str, set] = {}

        def walk(node, path):
            if node.is_leaf:
                self._leaf_members[node.name] = list(node.workers)
                self._leaf_nodes[node.name] = node
                for w in node.workers:
                    self._leaf_of[w] = node.name
                    ancestors.setdefault(w, set()).update(path)
                return
            self._node_workers[node.name] = node.all_workers()
            for c in node.children:
                walk(c, path + [node.name])
        walk(self.tree, [])
        if self.zones is not None:
            # per-*branch* zones: every worker of a leaf shares its
            # failure domain, so zone-blind spread (which happily packs
            # one branch) and spread_zones genuinely diverge under a
            # zone outage. Assignments are sticky: a leaf keeps its zone
            # across unrelated add/remove_branch calls.
            for leaf in self._leaf_members:
                if leaf not in self._zone_assign:
                    if isinstance(self.zones, dict):
                        z = self.zones.get(leaf)
                    else:
                        z = f"z{len(self._zone_assign) % self.zones}"
                    if z is not None:
                        self._zone_assign[leaf] = z
            self.zone_workers = {}
            for leaf, members in self._leaf_members.items():
                z = self._zone_assign.get(leaf)
                for wname in members:
                    w = self.workers.get(wname)
                    if w is not None:
                        w.zone = z
                if z is not None:
                    self.zone_workers.setdefault(z, []).extend(members)
        self._worker_ancestors = {w: sorted(a) for w, a in ancestors.items()}
        self._node_dirty = set(self._node_workers)
        self._node_cache = {}
        # leaves that survived a topology change keep their rows (the
        # eager scheme kept them in the StateView across rebuilds)
        live = self._leaf_members
        self._leaf_dirty_t = {k: v for k, v in self._leaf_dirty_t.items()
                              if k in live}
        self._leaf_ver = {k: v for k, v in self._leaf_ver.items() if k in live}
        self._leaf_cache = {k: v for k, v in self._leaf_cache.items()
                            if k in live}

    def _aggregate_state(self, name: str, members,
                         now: Optional[float] = None) -> WorkerState:
        """One aggregated WorkerState row over a set of *live* workers so
        stateful branch-level policies (deadline_aware) can score whole
        leaf branches: sums for queue/inflight/capacity, unions for warm
        sets, and the *best* free memory (a cold start needs one worker
        that fits, not average headroom). ``now`` prices warm-slot
        readiness: the dirty-lazy leaf path passes the leaf's last
        member-event time so a deferred aggregation reproduces the
        eagerly-refreshed row byte-for-byte. Inner-node rows use the
        row-based (staleness-respecting) variant in
        ``_resolve_node_state``."""
        if now is None:
            now = self.now
        q = infl = cap = 0
        qd: Dict[str, int] = {}
        fs: Dict[str, int] = {}
        warm: set = set()
        healthy = False
        mem = 0.0
        for wname in members:
            w = self.workers.get(wname)
            if w is None:
                continue
            q += len(w.queue)
            infl += w.inflight()
            cap += w.slots_total()
            if not w.healthy:
                continue
            healthy = True
            mem = max(mem, w.mem_free_mb())
            warm.update(w.warm_fns())
            for fn, n in w.queue.depths().items():
                qd[fn] = qd.get(fn, 0) + n
            for fn, n in w.fn_free_slots(now).items():
                fs[fn] = fs.get(fn, 0) + n
        return WorkerState(
            worker=name, queue_len=q, inflight=infl, capacity=cap,
            warm_fns=frozenset(warm), healthy=healthy, fn_queue=qd,
            fn_free_slots=fs, mem_free_mb=mem)

    def _refresh_branch_view(self, leaf: str):
        self.view.update(
            self._aggregate_state(leaf, self._leaf_members.get(leaf, ())),
            self.now)

    def _resolve_node_state(self, name: str, t: float):
        """StateView fallback for branch-level node names.

        *Leaf* rows are dirty-lazy (ISSUE-5 satellite): a member event
        only stamps the leaf's dirty time; the O(leaf_size × fns)
        aggregation is deferred to the next routing read and cached
        until the next member event. Aggregating the *live* members at
        the recorded dirty time reproduces exactly what the old eager
        refresh computed then — worker state only changes on member
        events (the one exception, a control-plane ``prewarm`` between
        member events, becomes visible one read earlier, which is
        strictly fresher information). A leaf with no member event yet
        resolves to None (the blind default), as under the eager scheme.

        *Inner* (non-leaf) names aggregate the members' per-worker *view
        rows* — not live workers — so upper-level scoring sees exactly
        the staleness the StateView models; cached until a member
        refreshes (dirty-tracked in ``_refresh_view``) or the stale
        snapshot rotates. 2-level trees, whose scored children are all
        leaves, never pay for the inner-node machinery."""
        dirty_t = self._leaf_dirty_t.get(name)
        if dirty_t is not None:
            ver = self._leaf_ver[name]
            cached = self._leaf_cache.get(name)
            if cached is not None and cached[0] == ver:
                return cached[1]
            row = self._aggregate_state(
                name, self._leaf_members.get(name, ()), now=dirty_t)
            self._leaf_cache[name] = (ver, row)
            return row
        members = self._node_workers.get(name)
        if members is None:
            return None
        if (self.view.staleness_s > 0
                and self._node_cache_stale_t != self.view._stale_t):
            self._node_cache.clear()        # stale snapshot rotated
            self._node_cache_stale_t = self.view._stale_t
        if name in self._node_dirty or name not in self._node_cache:
            q = infl = cap = 0
            qd: Dict[str, int] = {}
            fs: Dict[str, int] = {}
            warm: set = set()
            healthy = False
            mem = 0.0
            for wname in members:
                ws = self.view.get(wname, t)   # staleness-respecting row
                q += ws.queue_len
                infl += ws.inflight
                cap += ws.capacity
                if not ws.healthy:
                    continue
                healthy = True
                mem = max(mem, ws.mem_free_mb)
                warm.update(ws.warm_fns)
                for fn, n in ws.fn_queue.items():
                    qd[fn] = qd.get(fn, 0) + n
                for fn, n in ws.fn_free_slots.items():
                    fs[fn] = fs.get(fn, 0) + n
            self._node_cache[name] = WorkerState(
                worker=name, queue_len=q, inflight=infl, capacity=cap,
                warm_fns=frozenset(warm), healthy=healthy, fn_queue=qd,
                fn_free_slots=fs, mem_free_mb=mem)
            self._node_dirty.discard(name)
        return self._node_cache[name]

    def _refresh_view(self, w: Worker):
        if not self._view_needed:    # stateless tree: nothing reads rows
            return
        if self._fn_view_needed:     # only per-fn routing pays for the dicts
            state = WorkerState(
                worker=w.name, queue_len=len(w.queue), inflight=w.inflight(),
                capacity=w.slots_total(), warm_fns=w.warm_fns(),
                healthy=w.healthy, fn_queue=w.queue.depths(),
                fn_free_slots=w.fn_free_slots(self.now),
                mem_free_mb=w.mem_free_mb())
        else:
            state = WorkerState(
                worker=w.name, queue_len=len(w.queue), inflight=w.inflight(),
                capacity=w.slots_total(), warm_fns=w.warm_fns(),
                healthy=w.healthy)
        self.view.update(state, self.now)
        if self._branch_view_needed:
            leaf = self._leaf_of.get(w.name)
            if leaf is not None:
                if self.view.staleness_s > 0:
                    # the stale-snapshot rotation needs leaf rows stored
                    # in the StateView; keep the eager refresh here (the
                    # dirty-lazy path models staleness == 0 only)
                    self._refresh_branch_view(leaf)
                else:
                    self._leaf_dirty_t[leaf] = self.now
                    self._leaf_ver[leaf] = self._leaf_ver.get(leaf, 0) + 1
            anc = self._worker_ancestors.get(w.name)
            if anc:
                self._node_dirty.update(anc)

    # -------------------------------------------------------------- helpers
    def fn_cost(self, fn: str) -> float:
        if fn not in self._fn_cost:
            from repro.configs import get_config
            try:
                arch = self.store.get(fn).arch
                self._fn_cost[fn] = get_config(arch).param_count() / 1e7
            except Exception:
                self._fn_cost[fn] = 1.0
        return self._fn_cost[fn]

    def load(self, workload) -> int:
        """Submit every request of a ``repro.workloads`` workload;
        returns the request count. A workload carrying a fault plan
        (``workload.faults``, set by chaos scenarios) attaches it,
        unless the simulator already has one."""
        faults = getattr(workload, "faults", None)
        if faults is not None and self.faults is None:
            self.attach_faults(faults)
        gateway = getattr(workload, "gateway", None)
        if gateway is not None and self.gateway is None:
            self.attach_gateway(gateway)
        return workload.submit_to(self)

    def load_bulk(self, workload, *, chunk: int = 1 << 18) -> int:
        """Vectorized counterpart of :meth:`load`: generate the
        workload's columnar ``RequestBatch`` (``generate_bulk``) and
        stream it into the event engine in ``chunk``-sized bulk runs —
        same fault-plan attachment and the same ``(t, seq)`` arrival
        stamps as per-request ``submit`` in arrival order, so the run
        is byte-identical to the submit loop, without the per-request
        scalar RNG walk. Also accepts a pre-built ``RequestBatch``.
        Note the *workload content* follows the bulk determinism
        contract (numpy streams), not the scalar one."""
        from repro.workloads.workload import RequestBatch
        faults = getattr(workload, "faults", None)
        if faults is not None and self.faults is None:
            self.attach_faults(faults)
        gateway = getattr(workload, "gateway", None)
        if gateway is not None and self.gateway is None:
            self.attach_gateway(gateway)
        batch = (workload if isinstance(workload, RequestBatch)
                 else workload.generate_bulk())
        push_bulk = self.engine.push_bulk
        for sub in batch.iter_chunks(chunk):
            push_bulk(sub.arrival_t, "arrival", sub.to_requests())
        return len(batch)

    # ---------------------------------------------------------------- run
    def run(self, until: Optional[float] = None):
        """Drive the event engine until empty (or past ``until``).

        ``engine.pop(until)`` *peeks* before popping, so an event beyond
        the horizon stays in the queue untouched — a segmented
        ``run(until); run()`` is byte-identical to one straight ``run()``
        including ``events_processed`` (pinned in tests/test_events.py);
        there is no pop-and-requeue path left to double-count through."""
        engine = self.engine
        handlers = {k: getattr(self, "_on_" + k) for k in self._EVENT_KINDS}
        get_handler = handlers.get
        while True:
            entry = engine.pop(until)
            if entry is None:
                break
            t, _seq, kind, payload = entry
            self.now = t
            self.events_processed += 1
            h = get_handler(kind)
            if h is None:                  # custom kind pushed by a caller
                h = handlers[kind] = getattr(self, "_on_" + kind)
            h(payload)
        return self.results

    # ------------------------------------------------------------- events
    def _on_autoscale_tick(self, _payload):
        self.control.on_tick()

    def _on_arrival(self, req: Request):
        if req.hedged_from is None:
            self.arrivals_seen += 1
            self.arrivals_by_fn[req.fn] = self.arrivals_by_fn.get(req.fn,
                                                                  0) + 1
            # front door: every offered arrival traverses the gateway
            # before the LB tree; a shed is a terminal answer (not
            # retryable) recorded before any routing/telemetry happens
            if self.gateway is not None:
                verdict = self.gateway.admit(req, self.now)
                if self._record:
                    self.control.log_gateway("arrival", req, verdict)
                if verdict is not None:
                    self._record_fail(req, verdict)
                    return
        else:
            # hedge clones are the platform's own speculation, not
            # offered load: counting them as arrivals fed the autoscaler
            # synthetic demand that grew with its own hedging
            self.hedges_seen += 1
        # healthy set is tracked incrementally; the full list is only
        # materialised on the rare stale-routing re-roll (the seed built
        # it on every arrival: O(fleet) on the hottest event)
        if self._healthy_count == 0:
            self._record_fail(req, "no healthy workers")
            return
        if (self.view.estimator is not None
                and req.fn not in self.view.fn_memory):
            # deadline routing needs the fn's footprint to spot workers
            # where a cold start is memory-blocked
            self.view.fn_memory[req.fn] = self.store.get(req.fn).memory_mb
        wid, hops = self.tree.route(req, self.view, self.rng, self.now)
        rerolled = not self.workers[wid].healthy   # stale routing
        if rerolled:
            wid = self._reroute_healthy(req, wid)
        if self._record:
            self.control.log_routing("arrival_reroll" if rerolled
                                     else "arrival", req, wid)
        w = self.workers[wid]
        cfg = self.store.get(req.fn)
        if self.collect_telemetry:
            self.telemetry.append(TelemetryRecord(
                fn=req.fn, t=self.now, queue_len=len(w.queue),
                inflight=w.inflight(), batch_size=0, cold=False,
                prompt_tokens=req.size, gen_tokens=cfg.gen_tokens,
                fn_cost=self.fn_cost(req.fn), latency=0.0, ok=True))
            req._telemetry_idx = len(self.telemetry) - 1
        req._worker = wid
        self._push(self.now + self.hop_s * hops, "enqueue", req)
        if self.hedge_after_s is not None and req.hedged_from is None:
            self._push(self.now + self.hedge_after_s, "maybe_hedge", req)

    def _on_reroute(self, req: Request):
        """Send a displaced request (its worker's branch was removed)
        through the shrunk tree. Unlike an arrival this reuses the
        request's telemetry record and hedge timer — it is the same
        request, not new offered load."""
        self._route_displaced(req, "reroute")

    def _on_retry(self, req: Request):
        """A retry backoff expired: re-offer the request through the
        tree (it may have finished meanwhile via a hedge — then drop)."""
        self._retries_pending -= 1
        primary = req.hedged_from if req.hedged_from is not None else req.rid
        if primary in self._finished:
            self.retries_dropped += 1
            return
        # the front door is consulted on retries too: re-offering a
        # request into a saturated platform is exactly the storm shape
        # admission control exists to refuse
        if self.gateway is not None:
            verdict = self.gateway.admit(req, self.now, retry=True)
            if self._record:
                self.control.log_gateway("retry", req, verdict)
            if verdict is not None:
                self._record_fail(req, verdict)
                return
        self._route_displaced(req, "retry")

    def _reroute_healthy(self, req: Request, wid: str) -> str:
        """The routed worker turned unhealthy between state publication
        and this hop: re-score the healthy fleet with the *leaf policy*
        that produced the stale pick. The old uniform
        ``rng.choice(healthy)`` re-roll bypassed placement/deadline
        scoring entirely (a deadline_aware tree degraded to random
        exactly when capacity was scarcest). Fault-free runs never take
        this path, so their goldens are untouched."""
        healthy = [w for w in self._worker_list if self.workers[w].healthy]
        leaf = self._leaf_nodes.get(self._leaf_of.get(wid, ""))
        if leaf is None:                 # no owning leaf (defensive)
            return self.rng.choice(healthy)
        return leaf._policy(req, healthy, self.view, self.rng, self.now)

    def _route_displaced(self, req: Request, kind: str):
        if self._healthy_count == 0:
            self._record_fail(req, "no healthy workers")
            return
        wid, hops = self.tree.route(req, self.view, self.rng, self.now)
        rerolled = not self.workers[wid].healthy   # stale routing
        if rerolled:
            wid = self._reroute_healthy(req, wid)
        if self._record:
            # the _reroll suffix records the hop itself, so a decision-log
            # replay/audit can see the displaced pick was policy-scored
            self.control.log_routing(f"{kind}_reroll" if rerolled else kind,
                                     req, wid)
        req._worker = wid
        self._push(self.now + self.hop_s * hops, "enqueue", req)

    def _on_maybe_hedge(self, req: Request):
        if req.rid in self._finished:
            return
        # the clone's rid derives from the primary (-rid - 1), not the
        # process-global counter: workload rids are >= 0 so clone ids
        # cannot collide, and two same-seed runs in one process now
        # produce byte-identical routing logs (the global counter kept
        # advancing across runs). Clones never hedge again, so the
        # mapping needn't nest.
        clone = Request(fn=req.fn, arrival_t=self.now, payload=req.payload,
                        size=req.size, rid=-req.rid - 1,
                        hedged_from=req.rid, deadline_t=req.deadline_t,
                        priority=req.priority,
                        wf=req.wf, stage=req.stage, wf_task=req.wf_task,
                        wf_critical=req.wf_critical,
                        wf_affinity=req.wf_affinity)
        # keep a handle on the primary so record_result can resolve its
        # telemetry row when the clone wins the race
        clone._primary = req
        self._on_arrival(clone)

    def _on_fault(self, payload):
        if self.faults is not None:
            self.faults.on_event(payload)

    def _on_workflow_done(self, payload):
        if self.workflows is not None:
            self.workflows.fire(self, payload)

    def _on_fail(self, worker: str):
        w = self.workers.get(worker)
        if w is None:                   # branch already scaled away
            self._draining.pop(worker, None)
            return
        if w.healthy:
            self._healthy_count -= 1
        w.healthy = False
        for req in w.queue.drain_all():
            self._record_fail(req, "worker died")
        w.clear_instances()
        self._refresh_view(w)

    def _on_recover(self, worker: str):
        w = self.workers.get(worker)
        if w is None:
            return
        if not w.healthy:
            self._healthy_count += 1
        w.healthy = True
        self._refresh_view(w)

    # ------------------------------------------------- worker-runtime seam
    # The mechanics live on repro.core.worker.WorkerRuntime (self.runtime);
    # these methods are the override/patch seam — the runtime re-enters
    # through them, so intercepting here catches every internal path.
    def _on_enqueue(self, req: Request):
        self.runtime.enqueue(req)

    def _on_poke(self, worker: str):
        self.runtime.on_poke(worker)

    def _on_finish(self, payload):
        self.runtime.finish(payload)

    def _on_idle_check(self, payload):
        self.runtime.idle_check(payload)

    def _dispatch(self, w: Worker):
        self.runtime.dispatch(w)

    def _maybe_start_instance(self, w: Worker, cfg) -> Optional[Instance]:
        return self.runtime.maybe_start_instance(w, cfg)

    def _start_service(self, w: Worker, inst: Instance, req: Request, cfg,
                       queue_len: int):
        self.runtime.start_service(w, inst, req, cfg, queue_len)

    def _poke(self, w: Worker, t: float):
        self.runtime.poke(w, t)

    # ------------------------------------------------------ result recording
    def _resolve_telemetry(self, req: Request, ok: bool) -> None:
        """Resolve a request's placeholder telemetry row (created at
        arrival with ``latency=0.0, ok=True``) to its final outcome.
        Guarded: a request that failed *before* routing ("no healthy
        workers" at arrival) never got a row — dereferencing the missing
        index used to crash the retry-after-recovery path. Resolution is
        exactly-once: clearing the index keeps a hedge loser's late
        completion from clobbering the end-to-end outcome the winner
        already stamped on the primary's row."""
        if not self.collect_telemetry:
            return
        idx = getattr(req, "_telemetry_idx", None)
        if idx is None:
            return
        rec = self.telemetry[idx]
        rec.latency = self.now - req.arrival_t
        rec.ok = ok
        req._telemetry_idx = None

    def record_result(self, req: Request, *, start_t: float, ok: bool,
                      cold: bool, worker: str, instance: str) -> bool:
        """Record a completion for ``req`` (resolving hedge races to the
        primary rid); returns False when a faster hedge already won."""
        # rid 0 is falsy, so `or` would misattribute a hedge of request 0
        primary = req.hedged_from if req.hedged_from is not None else req.rid
        if primary in self._finished:
            # hedge lost the race: no result row, but this attempt's own
            # telemetry row still resolves (it used to stay at the
            # placeholder forever)
            self._resolve_telemetry(req, ok)
            return False
        self._finished.add(primary)
        if self.gateway is not None:
            # the slot was taken at the primary's admit; a winning clone
            # carries the primary handle so the release targets the
            # object holding the admit stamp
            self.gateway.release(getattr(req, "_primary", req), self.now)
        res = RequestResult(rid=primary, fn=req.fn, ok=ok,
                            arrival_t=req.arrival_t, start_t=start_t,
                            finish_t=self.now, cold_start=cold,
                            worker=worker, instance=instance,
                            wf=req.wf, stage=req.stage)
        self.results.append(res)
        if self.view.estimator is not None and ok:
            # deadline routing prices backlogs with this windowed
            # observation; fed in result order, so it is deterministic
            self.view.estimator.observe(req.fn, res.service_time)
        self._resolve_telemetry(req, ok)
        if req.hedged_from is not None:
            # the clone won: resolve the primary's row with the same
            # end-to-end outcome (same latency math: now - arrival)
            prim = getattr(req, "_primary", None)
            if prim is not None:
                self._resolve_telemetry(prim, ok)
        if self.workflows is not None and req.wf is not None:
            self.workflows.on_stage_done(self, req, ok, worker)
        return True

    def _record_fail(self, req: Request, err: str):
        primary = req.hedged_from if req.hedged_from is not None else req.rid
        if primary in self._finished:
            # hedge race already settled: no result row, but this losing
            # attempt's own telemetry row still resolves (exactly-once
            # keeps the settled primary row untouched)
            self._resolve_telemetry(req, False)
            return
        # retry budget: resurrect infrastructure failures with capped
        # exponential backoff. Hedge clones don't retry (the primary's
        # own path still stands); the storm guard sheds retries beyond
        # retry_storm_cap concurrently pending so a zone-sized blast
        # wave can't multiply itself back into the queue instantly.
        if (self.retry_budget > 0 and err in RETRYABLE_ERRORS
                and req.hedged_from is None):
            tried = getattr(req, "_retries", 0)
            if tried < self.retry_budget:
                if self._retries_pending >= self.retry_storm_cap:
                    self.retries_shed += 1
                else:
                    req._retries = tried + 1
                    self._retries_pending += 1
                    self.retries_scheduled += 1
                    backoff = min(self.retry_backoff_s * (2.0 ** tried),
                                  self.retry_backoff_cap_s)
                    self._push(self.now + backoff, "retry", req)
                    return
        self._finished.add(primary)
        if self.gateway is not None:
            # terminal failure settles the request: free its admission
            # slot (no-op for gateway-shed requests — never admitted)
            self.gateway.release(getattr(req, "_primary", req), self.now)
        self.results.append(RequestResult(
            rid=primary, fn=req.fn, ok=False, arrival_t=req.arrival_t,
            start_t=self.now, finish_t=self.now, cold_start=False,
            worker=getattr(req, "_worker", "?"), instance="-", error=err,
            wf=req.wf, stage=req.stage))
        # failed rows used to keep their placeholder latency=0.0,
        # ok=True, poisoning the RQ-B training set with "instant
        # successes" — resolve them exactly like completions do
        self._resolve_telemetry(req, False)
        if req.hedged_from is not None:
            prim = getattr(req, "_primary", None)
            if prim is not None:
                self._resolve_telemetry(prim, False)
        if self.workflows is not None and req.wf is not None:
            self.workflows.on_stage_done(self, req, False, None)


# ---------------------------------------------------------------------------
# Load generation + metrics
# ---------------------------------------------------------------------------

def poisson_load(sim: Simulator, *, fn: str, rps: float, duration_s: float,
                 prompt_tokens: int = 16, seed: int = 1):
    """Legacy single-function Poisson driver; now a thin shim over the
    workload subsystem (``repro.workloads``). ``rid_base=None`` keeps the
    process-global request-id counter this entry point always used."""
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)
    wl = MixedWorkload(
        PoissonArrivals(rps),
        [FunctionProfile(fn, size=SizeDist.const(prompt_tokens))],
        duration_s=duration_s, seed=seed, rid_base=None)
    return sim.load(wl)


def stream_digest(sim) -> str:
    """sha256[:16] over a run's full result + telemetry + workflow
    streams — THE byte-identity projection every golden/equivalence
    suite compares (one definition, so the suites can never drift apart
    on which fields "byte-identical" covers). Accepts anything exposing
    ``results`` / ``telemetry`` / ``workflow_results`` — a
    :class:`Simulator` or a ``repro.parallel.MergedRun``."""
    import hashlib
    h = hashlib.sha256()
    for r in sim.results:
        h.update(repr((r.rid, r.fn, r.ok, r.arrival_t, r.start_t, r.finish_t,
                       r.cold_start, r.worker, r.instance, r.error)).encode())
    for t in sim.telemetry:
        h.update(repr((t.fn, t.t, t.queue_len, t.inflight, t.batch_size,
                       t.cold, t.latency, t.ok)).encode())
    for w in getattr(sim, "workflow_results", ()):
        h.update(repr((w.wf, w.name, w.ok, w.arrival_t, w.finish_t,
                       w.tasks, w.error)).encode())
    return h.hexdigest()[:16]


def part_summary(results) -> dict:
    """Mergeable partial of :func:`summarize` over one result stream
    (a partition's share): raw counts plus the ok-latency sample, so
    :func:`merge_part_summaries` reproduces ``summarize`` over the
    union exactly (percentiles are order-invariant)."""
    import numpy as np
    lat, ok, served, cold = [], 0, 0, 0
    t0 = float("inf")
    t1 = -float("inf")
    n = 0
    for r in results:
        n += 1
        t0 = min(t0, r.arrival_t)
        if r.instance != "-":
            served += 1
        if r.cold_start:
            cold += 1
        if r.ok:
            ok += 1
            lat.append(r.latency)
            t1 = max(t1, r.finish_t)
    return {"n": n, "ok": ok, "served": served, "cold": cold,
            "lat": np.asarray(lat, dtype=np.float64),
            "t0": t0, "t1": t1}


def merge_part_summaries(parts) -> dict:
    """Combine :func:`part_summary` partials into the exact dict
    :func:`summarize` computes over the concatenated results."""
    import numpy as np
    parts = [p for p in parts if p["n"]]
    if not parts:
        return {"n": 0}
    n = sum(p["n"] for p in parts)
    ok = sum(p["ok"] for p in parts)
    served = sum(p["served"] for p in parts)
    cold = sum(p["cold"] for p in parts)
    lat = np.concatenate([p["lat"] for p in parts])
    t0 = min(p["t0"] for p in parts)
    t1 = max((p["t1"] for p in parts if p["ok"]), default=t0)
    makespan = t1 - t0
    goodput = ok / max(makespan, 1e-9) if ok else 0.0
    return {
        "n": n, "ok": ok, "fail_rate": 1 - ok / n,
        "cold_rate": cold / served if served else 0.0,
        "p50": float(np.percentile(lat, 50)) if len(lat) else float("nan"),
        "p95": float(np.percentile(lat, 95)) if len(lat) else float("nan"),
        "p99": float(np.percentile(lat, 99)) if len(lat) else float("nan"),
        "mean": float(lat.mean()) if len(lat) else float("nan"),
        "throughput": goodput,
        "goodput": goodput,
    }


def summarize(results: List[RequestResult]) -> dict:
    import numpy as np
    if not results:
        return {"n": 0}
    lat = np.array([r.latency for r in results if r.ok])
    ok = sum(r.ok for r in results)
    # cold_rate over *served* rows only: failures that never reached an
    # instance (gateway sheds, dead-on-arrival routing, queue timeouts —
    # their instance column is "-") can't have had a cold start, so
    # counting them in the denominator understated the rate under load
    served = sum(1 for r in results if r.instance != "-")
    # throughput/goodput over the useful makespan: last *successful*
    # finish minus first arrival. Using failed rows' finish_t let one
    # late queue-timeout tail (arrival + timeout_s) stretch the window
    # and dilute the rate; arrivals still span all rows so a run whose
    # first arrival is at t0 > 0 (daily_cycle offsets, resumed
    # run(until)) isn't credited for the empty [0, t0) prefix
    t0 = min(r.arrival_t for r in results)
    t1 = max((r.finish_t for r in results if r.ok), default=t0)
    makespan = t1 - t0
    goodput = ok / max(makespan, 1e-9) if ok else 0.0
    return {
        "n": len(results), "ok": ok, "fail_rate": 1 - ok / len(results),
        "cold_rate": (sum(r.cold_start for r in results) / served
                      if served else 0.0),
        "p50": float(np.percentile(lat, 50)) if len(lat) else float("nan"),
        "p95": float(np.percentile(lat, 95)) if len(lat) else float("nan"),
        "p99": float(np.percentile(lat, 99)) if len(lat) else float("nan"),
        "mean": float(lat.mean()) if len(lat) else float("nan"),
        "throughput": goodput,
        "goodput": goodput,
    }

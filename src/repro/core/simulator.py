"""Deterministic discrete-event simulator for the HyperFaaS testbed.

This is what lets the platform be *studied under massive load* (paper §I):
thousands of (emulated) workers, millions of requests, virtual time. The same
router tree / config store / concurrency policies run here as in the real
in-process engine (``repro.serving.engine``); only the worker execution is
replaced by a service-time model — either a synthetic profile or the learned
RQ-B worker model (paper Fig. 2 step 3).

Fault tolerance features exercised here: worker fail/recover injection,
per-worker straggler slowdowns, hedged requests (tail mitigation), queue
timeouts, and live add/remove of tree branches (elastic scaling).
"""
from __future__ import annotations

import heapq
import itertools
import random
from typing import Dict, List, Optional

from repro.core.config_store import ConfigStore
from repro.core.placement import Placer, get_placer
from repro.core.router import LBNode, StateView, WorkerState
from repro.core.scheduling import (UNLIMITED_SLOTS, FnQueues,
                                   FunctionReplicaSet, Instance)
from repro.core.types import FunctionConfig, Request, RequestResult, TelemetryRecord


# ---------------------------------------------------------------------------
# Service-time models
# ---------------------------------------------------------------------------

class SyntheticServiceModel:
    """Deterministic-plus-noise cost: t = t0 + a*(prompt+gen)*fn_cost, scaled by
    slot contention; lognormal jitter. The 'ground truth' worker for RQ-B."""

    def __init__(self, *, t0=0.004, per_token=0.0008, contention=0.30,
                 jitter=0.08, fail_rate=0.002, seed=0):
        self.t0, self.per_token, self.contention = t0, per_token, contention
        self.jitter, self.fail_rate = jitter, fail_rate
        self.rng = random.Random(seed)

    def sample(self, cfg: FunctionConfig, *, batch_size: int, queue_len: int,
               prompt: int, cold: bool, fn_cost: float):
        base = self.t0 + self.per_token * (prompt + cfg.gen_tokens) * fn_cost
        base *= 1.0 + self.contention * max(batch_size - 1, 0)
        base *= self.rng.lognormvariate(0.0, self.jitter)
        ok = self.rng.random() >= self.fail_rate
        return base, ok


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

# LB policies that read the per-function WorkerState layer; the simulator
# only pays for building those snapshots when the tree routes with one
_FN_STATE_POLICIES = frozenset({"warm_least_loaded", "deadline_aware"})

# LB policies that additionally price backlogs with the windowed service
# estimator; the simulator only feeds it when the tree routes with one
_DEADLINE_POLICIES = frozenset({"deadline_aware"})


def _tree_uses_fn_state(node) -> bool:
    return (node.policy_name in _FN_STATE_POLICIES
            or any(_tree_uses_fn_state(c) for c in node.children))


def _tree_uses_deadline(node) -> bool:
    return (node.policy_name in _DEADLINE_POLICIES
            or any(_tree_uses_deadline(c) for c in node.children))

# Re-exported for callers that patched/inspected the old private name.
_Instance = Instance


class _Worker:
    """One node: per-function replica sets + per-function FIFO queues,
    indexed so every hot-path read is O(affected function). Memory and
    slot totals are tracked incrementally (never recomputed by scanning
    instances) so the placement layer and ``slots_total`` are O(1)."""

    def __init__(self, name: str, capacity_slots: int = 16,
                 memory_mb: Optional[float] = None):
        self.name = name
        self.capacity_slots = capacity_slots   # hardware concurrency of node
        self.memory_mb = memory_mb             # replica memory cap (None=inf)
        self.memory_used_mb = 0.0              # incremental footprint
        self.slowdown = 1.0                    # straggler factor
        self.healthy = True
        self.replica_sets: Dict[str, FunctionReplicaSet] = {}
        self.iid_index: Dict[str, Instance] = {}   # iid -> live instance
        self.total_instances = 0
        self._inflight = 0                 # incremental busy-slot count
        self._slots_total = 0              # incremental slots_total counter
        self.queue = FnQueues()
        self.busy_time = 0.0
        self.cold_starts = 0
        self.instances_started = 0
        self.poke_times: set = set()       # dedupe scheduled pokes

    @property
    def instances(self) -> Dict[str, List[Instance]]:
        """Legacy fn -> instance-list view (tests/examples read this)."""
        return {fn: rs.instances for fn, rs in self.replica_sets.items()
                if rs.instances}

    @staticmethod
    def _slot_contrib(inst: Instance) -> int:
        # an unlimited-concurrency instance (slots == 0) counts its live
        # occupancy (min 1) — matches the old flat recomputation exactly
        return inst.slots if inst.slots > 0 else max(inst.busy, 1)

    def add_instance(self, inst: Instance) -> None:
        rs = self.replica_sets.get(inst.fn)
        if rs is None:
            rs = self.replica_sets[inst.fn] = FunctionReplicaSet(inst.fn)
        rs.add(inst)
        self.iid_index[inst.iid] = inst
        self.total_instances += 1
        self.memory_used_mb += inst.memory_mb
        self._slots_total += self._slot_contrib(inst)

    def remove_instance(self, inst: Instance) -> None:
        self.replica_sets[inst.fn].discard(inst)
        self.iid_index.pop(inst.iid, None)
        self.total_instances -= 1
        self.memory_used_mb -= inst.memory_mb
        self._slots_total -= self._slot_contrib(inst)

    def clear_instances(self) -> None:
        self.replica_sets.clear()
        self.iid_index.clear()
        self.total_instances = 0
        self.memory_used_mb = 0.0
        self._inflight = 0
        self._slots_total = 0

    def note_busy(self, inst: Instance, delta: int) -> None:
        """Move an instance's busy count, keeping ``_slots_total`` exact:
        a slots==0 instance contributes ``max(busy, 1)``, so its share
        shifts as occupancy changes."""
        self._inflight += delta
        if inst.slots > 0:
            inst.busy += delta
            return
        before = max(inst.busy, 1)
        inst.busy += delta
        self._slots_total += max(inst.busy, 1) - before

    def fits(self, memory_mb: float) -> bool:
        """Memory admission for one more ``memory_mb`` replica."""
        return (self.memory_mb is None
                or self.memory_used_mb + memory_mb <= self.memory_mb + 1e-9)

    def mem_free_mb(self) -> float:
        return (float("inf") if self.memory_mb is None
                else self.memory_mb - self.memory_used_mb)

    def fn_replicas(self, fn: str) -> int:
        rs = self.replica_sets.get(fn)
        return len(rs.instances) if rs is not None else 0

    def warm_fns(self) -> frozenset:
        return frozenset(fn for fn, rs in self.replica_sets.items()
                         if rs.instances)

    def inflight(self) -> int:
        return self._inflight

    def slots_total(self) -> int:
        return self._slots_total or 1

    def fn_free_slots(self, now: float) -> Dict[str, int]:
        """Per-function immediately-usable warm slots (router signal)."""
        return {fn: rs.ready_free_slots(now)
                for fn, rs in self.replica_sets.items() if rs.instances}


class Simulator:
    def __init__(self, tree: LBNode, store: ConfigStore, service_model, *,
                 seed: int = 0, state_staleness_s: float = 0.0,
                 hedge_after_s: Optional[float] = None,
                 cold_start_default_s: float = 0.25,
                 network_hop_s: float = 0.0005,
                 worker_capacity_slots: int = 16,
                 worker_memory_mb: Optional[float] = None,
                 placer="first_fit",
                 record_decisions: bool = False):
        self.tree = tree
        self.store = store
        self.model = service_model
        self.rng = random.Random(seed)
        self.view = StateView(state_staleness_s)
        self.hedge_after_s = hedge_after_s
        self.cold_default = cold_start_default_s
        self.hop_s = network_hop_s
        self.worker_capacity_slots = worker_capacity_slots
        # None => unlimited replica memory per worker: every placement
        # admission passes and behaviour is byte-identical to the
        # pre-placement simulator (pinned in tests/test_placement.py)
        self.worker_memory_mb = worker_memory_mb
        self.placer: Placer = (get_placer(placer) if isinstance(placer, str)
                               else placer)
        self._record = record_decisions
        self.placement_records: List[str] = []   # start/reap/idle events
        self.routing_records: List[str] = []     # arrival/reroute choices
        self.workers: Dict[str, _Worker] = {
            w: _Worker(w, capacity_slots=worker_capacity_slots,
                       memory_mb=worker_memory_mb)
            for w in tree.all_workers()}
        self._worker_list = list(self.workers)   # cache (rebuilt on add/remove)
        self._healthy_count = len(self.workers)  # incremental: O(1) arrivals
        self._fn_view_needed = _tree_uses_fn_state(tree)
        self._branch_view_needed = False  # aggregate leaf rows for inner LBs
        self._leaf_members: Dict[str, List[str]] = {}
        self._leaf_of: Dict[str, str] = {}
        self._node_workers: Dict[str, List[str]] = {}   # inner-node subtrees
        self._worker_ancestors: Dict[str, List[str]] = {}
        self._node_dirty: set = set()
        self._node_cache: Dict[str, WorkerState] = {}
        self._node_cache_stale_t = -1e30   # stale-snapshot rotation stamp
        self._rebuild_leaf_index()
        if _tree_uses_deadline(tree):
            self._enable_service_est()
        self._draining: Dict[str, _Worker] = {}  # removed, in-flight finishing
        self._events: list = []
        self._pending_real = 0       # events besides autoscale_tick in queue
        self._seq = itertools.count()
        self._iid = itertools.count()
        self.now = 0.0
        self.events_processed = 0
        self.arrivals_seen = 0
        self.arrivals_by_fn: Dict[str, int] = {}   # per-fn scaling signal
        self.cold_starts_total = 0   # survives worker removal (scale-down)
        self.results: List[RequestResult] = []
        self.telemetry: List[TelemetryRecord] = []
        self._finished: set = set()
        self._fn_cost: Dict[str, float] = {}
        self.autoscaler = None

    # ----------------------------------------------------------- event API
    def _push(self, t: float, kind: str, payload):
        if kind != "autoscale_tick":
            self._pending_real += 1
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def submit(self, req: Request):
        self._push(req.arrival_t, "arrival", req)

    def inject_failure(self, worker: str, at: float, recover_after: float):
        self._push(at, "fail", worker)
        self._push(at + recover_after, "recover", worker)

    def set_straggler(self, worker: str, factor: float):
        self.workers[worker].slowdown = factor

    def add_branch(self, node: LBNode):
        self.tree.add_branch(node)
        for w in node.all_workers():
            self.workers[w] = _Worker(
                w, capacity_slots=self.worker_capacity_slots,
                memory_mb=self.worker_memory_mb)
        self._worker_list = list(self.workers)
        self._recount_healthy()
        self._rebuild_leaf_index()
        self._fn_view_needed = (self._fn_view_needed
                                or _tree_uses_fn_state(node))
        if _tree_uses_deadline(node):
            self._enable_service_est()

    def remove_branch(self, name: str):
        """Remove a branch *safely*: queued requests on its workers are
        re-routed through the shrunk tree, in-flight ones drain to
        completion on a parked worker, and the stale ``self.workers``
        entries are dropped so a later ``add_branch`` cannot resurrect
        routing to dead names (the seed left both dangling)."""
        removed = [c for c in self.tree.children if c.name == name]
        self.tree.remove_branch(name)
        self._worker_list = self.tree.all_workers()
        live = set(self._worker_list)
        for node in removed:
            for wname in node.all_workers():
                if wname in live:           # still reachable via another branch
                    continue
                w = self.workers.pop(wname, None)
                if w is None:
                    continue
                for req in w.queue.drain_all():   # re-route queued work
                    self._push(self.now, "reroute", req)
                if w.inflight() > 0:
                    self._draining[wname] = w
        self._recount_healthy()
        self._rebuild_leaf_index()

    def _recount_healthy(self):
        self._healthy_count = sum(
            1 for w in self._worker_list if self.workers[w].healthy)

    def prewarm(self, worker: str, fn: str) -> bool:
        """Proactively start (cold-start now, serve warm later) one
        instance of ``fn`` on a worker — the autoscaler's scale-up
        companion. Returns False if the worker is gone/unhealthy or at
        instance capacity."""
        w = self.workers.get(worker)
        if w is None or not w.healthy:
            return False
        cfg = self.store.get(fn)
        inst = self._maybe_start_instance(w, cfg)
        if inst is None:
            return False
        # instances normally get idle_checks from _on_finish; a prewarmed
        # instance that never serves traffic needs its own reap path or it
        # would pin a capacity slot forever
        self._push(inst.ready_t + cfg.idle_timeout_s, "idle_check",
                   (worker, inst.iid))
        # a prewarm onto a worker already holding queued work for this fn
        # must wake its dispatch when the replica is ready, or that work
        # only drains on the next unrelated enqueue/finish
        if w.queue.depth(fn) > 0:
            self._poke(w, inst.ready_t)
        return True

    def reap(self, worker: str, fn: str) -> bool:
        """Stop one idle warm instance of ``fn`` on a worker — the
        autoscaler's per-function scale-down companion to :meth:`prewarm`.
        Returns False if the worker is gone/unhealthy or holds no idle
        ready replica of that function."""
        w = self.workers.get(worker)
        if w is None or not w.healthy:
            return False
        rs = w.replica_sets.get(fn)
        inst = rs.idle_ready(self.now) if rs is not None else None
        if inst is None:
            return False
        w.remove_instance(inst)
        if self._record:
            self._log_placement("reap", w, fn)
        if len(w.queue) > 0:       # freed capacity may unblock other fns
            self._dispatch(w)
        else:
            self._refresh_view(w)
        return True

    def _enable_service_est(self):
        """Attach the windowed service-time estimator deadline-aware
        routing prices backlogs with (idempotent; lazy import keeps the
        core layer free of a hard autoscale dependency). Deadline routing
        is the one stateful policy meant for *inner* LB nodes too — the
        paper's recipe otherwise scatters across branches statelessly —
        so it also turns on aggregated per-branch state rows."""
        if self.view.estimator is None:
            from repro.autoscale.metrics import ServiceEstimator
            self.view.estimator = ServiceEstimator()
        self.view.cold_start_est_s = self.cold_default
        self.view.node_resolver = self._resolve_node_state
        self._branch_view_needed = True

    def _rebuild_leaf_index(self):
        """Worker -> leaf / inner-ancestor maps for branch-level state
        rows (leaf rows are refreshed eagerly; inner-node rows resolve
        lazily through ``_resolve_node_state``)."""
        self._leaf_members = {}
        self._leaf_of = {}
        self._node_workers = {}
        ancestors: Dict[str, set] = {}

        def walk(node, path):
            if node.is_leaf:
                self._leaf_members[node.name] = list(node.workers)
                for w in node.workers:
                    self._leaf_of[w] = node.name
                    ancestors.setdefault(w, set()).update(path)
                return
            self._node_workers[node.name] = node.all_workers()
            for c in node.children:
                walk(c, path + [node.name])
        walk(self.tree, [])
        self._worker_ancestors = {w: sorted(a) for w, a in ancestors.items()}
        self._node_dirty = set(self._node_workers)
        self._node_cache = {}

    def _aggregate_state(self, name: str, members) -> WorkerState:
        """One aggregated WorkerState row over a set of *live* workers so
        stateful branch-level policies (deadline_aware) can score whole
        leaf branches: sums for queue/inflight/capacity, unions for warm
        sets, and the *best* free memory (a cold start needs one worker
        that fits, not average headroom). Inner-node rows use the
        row-based (staleness-respecting) variant in
        ``_resolve_node_state``."""
        q = infl = cap = 0
        qd: Dict[str, int] = {}
        fs: Dict[str, int] = {}
        warm: set = set()
        healthy = False
        mem = 0.0
        for wname in members:
            w = self.workers.get(wname)
            if w is None:
                continue
            q += len(w.queue)
            infl += w.inflight()
            cap += w.slots_total()
            if not w.healthy:
                continue
            healthy = True
            mem = max(mem, w.mem_free_mb())
            warm.update(w.warm_fns())
            for fn, n in w.queue.depths().items():
                qd[fn] = qd.get(fn, 0) + n
            for fn, n in w.fn_free_slots(self.now).items():
                fs[fn] = fs.get(fn, 0) + n
        return WorkerState(
            worker=name, queue_len=q, inflight=infl, capacity=cap,
            warm_fns=frozenset(warm), healthy=healthy, fn_queue=qd,
            fn_free_slots=fs, mem_free_mb=mem)

    def _refresh_branch_view(self, leaf: str):
        self.view.update(
            self._aggregate_state(leaf, self._leaf_members.get(leaf, ())),
            self.now)

    def _resolve_node_state(self, name: str, t: float):
        """StateView fallback for *inner* (non-leaf) node names: deeper
        trees route deadline_aware above the leaf level too, and those
        nodes have no eagerly-refreshed row. Aggregates the members'
        per-worker *view rows* — not live workers — so upper-level
        scoring sees exactly the staleness the StateView models; cached
        until a member refreshes (dirty-tracked in ``_refresh_view``) or
        the stale snapshot rotates. 2-level trees, whose scored children
        are all leaves, never pay for any of this."""
        members = self._node_workers.get(name)
        if members is None:
            return None
        if (self.view.staleness_s > 0
                and self._node_cache_stale_t != self.view._stale_t):
            self._node_cache.clear()        # stale snapshot rotated
            self._node_cache_stale_t = self.view._stale_t
        if name in self._node_dirty or name not in self._node_cache:
            q = infl = cap = 0
            qd: Dict[str, int] = {}
            fs: Dict[str, int] = {}
            warm: set = set()
            healthy = False
            mem = 0.0
            for wname in members:
                ws = self.view.get(wname, t)   # staleness-respecting row
                q += ws.queue_len
                infl += ws.inflight
                cap += ws.capacity
                if not ws.healthy:
                    continue
                healthy = True
                mem = max(mem, ws.mem_free_mb)
                warm.update(ws.warm_fns)
                for fn, n in ws.fn_queue.items():
                    qd[fn] = qd.get(fn, 0) + n
                for fn, n in ws.fn_free_slots.items():
                    fs[fn] = fs.get(fn, 0) + n
            self._node_cache[name] = WorkerState(
                worker=name, queue_len=q, inflight=infl, capacity=cap,
                warm_fns=frozenset(warm), healthy=healthy, fn_queue=qd,
                fn_free_slots=fs, mem_free_mb=mem)
            self._node_dirty.discard(name)
        return self._node_cache[name]

    # ------------------------------------------------------------ placement
    def _log_placement(self, kind: str, w: _Worker, fn: str) -> None:
        cap = "inf" if w.memory_mb is None else f"{w.memory_mb:.0f}"
        self.placement_records.append(
            f"t={self.now:.6f} {kind} fn={fn} worker={w.name} "
            f"mem={w.memory_used_mb:.0f}/{cap} inst={w.total_instances}")

    def placement_log(self) -> str:
        """Byte-stable placement decision log (``record_decisions=True``):
        one line per replica start/reap/idle-stop, in event order."""
        return "\n".join(self.placement_records)

    def routing_log(self) -> str:
        """Byte-stable routing decision log (``record_decisions=True``):
        one line per arrival/reroute with the worker the tree chose."""
        return "\n".join(self.routing_records)

    def place_prewarm(self, fn: str) -> Optional[str]:
        """Start one replica of ``fn`` on the worker the placer picks —
        the autoscaler's scale-up entry into the placement layer.

        Candidates are offered coldest-in-``fn`` first (fewest replicas
        of the function, then fewest instances overall, then name — the
        deterministic preference order the control loop always used);
        the placer bin-packs within that order. Returns the worker name,
        or None when no worker has memory/instance headroom."""
        cfg = self.store.get(fn)
        cands = sorted(
            (self.workers[n] for n in self._worker_list
             if n in self.workers),
            key=lambda w: (w.fn_replicas(fn), w.total_instances, w.name))
        for w in self.placer.place_order(fn, cfg.memory_mb, cands):
            if self.prewarm(w.name, fn):
                return w.name
        return None

    def place_reap(self, fn: str) -> Optional[str]:
        """Stop one idle replica of ``fn`` off the worker the placer
        picks (warmest-in-``fn`` candidates first) — the scale-down
        mirror of :meth:`place_prewarm`. Returns the worker name, or
        None when no worker holds an idle ready replica."""
        cands = sorted(
            (self.workers[n] for n in self._worker_list
             if n in self.workers),
            key=lambda w: (-w.fn_replicas(fn), w.name))
        for w in self.placer.reap_order(fn, cands):
            if self.reap(w.name, fn):
                return w.name
        return None

    def attach_autoscaler(self, scaler, *, first_tick_s: float = None):
        """Bind an ``repro.autoscale.Autoscaler`` and schedule its periodic
        ``autoscale_tick`` control-loop event. Ticks re-arm themselves only
        while other events remain, so ``run()`` still terminates."""
        self.autoscaler = scaler
        t0 = self.now + (scaler.interval_s if first_tick_s is None
                         else first_tick_s)
        self._push(t0, "autoscale_tick", None)
        return scaler

    def fn_cost(self, fn: str) -> float:
        if fn not in self._fn_cost:
            from repro.configs import get_config
            try:
                arch = self.store.get(fn).arch
                self._fn_cost[fn] = get_config(arch).param_count() / 1e7
            except Exception:
                self._fn_cost[fn] = 1.0
        return self._fn_cost[fn]

    def load(self, workload) -> int:
        """Submit every request of a ``repro.workloads`` workload;
        returns the request count."""
        return workload.submit_to(self)

    # ---------------------------------------------------------------- run
    def run(self, until: Optional[float] = None):
        while self._events:
            t, seq, kind, payload = heapq.heappop(self._events)
            if until is not None and t > until:
                # re-queue so a later run() resumes without losing the event
                heapq.heappush(self._events, (t, seq, kind, payload))
                break
            if kind != "autoscale_tick":
                self._pending_real -= 1
            self.now = t
            self.events_processed += 1
            getattr(self, f"_on_{kind}")(payload)
        return self.results

    # ------------------------------------------------------------- events
    def _refresh_view(self, w: _Worker):
        if self._fn_view_needed:     # only per-fn routing pays for the dicts
            state = WorkerState(
                worker=w.name, queue_len=len(w.queue), inflight=w.inflight(),
                capacity=w.slots_total(), warm_fns=w.warm_fns(),
                healthy=w.healthy, fn_queue=w.queue.depths(),
                fn_free_slots=w.fn_free_slots(self.now),
                mem_free_mb=w.mem_free_mb())
        else:
            state = WorkerState(
                worker=w.name, queue_len=len(w.queue), inflight=w.inflight(),
                capacity=w.slots_total(), warm_fns=w.warm_fns(),
                healthy=w.healthy)
        self.view.update(state, self.now)
        if self._branch_view_needed:
            leaf = self._leaf_of.get(w.name)
            if leaf is not None:
                self._refresh_branch_view(leaf)
            anc = self._worker_ancestors.get(w.name)
            if anc:
                self._node_dirty.update(anc)

    def _on_autoscale_tick(self, _payload):
        if self.autoscaler is None:
            return
        self.autoscaler.on_tick(self)
        if self._pending_real > 0:      # re-arm only while real work remains
            self._push(self.now + self.autoscaler.interval_s,
                       "autoscale_tick", None)

    def _on_arrival(self, req: Request):
        self.arrivals_seen += 1
        self.arrivals_by_fn[req.fn] = self.arrivals_by_fn.get(req.fn, 0) + 1
        # healthy set is tracked incrementally; the full list is only
        # materialised on the rare stale-routing re-roll (the seed built
        # it on every arrival: O(fleet) on the hottest event)
        if self._healthy_count == 0:
            self._record_fail(req, "no healthy workers")
            return
        if (self.view.estimator is not None
                and req.fn not in self.view.fn_memory):
            # deadline routing needs the fn's footprint to spot workers
            # where a cold start is memory-blocked
            self.view.fn_memory[req.fn] = self.store.get(req.fn).memory_mb
        wid, hops = self.tree.route(req, self.view, self.rng, self.now)
        if not self.workers[wid].healthy:          # stale routing: re-roll
            healthy = [w for w in self._worker_list
                       if self.workers[w].healthy]
            wid = self.rng.choice(healthy)
        if self._record:
            self.routing_records.append(
                f"t={self.now:.6f} arrival rid={req.rid} fn={req.fn} "
                f"worker={wid}")
        w = self.workers[wid]
        cfg = self.store.get(req.fn)
        self.telemetry.append(TelemetryRecord(
            fn=req.fn, t=self.now, queue_len=len(w.queue),
            inflight=w.inflight(), batch_size=0, cold=False,
            prompt_tokens=req.size, gen_tokens=cfg.gen_tokens,
            fn_cost=self.fn_cost(req.fn), latency=0.0, ok=True))
        req._telemetry_idx = len(self.telemetry) - 1
        req._worker = wid
        self._push(self.now + self.hop_s * hops, "enqueue", req)
        if self.hedge_after_s is not None and req.hedged_from is None:
            self._push(self.now + self.hedge_after_s, "maybe_hedge", req)

    def _on_enqueue(self, req: Request):
        w = self.workers.get(req._worker)
        if w is None:                   # branch removed mid-hop: re-route
            self._on_reroute(req)
            return
        if not w.healthy:
            self._record_fail(req, "worker died")
            return
        w.queue.push(req, self.store.get(req.fn).timeout_s)
        self._dispatch(w)

    def _on_reroute(self, req: Request):
        """Send a displaced request (its worker's branch was removed)
        through the shrunk tree. Unlike an arrival this reuses the
        request's telemetry record and hedge timer — it is the same
        request, not new offered load."""
        if self._healthy_count == 0:
            self._record_fail(req, "no healthy workers")
            return
        wid, hops = self.tree.route(req, self.view, self.rng, self.now)
        if not self.workers[wid].healthy:          # stale routing: re-roll
            healthy = [w for w in self._worker_list
                       if self.workers[w].healthy]
            wid = self.rng.choice(healthy)
        if self._record:
            self.routing_records.append(
                f"t={self.now:.6f} reroute rid={req.rid} fn={req.fn} "
                f"worker={wid}")
        req._worker = wid
        self._push(self.now + self.hop_s * hops, "enqueue", req)

    def _on_maybe_hedge(self, req: Request):
        if req.rid in self._finished:
            return
        clone = Request(fn=req.fn, arrival_t=self.now, payload=req.payload,
                        size=req.size, hedged_from=req.rid,
                        deadline_t=req.deadline_t)
        self._on_arrival(clone)

    def _on_fail(self, worker: str):
        w = self.workers.get(worker)
        if w is None:                   # branch already scaled away
            self._draining.pop(worker, None)
            return
        if w.healthy:
            self._healthy_count -= 1
        w.healthy = False
        for req in w.queue.drain_all():
            self._record_fail(req, "worker died")
        w.clear_instances()
        self._refresh_view(w)

    def _on_recover(self, worker: str):
        w = self.workers.get(worker)
        if w is None:
            return
        if not w.healthy:
            self._healthy_count += 1
        w.healthy = True
        self._refresh_view(w)

    # ----------------------------------------------------- worker mechanics
    def _dispatch(self, w: _Worker):
        """Serve a worker's backlog through the per-function index.

        Queue timeouts are flushed from the deadline heap (the flat scan
        checked every queued request each pass; the heap surfaces exactly
        the expired ones, in the same arrival order). Then only functions
        that can make progress are merge-scanned by global arrival
        sequence, so a saturated function's whole backlog is skipped in
        O(1) while cross-function service order — and hence the service
        model's RNG stream — matches the flat scan byte-for-byte.
        """
        if not w.healthy:
            return
        # the flat scan passed the pre-scan queue length to the service
        # model (the list was only compacted afterwards) — preserve that
        qlen_at_scan = len(w.queue)
        if w.queue.has_expired(self.now):
            for req in w.queue.pop_expired(self.now):
                self._record_fail(req, "queue timeout")
        if len(w.queue):
            self._merge_scan(w, qlen_at_scan)
        self._refresh_view(w)

    def _merge_scan(self, w: _Worker, qlen_at_scan: int):
        now = self.now
        q = w.queue
        active = q.active_fns()
        if len(active) == 1:           # overwhelmingly common: no merge
            self._scan_one_fn(w, active[0], qlen_at_scan)
            return
        # per-fn scan state: [cfg, warming-free slots, kept prefix].
        # Warming free slots are counted up front (as the flat scan did):
        # queued requests wait on those before spawning more replicas
        # (c=1 instances expose 0 extra slots, so Lambda-style
        # one-instance-per-request behaviour is preserved). Free ready
        # slots, warming slots, and instance-start headroom only shrink
        # during the scan, so one fully-failed attempt proves every later
        # same-fn attempt fails too: the function drops out of the merge.
        state: dict = {}
        heap = []
        for fn in active:
            head = q.scan_head(fn)
            if head is None:
                continue
            rs = w.replica_sets.get(fn)
            state[fn] = [self.store.get(fn), rs.warming_free(now)
                         if rs is not None else 0, []]
            heap.append((head._wseq, fn))
        heapq.heapify(heap)
        while heap:
            _, fn = heapq.heappop(heap)
            st = state[fn]
            cfg, kept = st[0], st[2]
            req = q.scan_head(fn)
            q.pop_head(fn)
            rs = w.replica_sets.get(fn)
            inst = rs.pick(now) if rs is not None else None
            saturated = False
            if inst is not None:
                q.mark_served(req)
                self._start_service(w, inst, req, cfg, qlen_at_scan)
            elif st[1] > 0:
                st[1] -= 1                  # wait on a warming instance
                self._poke(w, rs.next_ready_after(now))
                kept.append(req)
            else:
                started = self._maybe_start_instance(w, cfg)
                if started is None:
                    kept.append(req)
                    saturated = True
                    self._maybe_poke_timeout(w, req, cfg)
                elif started.ready_t <= now:
                    # instant start (explicit cold_start_s=0.0): the new
                    # replica is ready capacity, not warming — serve on
                    # it directly (counting it as warming would strand a
                    # later request waiting on a next_ready that never
                    # comes)
                    q.mark_served(req)
                    self._start_service(w, started, req, cfg, qlen_at_scan)
                else:
                    st[1] += (started.slots if started.slots > 0
                              else UNLIMITED_SLOTS) - 1
                    self._poke(w, started.ready_t)
                    kept.append(req)
            if not saturated:
                head = q.scan_head(fn)
                if head is not None:
                    heapq.heappush(heap, (head._wseq, fn))
        for fn, st in state.items():
            q.restore(fn, st[2])

    def _scan_one_fn(self, w: _Worker, fn: str, qlen_at_scan: int):
        """Heap-free scan when a single function holds all queued work —
        FIFO order *is* global order, so semantics match the merge."""
        now = self.now
        q = w.queue
        cfg = self.store.get(fn)
        rs = w.replica_sets.get(fn)
        warming = rs.warming_free(now) if rs is not None else 0
        kept = []
        while True:
            req = q.scan_head(fn)
            if req is None:
                break
            q.pop_head(fn)
            inst = rs.pick(now) if rs is not None else None
            if inst is not None:
                q.mark_served(req)
                self._start_service(w, inst, req, cfg, qlen_at_scan)
                continue
            if warming > 0:
                warming -= 1                # wait on a warming instance
                self._poke(w, rs.next_ready_after(now))
                kept.append(req)
                continue
            started = self._maybe_start_instance(w, cfg)
            if started is None:
                kept.append(req)
                self._maybe_poke_timeout(w, req, cfg)
                break                       # saturated: rest stays queued
            rs = w.replica_sets[fn]         # created on first start
            if started.ready_t <= now:
                # instant start (explicit cold_start_s=0.0): ready
                # capacity, not warming — serve the trigger directly
                q.mark_served(req)
                self._start_service(w, started, req, cfg, qlen_at_scan)
                continue
            warming += (started.slots if started.slots > 0
                        else UNLIMITED_SLOTS) - 1
            self._poke(w, started.ready_t)
            kept.append(req)
        q.restore(fn, kept)

    def _maybe_poke_timeout(self, w: _Worker, req: Request, cfg) -> None:
        """A start refused for *memory* can be blocked permanently (no
        finish/idle event need ever touch this worker again), which would
        strand the queued request without even its timeout failure. Poke
        the worker just past the request's queue deadline so the flush
        runs. Slot-saturation refusals are excluded: they always clear
        through a finish, and uncapped runs must stay byte-identical to
        the pre-placement simulator."""
        if not w.fits(cfg.memory_mb):
            self._poke(w, req.arrival_t + cfg.timeout_s + 1e-6)

    def _poke(self, w: "_Worker", t: float):
        key = round(t, 9)
        if key not in w.poke_times:
            w.poke_times.add(key)
            self._push(t, "poke", w.name)

    def _on_poke(self, worker: str):
        w = self.workers.get(worker)
        if w is None:
            return
        w.poke_times.discard(round(self.now, 9))
        self._dispatch(w)

    def _maybe_start_instance(self, w: _Worker, cfg) -> Optional[Instance]:
        rs = w.replica_sets.get(cfg.name)
        if ((rs is not None and len(rs) >= cfg.max_instances_per_worker)
                or w.total_instances >= w.capacity_slots
                or not w.fits(cfg.memory_mb)):   # placement memory admission
            return None
        # an explicitly configured cold_start_s=0.0 means *instant*, only
        # an unset (None) config falls back to the platform default
        cold = (cfg.cold_start_s if cfg.cold_start_s is not None
                else self.cold_default)
        inst = Instance(iid=f"{w.name}/i{next(self._iid)}", fn=cfg.name,
                        slots=cfg.concurrency,
                        ready_t=self.now + cold * w.slowdown,
                        last_used=self.now,
                        memory_mb=cfg.memory_mb)
        w.add_instance(inst)
        w.cold_starts += 1
        w.instances_started += 1
        self.cold_starts_total += 1
        if self._record:
            self._log_placement("start", w, cfg.name)
        return inst

    def _start_service(self, w: _Worker, inst: Instance, req: Request, cfg,
                       queue_len: int):
        w.note_busy(inst, +1)
        inst.last_used = self.now
        cold = inst.ready_t > req.arrival_t
        dur, ok = self.model.sample(
            cfg, batch_size=inst.busy, queue_len=queue_len,
            prompt=req.size, cold=cold, fn_cost=self.fn_cost(req.fn))
        dur *= w.slowdown
        # unlimited concurrency: utilization-triggered replica pre-start
        if cfg.concurrency == 0:
            util = inst.busy / max(cfg.max_instances_per_worker, 1)
            if util > cfg.util_scale_threshold:
                self._maybe_start_instance(w, cfg)
        rec = self.telemetry[req._telemetry_idx]
        rec.batch_size = inst.busy
        rec.cold = cold
        self._push(self.now + dur, "finish",
                   (req, w.name, inst.iid, cold, self.now, ok))
        w.busy_time += dur

    def _on_finish(self, payload):
        req, wname, iid, cold, start_t, ok = payload
        draining = wname not in self.workers
        # a drained-and-retired (or failed-then-removed) worker may be gone
        # entirely; the result below must still be recorded either way
        w = self._draining.get(wname) if draining else self.workers[wname]
        inst = w.iid_index.get(iid) if w is not None else None
        if inst is not None:               # O(1) via the iid index
            w.note_busy(inst, -1)
            inst.last_used = self.now
            self._push(self.now + self.store.get(req.fn).idle_timeout_s,
                       "idle_check", (wname, iid))
        if draining and w is not None and w.inflight() == 0:
            self._draining.pop(wname, None)   # retire even if hedge lost
        # rid 0 is falsy, so `or` would misattribute a hedge of request 0
        primary = req.hedged_from if req.hedged_from is not None else req.rid
        if primary in self._finished:
            return                       # hedge lost the race
        self._finished.add(primary)
        res = RequestResult(rid=primary, fn=req.fn, ok=ok,
                            arrival_t=req.arrival_t, start_t=start_t,
                            finish_t=self.now, cold_start=cold,
                            worker=wname, instance=iid)
        self.results.append(res)
        if self.view.estimator is not None and ok:
            # deadline routing prices backlogs with this windowed
            # observation; fed in result order, so it is deterministic
            self.view.estimator.observe(req.fn, res.service_time)
        rec = self.telemetry[req._telemetry_idx]
        rec.latency = res.latency
        rec.ok = ok
        if draining:                     # already retired above if empty
            return
        self._dispatch(w)

    def _on_idle_check(self, payload):
        wname, iid = payload
        w = self.workers.get(wname)
        if w is None:
            # branch scaled away meanwhile, or the worker is draining in
            # self._draining: draining workers only finish in-flight work,
            # they never reap (pinned by tests/test_core_platform.py)
            return
        inst = w.iid_index.get(iid)        # O(1) via the iid index
        if (inst is not None and inst.busy == 0 and
                self.now - inst.last_used >=
                self.store.get(inst.fn).idle_timeout_s - 1e-9):
            w.remove_instance(inst)
            if self._record:
                self._log_placement("idle", w, inst.fn)
            if len(w.queue) > 0:
                # the freed capacity slot may unblock another function's
                # backlog (the seed left such work stranded until the
                # next unrelated enqueue/finish — or forever)
                self._dispatch(w)
                return
        self._refresh_view(w)

    def _record_fail(self, req: Request, err: str):
        primary = req.hedged_from if req.hedged_from is not None else req.rid
        if primary in self._finished:
            return
        self._finished.add(primary)
        self.results.append(RequestResult(
            rid=primary, fn=req.fn, ok=False, arrival_t=req.arrival_t,
            start_t=self.now, finish_t=self.now, cold_start=False,
            worker=getattr(req, "_worker", "?"), instance="-", error=err))


# ---------------------------------------------------------------------------
# Load generation + metrics
# ---------------------------------------------------------------------------

def poisson_load(sim: Simulator, *, fn: str, rps: float, duration_s: float,
                 prompt_tokens: int = 16, seed: int = 1):
    """Legacy single-function Poisson driver; now a thin shim over the
    workload subsystem (``repro.workloads``). ``rid_base=None`` keeps the
    process-global request-id counter this entry point always used."""
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)
    wl = MixedWorkload(
        PoissonArrivals(rps),
        [FunctionProfile(fn, size=SizeDist.const(prompt_tokens))],
        duration_s=duration_s, seed=seed, rid_base=None)
    return sim.load(wl)


def summarize(results: List[RequestResult]) -> dict:
    import numpy as np
    if not results:
        return {"n": 0}
    lat = np.array([r.latency for r in results if r.ok])
    ok = sum(r.ok for r in results)
    # throughput over the makespan, not absolute finish time: a run whose
    # first arrival is at t0 > 0 (daily_cycle offsets, resumed run(until))
    # must not have its rate diluted by the empty [0, t0) prefix
    makespan = (max(r.finish_t for r in results)
                - min(r.arrival_t for r in results))
    return {
        "n": len(results), "ok": ok, "fail_rate": 1 - ok / len(results),
        "cold_rate": sum(r.cold_start for r in results) / len(results),
        "p50": float(np.percentile(lat, 50)) if len(lat) else float("nan"),
        "p95": float(np.percentile(lat, 95)) if len(lat) else float("nan"),
        "p99": float(np.percentile(lat, 99)) if len(lat) else float("nan"),
        "mean": float(lat.mean()) if len(lat) else float("nan"),
        "throughput": ok / max(makespan, 1e-9),
    }

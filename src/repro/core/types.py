"""Shared types for the HyperFaaS platform layer (paper Fig. 1 vocabulary)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

_req_ids = itertools.count()


@dataclass(frozen=True)
class FunctionConfig:
    """What the paper's config store holds per function.

    ``concurrency`` is RQ-A's within-instance concurrency policy:
      1   -> AWS-Lambda-style (one request per instance)
      k>1 -> Knative-style hard limit
      0   -> Azure/GCF-style "unlimited": requests pack into the instance and
             resource-based scaling adds replicas when utilization trips.
    """
    name: str
    arch: str                          # key into the image registry
    concurrency: int = 1
    timeout_s: float = 30.0            # request timeout (failure beyond this)
    idle_timeout_s: float = 10.0       # instance stop after idleness
    # None => platform default (simulator's cold_start_default_s; the real
    # engine measures compile+load). An explicit 0.0 means *instant* —
    # the seed's falsy-or check silently replaced it with the default.
    cold_start_s: Optional[float] = None
    memory_mb: int = 512
    max_instances_per_worker: int = 8
    util_scale_threshold: float = 0.8  # "unlimited" mode replica trigger
    gen_tokens: int = 8                # tokens generated per invocation (LM fns)


@dataclass
class Request:
    fn: str
    arrival_t: float
    payload: Any = None
    size: int = 16                     # prompt tokens (cost driver)
    rid: int = field(default_factory=lambda: next(_req_ids))
    hedged_from: Optional[int] = None  # straggler-mitigation clone marker
    # gateway priority class ("interactive" | "batch"), stamped from
    # FunctionProfile.priority by the workload layer; None falls back to
    # the tenant quota's class at the front door (core/gateway.py)
    priority: Optional[str] = None
    # absolute completion deadline (arrival + the function's slo_p95_s —
    # or, for a workflow stage, the stage's share of the end-to-end
    # workflow SLO), stamped by the workload layer; None => no latency
    # objective. deadline_aware routing scores branches against the
    # remaining slack.
    deadline_t: Optional[float] = None
    # ---- workflow identity (repro.workloads.workflows) --------------
    # None/False for plain invocations: a request that is one stage task
    # of a composed workflow carries its DAG context so workflow_aware
    # routing can see the critical path.
    wf: Optional[int] = None           # workflow instance id
    stage: Optional[str] = None        # stage name within the DAG
    wf_task: int = 0                   # task index within the stage fan-out
    wf_critical: bool = False          # stage lies on the DAG critical path
    # (worker, leaf-branch) that served the completion triggering this
    # stage — the co-location target for chained stages
    wf_affinity: Optional[tuple] = None


@dataclass
class RequestResult:
    rid: int
    fn: str
    ok: bool
    arrival_t: float
    start_t: float                     # service start (after queue + cold)
    finish_t: float
    cold_start: bool
    worker: str
    instance: str
    error: str = ""
    # workflow identity carried through from the request (None for
    # plain invocations) — lets analysis group stage tasks per instance
    wf: Optional[int] = None
    stage: Optional[str] = None

    @property
    def latency(self) -> float:
        return self.finish_t - self.arrival_t

    @property
    def service_time(self) -> float:
        return self.finish_t - self.start_t


@dataclass
class TelemetryRecord:
    """One row of the RQ-B worker-model training set (paper Fig. 2 step 1)."""
    fn: str
    t: float
    queue_len: int                     # worker queue at arrival
    inflight: int                      # busy slots at arrival
    batch_size: int                    # slot occupancy of the serving instance
    cold: bool
    prompt_tokens: int
    gen_tokens: int
    fn_cost: float                     # static per-token cost proxy (params)
    latency: float
    ok: bool

    def features(self):
        return [self.queue_len, self.inflight, self.batch_size,
                1.0 if self.cold else 0.0, self.prompt_tokens,
                self.gen_tokens, self.fn_cost]

    FEATURE_NAMES = ("queue_len", "inflight", "batch_size", "cold",
                     "prompt_tokens", "gen_tokens", "fn_cost")

"""The worker runtime: one node's execution mechanics, behind a protocol.

Splitting this out of the simulator is the paper's architecture-swapping
requirement applied to our own testbed: the event engine
(``repro.core.events``), the worker runtime (this module), and the
control plane (``repro.autoscale.control``) are separate layers with
narrow interfaces, so any one can be replaced without touching the
others.

Two pieces live here:

- :class:`Worker` — one node's *state*: per-function replica sets
  (``FunctionReplicaSet``), the per-function queue index (``FnQueues``),
  and the incrementally tracked memory / busy-slot / slots-total
  counters the placement layer and routers read in O(1).
- :class:`WorkerRuntime` — one node's *mechanics*: backlog dispatch
  (merge-scan by global arrival order), memory/instance admission,
  service start, service completion, and idle reaping. The runtime
  drives workers but owns no global state; everything global is read
  through the :class:`SimContext` protocol below.

``SimContext`` (duck-typed; ``repro.core.simulator.Simulator`` is the
one implementation) must provide:

==================  ======================================================
``now``             current virtual time
``store``           the function ``ConfigStore``
``model``           service-time model (``sample(cfg, ...)``)
``workers``         live name -> :class:`Worker` map
``_draining``       removed-but-finishing name -> :class:`Worker` map
``cold_default``    platform cold-start default (s)
``cold_starts_total``  run-wide cold-start counter
``results`` / ``telemetry`` / ``_finished``  result-recording surface
``view``            the router ``StateView`` (estimator feed)
``fn_cost(fn)``     static per-token cost proxy
``_push(t, kind, payload)``  schedule an event on the event engine
``_record_fail(req, err)``   record a failed request
``_refresh_view(w)``         publish a worker's state row
``faults``          the chaos layer (``repro.core.faults``) or None;
                    consulted at service start for lost completions
``_dispatch(w)`` / ``_maybe_start_instance(w, cfg)`` /
``_start_service(w, inst, req, cfg, queue_len)`` / ``_poke(w, t)``
                    re-entry hooks — the runtime always re-enters
                    through the simulator-level methods (which delegate
                    straight back here) so tests and custom platforms
                    can intercept them in one place
``control``         the control plane (placement decision logging)
==================  ======================================================

Byte-identity contract: this is a *move*, not a rewrite — dispatch
order, RNG consumption, and every counter update are exactly the
pre-split simulator's, pinned by the golden digests in
``tests/test_scheduling.py`` / ``tests/test_placement.py``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional

from repro.core.scheduling import (UNLIMITED_SLOTS, FnQueues,
                                   FunctionReplicaSet, Instance)
from repro.core.types import Request


class Worker:
    """One node: per-function replica sets + per-function FIFO queues,
    indexed so every hot-path read is O(affected function). Memory and
    slot totals are tracked incrementally (never recomputed by scanning
    instances) so the placement layer and ``slots_total`` are O(1)."""

    def __init__(self, name: str, capacity_slots: int = 16,
                 memory_mb: Optional[float] = None):
        self.name = name
        self.capacity_slots = capacity_slots   # hardware concurrency of node
        self.memory_mb = memory_mb             # replica memory cap (None=inf)
        self.memory_used_mb = 0.0              # incremental footprint
        self.slowdown = 1.0                    # straggler factor
        self.healthy = True
        self.zone = None                       # failure domain (zones=...)
        self.replica_sets: Dict[str, FunctionReplicaSet] = {}
        self.iid_index: Dict[str, Instance] = {}   # iid -> live instance
        self.total_instances = 0
        self._inflight = 0                 # incremental busy-slot count
        self._slots_total = 0              # incremental slots_total counter
        self.queue = FnQueues()
        self.busy_time = 0.0
        self.cold_starts = 0
        self.instances_started = 0
        self.poke_times: set = set()       # dedupe scheduled pokes

    @property
    def instances(self) -> Dict[str, List[Instance]]:
        """Legacy fn -> instance-list view (tests/examples read this)."""
        return {fn: rs.instances for fn, rs in self.replica_sets.items()
                if rs.instances}

    @staticmethod
    def _slot_contrib(inst: Instance) -> int:
        # an unlimited-concurrency instance (slots == 0) counts its live
        # occupancy (min 1) — matches the old flat recomputation exactly
        return inst.slots if inst.slots > 0 else max(inst.busy, 1)

    def add_instance(self, inst: Instance) -> None:
        rs = self.replica_sets.get(inst.fn)
        if rs is None:
            rs = self.replica_sets[inst.fn] = FunctionReplicaSet(inst.fn)
        rs.add(inst)
        self.iid_index[inst.iid] = inst
        self.total_instances += 1
        self.memory_used_mb += inst.memory_mb
        self._slots_total += self._slot_contrib(inst)

    def remove_instance(self, inst: Instance) -> None:
        self.replica_sets[inst.fn].discard(inst)
        self.iid_index.pop(inst.iid, None)
        self.total_instances -= 1
        self.memory_used_mb -= inst.memory_mb
        self._slots_total -= self._slot_contrib(inst)

    def clear_instances(self) -> None:
        self.replica_sets.clear()
        self.iid_index.clear()
        self.total_instances = 0
        self.memory_used_mb = 0.0
        self._inflight = 0
        self._slots_total = 0

    def note_busy(self, inst: Instance, delta: int) -> None:
        """Move an instance's busy count, keeping ``_slots_total`` exact:
        a slots==0 instance contributes ``max(busy, 1)``, so its share
        shifts as occupancy changes."""
        self._inflight += delta
        if inst.slots > 0:
            inst.busy += delta
            return
        before = max(inst.busy, 1)
        inst.busy += delta
        self._slots_total += max(inst.busy, 1) - before

    def fits(self, memory_mb: float) -> bool:
        """Memory admission for one more ``memory_mb`` replica."""
        return (self.memory_mb is None
                or self.memory_used_mb + memory_mb <= self.memory_mb + 1e-9)

    def mem_free_mb(self) -> float:
        return (float("inf") if self.memory_mb is None
                else self.memory_mb - self.memory_used_mb)

    def fn_replicas(self, fn: str) -> int:
        rs = self.replica_sets.get(fn)
        return len(rs.instances) if rs is not None else 0

    def warm_fns(self) -> frozenset:
        return frozenset(fn for fn, rs in self.replica_sets.items()
                         if rs.instances)

    def inflight(self) -> int:
        return self._inflight

    def slots_total(self) -> int:
        return self._slots_total or 1

    def fn_free_slots(self, now: float) -> Dict[str, int]:
        """Per-function immediately-usable warm slots (router signal)."""
        return {fn: rs.ready_free_slots(now)
                for fn, rs in self.replica_sets.items() if rs.instances}


class WorkerRuntime:
    """Backlog dispatch, admission, service start/completion for workers.

    Owns no global state: time, the config store, the service model, and
    event scheduling are all reached through the ``SimContext`` protocol
    (see module docstring). The simulator's ``_dispatch`` /
    ``_maybe_start_instance`` / ``_start_service`` methods are thin
    delegates onto this class, and the runtime deliberately *re-enters
    through them* for every nested call so a monkeypatch (or subclass
    override) of the simulator-level hook intercepts every path.
    """

    def __init__(self, sim):
        self.sim = sim

    # ------------------------------------------------------------ dispatch
    def enqueue(self, req: Request) -> None:
        sim = self.sim
        w = sim.workers.get(req._worker)
        if w is None:                   # branch removed mid-hop: re-route
            sim._on_reroute(req)
            return
        if not w.healthy:
            sim._record_fail(req, "worker died")
            return
        w.queue.push(req, sim.store.get(req.fn).timeout_s)
        sim._dispatch(w)

    def dispatch(self, w: Worker) -> None:
        """Serve a worker's backlog through the per-function index.

        Queue timeouts are flushed from the deadline heap (the flat scan
        checked every queued request each pass; the heap surfaces exactly
        the expired ones, in the same arrival order). Then only functions
        that can make progress are merge-scanned by global arrival
        sequence, so a saturated function's whole backlog is skipped in
        O(1) while cross-function service order — and hence the service
        model's RNG stream — matches the flat scan byte-for-byte.
        """
        sim = self.sim
        if not w.healthy:
            return
        # the flat scan passed the pre-scan queue length to the service
        # model (the list was only compacted afterwards) — preserve that
        qlen_at_scan = len(w.queue)
        if w.queue.has_expired(sim.now):
            for req in w.queue.pop_expired(sim.now):
                sim._record_fail(req, "queue timeout")
        if len(w.queue):
            self._merge_scan(w, qlen_at_scan)
        sim._refresh_view(w)

    def _merge_scan(self, w: Worker, qlen_at_scan: int) -> None:
        sim = self.sim
        now = sim.now
        q = w.queue
        active = q.active_fns()
        if len(active) == 1:           # overwhelmingly common: no merge
            self._scan_one_fn(w, active[0], qlen_at_scan)
            return
        # per-fn scan state: [cfg, warming-free slots, kept prefix].
        # Warming free slots are counted up front (as the flat scan did):
        # queued requests wait on those before spawning more replicas
        # (c=1 instances expose 0 extra slots, so Lambda-style
        # one-instance-per-request behaviour is preserved). Free ready
        # slots, warming slots, and instance-start headroom only shrink
        # during the scan, so one fully-failed attempt proves every later
        # same-fn attempt fails too: the function drops out of the merge.
        state: dict = {}
        heap = []
        for fn in active:
            head = q.scan_head(fn)
            if head is None:
                continue
            rs = w.replica_sets.get(fn)
            state[fn] = [sim.store.get(fn), rs.warming_free(now)
                         if rs is not None else 0, []]
            heap.append((head._wseq, fn))
        heapq.heapify(heap)
        while heap:
            _, fn = heapq.heappop(heap)
            st = state[fn]
            cfg, kept = st[0], st[2]
            req = q.scan_head(fn)
            q.pop_head(fn)
            rs = w.replica_sets.get(fn)
            inst = rs.pick(now) if rs is not None else None
            saturated = False
            if inst is not None:
                q.mark_served(req)
                sim._start_service(w, inst, req, cfg, qlen_at_scan)
            elif st[1] > 0:
                st[1] -= 1                  # wait on a warming instance
                sim._poke(w, rs.next_ready_after(now))
                kept.append(req)
            else:
                started = sim._maybe_start_instance(w, cfg)
                if started is None:
                    kept.append(req)
                    saturated = True
                    self._maybe_poke_timeout(w, req, cfg)
                elif started.ready_t <= now:
                    # instant start (explicit cold_start_s=0.0): the new
                    # replica is ready capacity, not warming — serve on
                    # it directly (counting it as warming would strand a
                    # later request waiting on a next_ready that never
                    # comes)
                    q.mark_served(req)
                    sim._start_service(w, started, req, cfg, qlen_at_scan)
                else:
                    st[1] += (started.slots if started.slots > 0
                              else UNLIMITED_SLOTS) - 1
                    sim._poke(w, started.ready_t)
                    kept.append(req)
            if not saturated:
                head = q.scan_head(fn)
                if head is not None:
                    heapq.heappush(heap, (head._wseq, fn))
        for fn, st in state.items():
            q.restore(fn, st[2])

    def _scan_one_fn(self, w: Worker, fn: str, qlen_at_scan: int) -> None:
        """Heap-free scan when a single function holds all queued work —
        FIFO order *is* global order, so semantics match the merge."""
        sim = self.sim
        now = sim.now
        q = w.queue
        cfg = sim.store.get(fn)
        rs = w.replica_sets.get(fn)
        warming = rs.warming_free(now) if rs is not None else 0
        kept = []
        while True:
            req = q.scan_head(fn)
            if req is None:
                break
            q.pop_head(fn)
            inst = rs.pick(now) if rs is not None else None
            if inst is not None:
                q.mark_served(req)
                sim._start_service(w, inst, req, cfg, qlen_at_scan)
                continue
            if warming > 0:
                warming -= 1                # wait on a warming instance
                sim._poke(w, rs.next_ready_after(now))
                kept.append(req)
                continue
            started = sim._maybe_start_instance(w, cfg)
            if started is None:
                kept.append(req)
                self._maybe_poke_timeout(w, req, cfg)
                break                       # saturated: rest stays queued
            rs = w.replica_sets[fn]         # created on first start
            if started.ready_t <= now:
                # instant start (explicit cold_start_s=0.0): ready
                # capacity, not warming — serve the trigger directly
                q.mark_served(req)
                sim._start_service(w, started, req, cfg, qlen_at_scan)
                continue
            warming += (started.slots if started.slots > 0
                        else UNLIMITED_SLOTS) - 1
            sim._poke(w, started.ready_t)
            kept.append(req)
        q.restore(fn, kept)

    def _maybe_poke_timeout(self, w: Worker, req: Request, cfg) -> None:
        """A start refused for *memory* can be blocked permanently (no
        finish/idle event need ever touch this worker again), which would
        strand the queued request without even its timeout failure. Poke
        the worker just past the request's queue deadline so the flush
        runs. Slot-saturation refusals are excluded: they always clear
        through a finish, and uncapped runs must stay byte-identical to
        the pre-placement simulator."""
        if not w.fits(cfg.memory_mb):
            self.sim._poke(w, req.arrival_t + cfg.timeout_s + 1e-6)

    def poke(self, w: Worker, t: float) -> None:
        key = round(t, 9)
        if key not in w.poke_times:
            w.poke_times.add(key)
            self.sim._push(t, "poke", w.name)

    def on_poke(self, worker: str) -> None:
        sim = self.sim
        w = sim.workers.get(worker)
        if w is None:
            return
        w.poke_times.discard(round(sim.now, 9))
        sim._dispatch(w)

    # ----------------------------------------------------------- admission
    def maybe_start_instance(self, w: Worker, cfg) -> Optional[Instance]:
        sim = self.sim
        rs = w.replica_sets.get(cfg.name)
        if ((rs is not None and len(rs) >= cfg.max_instances_per_worker)
                or w.total_instances >= w.capacity_slots
                or not w.fits(cfg.memory_mb)):   # placement memory admission
            return None
        # an explicitly configured cold_start_s=0.0 means *instant*, only
        # an unset (None) config falls back to the platform default
        cold = (cfg.cold_start_s if cfg.cold_start_s is not None
                else sim.cold_default)
        inst = Instance(iid=sim._alloc_iid(w), fn=cfg.name,
                        slots=cfg.concurrency,
                        ready_t=sim.now + cold * w.slowdown,
                        last_used=sim.now,
                        memory_mb=cfg.memory_mb)
        w.add_instance(inst)
        w.cold_starts += 1
        w.instances_started += 1
        sim.cold_starts_total += 1
        if sim._record:
            sim.control.log_placement("start", w, cfg.name)
        return inst

    # ------------------------------------------------------------- service
    def start_service(self, w: Worker, inst: Instance, req: Request, cfg,
                      queue_len: int) -> None:
        sim = self.sim
        w.note_busy(inst, +1)
        inst.last_used = sim.now
        cold = inst.ready_t > req.arrival_t
        dur, ok = sim.model.sample(
            cfg, batch_size=inst.busy, queue_len=queue_len,
            prompt=req.size, cold=cold, fn_cost=sim.fn_cost(req.fn))
        dur *= w.slowdown
        # unlimited concurrency: utilization-triggered replica pre-start
        if cfg.concurrency == 0:
            util = inst.busy / max(cfg.max_instances_per_worker, 1)
            if util > cfg.util_scale_threshold:
                sim._maybe_start_instance(w, cfg)
        if sim.collect_telemetry:
            # a retried request that originally failed *before* routing
            # ("no healthy workers" at arrival) has no telemetry row
            idx = getattr(req, "_telemetry_idx", None)
            if idx is not None:
                rec = sim.telemetry[idx]
                rec.batch_size = inst.busy
                rec.cold = cold
        faults = sim.faults
        if faults is not None and faults.drop_finish(req, w):
            # chaos layer: the completion is lost — no finish event; the
            # slot stays busy until the fn timeout (see FaultInjector)
            faults.lose_completion(w, inst, req, cfg)
        else:
            sim._push(sim.now + dur, "finish",
                      (req, w.name, inst.iid, cold, sim.now, ok))
        w.busy_time += dur

    def finish(self, payload) -> None:
        """Service completion: free the slot, record the result, feed the
        estimator, and re-dispatch the freed capacity."""
        sim = self.sim
        req, wname, iid, cold, start_t, ok = payload
        draining = wname not in sim.workers
        # a drained-and-retired (or failed-then-removed) worker may be gone
        # entirely; the result below must still be recorded either way
        w = sim._draining.get(wname) if draining else sim.workers[wname]
        inst = w.iid_index.get(iid) if w is not None else None
        if inst is None and not draining:
            # the worker is live but the instance is gone: only a crash
            # (`clear_instances` in `_on_fail`) removes instances that
            # still hold busy slots — every reap path requires busy == 0,
            # and a pending finish pins busy ≥ 1. This completion died
            # with the worker; recording it as a success was the
            # in-flight-ok bug (a drained-then-retired worker, w is None
            # with draining=True, still completes below as before).
            sim._record_fail(req, "worker died")
            return
        if inst is not None:               # O(1) via the iid index
            w.note_busy(inst, -1)
            inst.last_used = sim.now
            sim._push(sim.now + sim.store.get(req.fn).idle_timeout_s,
                      "idle_check", (wname, iid))
        if draining and w is not None and w.inflight() == 0:
            sim._draining.pop(wname, None)   # retire even if hedge lost
        if not sim.record_result(req, start_t=start_t, ok=ok, cold=cold,
                                 worker=wname, instance=iid):
            return                       # hedge lost the race
        if draining:                     # already retired above if empty
            return
        sim._dispatch(w)

    def idle_check(self, payload) -> None:
        sim = self.sim
        wname, iid = payload
        w = sim.workers.get(wname)
        if w is None:
            # branch scaled away meanwhile, or the worker is draining in
            # sim._draining: draining workers only finish in-flight work,
            # they never reap (pinned by tests/test_core_platform.py)
            return
        inst = w.iid_index.get(iid)        # O(1) via the iid index
        if (inst is not None and inst.busy == 0 and
                sim.now - inst.last_used >=
                sim.store.get(inst.fn).idle_timeout_s - 1e-9):
            w.remove_instance(inst)
            if sim._record:
                sim.control.log_placement("idle", w, inst.fn)
            if len(w.queue) > 0:
                # the freed capacity slot may unblock another function's
                # backlog (the seed left such work stranded until the
                # next unrelated enqueue/finish — or forever)
                sim._dispatch(w)
        # always republish the view: dispatch refreshes it on success,
        # but an unhealthy-worker dispatch returns without refreshing —
        # the early return here used to leave routing blind to the reap
        sim._refresh_view(w)

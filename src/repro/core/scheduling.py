"""Per-function scheduling substrate for the simulator's workers.

The seed kept one flat request list and one ``Dict[fn, List[_Instance]]``
per worker, so every dispatch rescanned the whole backlog and every finish
searched every instance on the worker — O(worker) work per event. This
module is the indexed replacement:

- :class:`Instance` — one function replica (warming until ``ready_t``,
  then serving up to ``slots`` concurrent requests).
- :class:`FunctionReplicaSet` — the per-function replica index: ready
  pick, warming free-slot count, next-ready time, free-slot totals.
- :class:`FnQueues` — per-function FIFO queues with a worker-global
  arrival sequence (so cross-function dispatch order is preserved
  exactly) and a deadline heap (so queue timeouts are flushed without
  scanning the backlog).

Dispatch and finish become O(affected function) instead of O(worker):
the simulator merges only *dispatchable* functions by global sequence
number, skipping saturated functions' entire queues in O(1), and looks
instances up through an iid index. Semantics are unchanged — same seed
still yields byte-identical request results (pinned by
``tests/test_scheduling.py``). One documented exception: a request's
queue-timeout deadline is fixed from the ``FunctionConfig`` at enqueue
time, so re-``put()``-ing a config mid-run no longer retimes requests
already queued (the seed re-read the config at every scan).
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

UNLIMITED_SLOTS = 10 ** 9      # free-slot stand-in for slots == 0 instances


@dataclass
class Instance:
    """One replica of a function on a worker."""

    iid: str
    fn: str
    slots: int                 # 0 => unlimited (soft)
    busy: int = 0
    last_used: float = 0.0
    ready_t: float = 0.0       # cold start completes
    memory_mb: float = 0.0     # footprint charged against worker capacity

    def has_free_slot(self) -> bool:
        return self.busy < self.slots if self.slots > 0 else True

    def free_slots(self) -> int:
        return (self.slots if self.slots > 0 else UNLIMITED_SLOTS) - self.busy


class FunctionReplicaSet:
    """Replica index for one function on one worker.

    Keeps the instance list plus the per-function reads the dispatch hot
    path needs: densest ready pick, warming free slots, next ready time.
    Instance counts are bounded by the worker's capacity, so these scans
    are O(replicas-of-one-fn), never O(worker). The set also carries the
    function's aggregate memory footprint (``mem_mb``), maintained
    incrementally by :meth:`add`/:meth:`discard` so the placement layer
    never rescans instance lists to account memory.
    """

    __slots__ = ("fn", "instances", "mem_mb")

    def __init__(self, fn: str):
        self.fn = fn
        self.instances: List[Instance] = []
        self.mem_mb = 0.0          # sum of live replicas' memory_mb

    def __len__(self) -> int:
        return len(self.instances)

    def add(self, inst: Instance) -> None:
        self.instances.append(inst)
        self.mem_mb += inst.memory_mb

    def discard(self, inst: Instance) -> None:
        self.instances.remove(inst)
        self.mem_mb -= inst.memory_mb

    def pick(self, now: float) -> Optional[Instance]:
        """Ready instance with a free slot, packing densest first."""
        best = None
        for inst in self.instances:
            if inst.ready_t <= now and inst.has_free_slot():
                if best is None or inst.busy > best.busy:
                    best = inst
        return best

    def warming_free(self, now: float) -> int:
        """Free slots on instances still cold-starting."""
        return sum(i.free_slots() for i in self.instances if i.ready_t > now)

    def next_ready_after(self, now: float) -> Optional[float]:
        return min((i.ready_t for i in self.instances if i.ready_t > now),
                   default=None)

    def ready_free_slots(self, now: float) -> int:
        """Immediately usable warm capacity (the router's warm signal)."""
        return sum(i.free_slots() for i in self.instances
                   if i.ready_t <= now)

    def inflight(self) -> int:
        return sum(i.busy for i in self.instances)

    def idle_ready(self, now: float) -> Optional[Instance]:
        """An idle warm instance, if any — the reap candidate."""
        for inst in self.instances:
            if inst.busy == 0 and inst.ready_t <= now:
                return inst
        return None


class FnQueues:
    """Per-function FIFO queues with a worker-global arrival order.

    Each pushed request is stamped with a monotonically increasing
    ``_wseq`` so a dispatch scan can merge several functions' queues in
    exactly the order a single flat queue would have produced. Queue
    timeouts live in a deadline heap: expired requests are surfaced in
    O(expired log n) instead of rescanning the backlog, and are marked
    dead in place (``_queued = False``) so deque entries are dropped
    lazily when a scan next reaches them.
    """

    __slots__ = ("_q", "_live", "_live_total", "_deadlines", "_seq")

    def __init__(self):
        self._q: Dict[str, deque] = {}
        self._live: Dict[str, int] = {}
        self._live_total = 0
        self._deadlines: list = []     # (deadline, wseq, timeout_s, req)
        self._seq = itertools.count()

    # ------------------------------------------------------------ mutate
    def push(self, req, timeout_s: float) -> None:
        req._wseq = next(self._seq)
        req._queued = True
        self._q.setdefault(req.fn, deque()).append(req)
        self._live[req.fn] = self._live.get(req.fn, 0) + 1
        self._live_total += 1
        heapq.heappush(self._deadlines,
                       (req.arrival_t + timeout_s, req._wseq, timeout_s, req))

    def has_expired(self, now: float) -> bool:
        """O(1) peek so the dispatch hot path can skip the flush."""
        return bool(self._deadlines) and self._deadlines[0][0] <= now

    def pop_expired(self, now: float) -> list:
        """Requests past their queue timeout, in arrival order.

        Mirrors the flat scan's check (``now - arrival_t > timeout_s``,
        strict) exactly; entries whose heap key rounds earlier than the
        exact check are pushed back rather than mis-expired.
        """
        out, putback = [], []
        while self._deadlines and self._deadlines[0][0] <= now:
            entry = heapq.heappop(self._deadlines)
            _, _, timeout_s, req = entry
            if not req._queued:
                continue                       # served/failed/drained already
            if now - req.arrival_t > timeout_s:
                req._queued = False
                self._live[req.fn] -= 1
                self._live_total -= 1
                out.append(req)
            else:
                putback.append(entry)
        for entry in putback:
            heapq.heappush(self._deadlines, entry)
        out.sort(key=lambda r: r._wseq)
        return out

    def drain_all(self) -> list:
        """Remove and return every live request, in arrival order
        (worker failure and branch removal both re-disposition the whole
        queue)."""
        out = [r for q in self._q.values() for r in q if r._queued]
        out.sort(key=lambda r: r._wseq)
        for r in out:
            r._queued = False
        self._q.clear()
        self._live.clear()
        self._live_total = 0
        self._deadlines.clear()
        return out

    # ------------------------------------------------------- scan support
    def scan_head(self, fn: str):
        """Live head of one function's queue (drops dead entries)."""
        q = self._q.get(fn)
        if q is None:
            return None
        while q and not q[0]._queued:
            q.popleft()
        return q[0] if q else None

    def pop_head(self, fn: str) -> None:
        """Detach the current head for processing; pair with
        ``mark_served`` (leaves the queue) or ``restore`` (kept)."""
        self._q[fn].popleft()

    def mark_served(self, req) -> None:
        req._queued = False
        self._live[req.fn] -= 1
        self._live_total -= 1

    def restore(self, fn: str, kept: list) -> None:
        """Put back, in order, the processed-but-kept prefix."""
        if kept:
            self._q[fn].extendleft(reversed(kept))

    # ------------------------------------------------------------- reads
    def __len__(self) -> int:
        return self._live_total

    def depth(self, fn: str) -> int:
        return self._live.get(fn, 0)

    def depths(self) -> Dict[str, int]:
        return {fn: n for fn, n in self._live.items() if n}

    def active_fns(self) -> List[str]:
        return [fn for fn, n in self._live.items() if n]

    def __iter__(self) -> Iterator:
        """Live requests in arrival order (non-destructive)."""
        live = [r for q in self._q.values() for r in q if r._queued]
        live.sort(key=lambda r: r._wseq)
        return iter(live)

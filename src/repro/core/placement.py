"""Pluggable replica-placement layer: bin-pack replica starts by memory.

SeBS (Copik et al.) and the FaaS Benchmarking Framework both identify the
per-function memory allocation as a dominant platform knob; this module
makes it a first-class architectural axis of the testbed. Every worker
carries an optional ``memory_mb`` capacity, every started replica charges
its function's ``FunctionConfig.memory_mb`` against it, and a *placer*
decides which worker hosts the next replica (and which worker gives one
back on scale-down).

A placer never mutates state. It ranks candidate workers; the simulator
supplies the candidates in a deterministic preference order (coldest in
the function for placement, warmest for reaping) and then attempts the
actual start/stop in the placer's order, so two same-seed runs make
byte-identical placement decisions.

The worker objects a placer sees are duck-typed (the simulator's
``_Worker``); a placer may read:

- ``name``              stable worker id (the deterministic tiebreak)
- ``mem_free_mb()``     free memory, ``inf`` when the worker is uncapped
- ``fits(mem_mb)``      admission check against the memory capacity
- ``fn_replicas(fn)``   live replicas of one function on this worker
- ``total_instances``   live replicas across all functions
- ``zone``              failure domain (``Simulator(zones=...)``), or None

Registering a custom placer mirrors the LB-policy and autoscaler
registries::

    @register_placer
    class MyPlacer(Placer):
        name = "my_placer"
        def place_order(self, fn, memory_mb, workers):
            return [w for w in workers if w.fits(memory_mb)]

    sim = Simulator(tree, store, model, placer="my_placer",
                    worker_memory_mb=4096)
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

PLACERS: Dict[str, Callable[..., "Placer"]] = {}


def register_placer(cls):
    """Class decorator: add a Placer subclass to the registry."""
    PLACERS[cls.name] = cls
    return cls


def get_placer(name: str, **params) -> "Placer":
    """Construct a registered placer by name: the config/CLI hook."""
    if name not in PLACERS:
        raise KeyError(f"placer {name!r} not registered "
                       f"(have: {sorted(PLACERS)})")
    return PLACERS[name](**params)


def list_placers() -> List[str]:
    return sorted(PLACERS)


class Placer:
    """Base interface: rank candidate workers for one replica move.

    ``workers`` arrives in the simulator's preference order (see module
    docstring); a placer filters by fit and may re-rank. Python sorts are
    stable, so a placer that sorts on a memory key degenerates to the
    incoming order when every worker is uncapped — which is what keeps
    unlimited-memory runs byte-identical to the pre-placement simulator.
    """

    name = "base"

    def place_order(self, fn: str, memory_mb: float,
                    workers: Sequence) -> List:
        """Workers that can host one more ``memory_mb`` replica of ``fn``,
        best host first."""
        raise NotImplementedError

    def reap_order(self, fn: str, workers: Sequence) -> List:
        """Workers to take an idle replica of ``fn`` from, first choice
        first. Default: the simulator's warmest-first preference order."""
        return list(workers)

    def blocked_cold_eta_s(self, need_mb: float, free_mb: float,
                           svc_s: float, depth: int,
                           inflight: int) -> float:
        """Graded ETA for a memory-blocked cold start on one leaf.

        ``deadline_aware`` routing historically priced a blocked cold
        start with a flat ~infinite penalty, which ranks a leaf that is
        1 MB short identically to one that needs the whole worker to
        drain. This hook prices the *unblock* instead: memory frees as
        outstanding work (queued + in flight) completes, so the expected
        wait is the per-request service time times the share of that
        work that must finish before the deficit closes. The estimate is
        capped at the flat penalty so a graded leaf can never outrank
        the flat model's view of an unblocked one.

        Opt-in: the simulator only wires this into
        ``StateView.mem_eta`` under ``mem_eta="placer"`` — the default
        flat penalty keeps every existing golden digest byte-identical.
        """
        from repro.core.router import MEM_BLOCKED_PENALTY_S
        deficit = max(need_mb - free_mb, 0.0) / max(need_mb, 1.0)
        outstanding = max(inflight + depth, 1)
        eta = max(svc_s, 1e-6) * outstanding * min(deficit, 1.0)
        return min(eta, MEM_BLOCKED_PENALTY_S)


@register_placer
class FirstFitPlacer(Placer):
    """Classic first-fit bin packing: take the first candidate with room.

    With unlimited memory every candidate fits, so this is exactly the
    pre-placement behaviour (pinned by the golden digests in
    ``tests/test_placement.py``) — the safe default.
    """

    name = "first_fit"

    def place_order(self, fn, memory_mb, workers):
        return [w for w in workers if w.fits(memory_mb)]


@register_placer
class BestFitMemoryPlacer(Placer):
    """Best-fit bin packing on memory: tightest surviving gap first.

    Packing big-footprint replicas into the fullest worker that still
    fits preserves large contiguous headroom elsewhere — the placement
    that keeps a heterogeneous-memory mix schedulable where first-fit
    fragments the fleet. Reaping is the mirror image: free memory on the
    most pressured worker first.
    """

    name = "best_fit_memory"

    def place_order(self, fn, memory_mb, workers):
        return sorted((w for w in workers if w.fits(memory_mb)),
                      key=lambda w: w.mem_free_mb())

    def reap_order(self, fn, workers):
        return sorted(workers, key=lambda w: w.mem_free_mb())


@register_placer
class SpreadPlacer(Placer):
    """Availability-first: spread replicas of a function across workers.

    Prefers the worker holding the fewest replicas of ``fn`` (then the
    emptiest overall, then the most free memory) so one worker failure
    takes out the smallest share of a function's warm capacity.
    """

    name = "spread"

    def place_order(self, fn, memory_mb, workers):
        return sorted((w for w in workers if w.fits(memory_mb)),
                      key=lambda w: (w.fn_replicas(fn), w.total_instances,
                                     -w.mem_free_mb()))


@register_placer
class SpreadZonesPlacer(Placer):
    """Failure-domain-aware spread: balance a function's replicas across
    *zones* first, then apply the per-worker spread key inside the zone.

    ``spread`` is blind to the tree's failure domains — with few
    functions and same-size workers it happily fills one branch, and a
    zone outage then takes out a function's entire warm capacity at
    once. This placer counts the function's replicas per zone over the
    candidate set and always grows the least-loaded zone, so any single
    zone holds at most ⌈replicas/zones⌉ of the function. Reaping is the
    mirror: shrink the most replica-heavy zone first. With no zones
    configured every worker shares the ``None`` domain and both orders
    degenerate to plain ``spread``.
    """

    name = "spread_zones"

    @staticmethod
    def _zone_load(fn, workers):
        load: dict = {}
        for w in workers:
            z = getattr(w, "zone", None)
            load[z] = load.get(z, 0) + w.fn_replicas(fn)
        return load

    def place_order(self, fn, memory_mb, workers):
        fits = [w for w in workers if w.fits(memory_mb)]
        # zone load counts *every* candidate's replicas, not just the
        # ones with room — a memory-full worker still anchors its zone's
        # share of the function, and dropping it from the count would
        # keep piling replicas into an already-loaded zone
        load = self._zone_load(fn, workers)
        return sorted(fits, key=lambda w: (
            load[getattr(w, "zone", None)], w.fn_replicas(fn),
            w.total_instances, -w.mem_free_mb()))

    def reap_order(self, fn, workers):
        load = self._zone_load(fn, workers)
        # stable sort keeps the simulator's warmest-first preference
        # order inside each zone
        return sorted(workers,
                      key=lambda w: -load[getattr(w, "zone", None)])

"""Configuration store + image registry (paper Fig. 1, right side).

The paper assumes "the cloud platform already offers ... a key-value store for
the configuration that can scale with the demands of the platform" — so these
are deliberately thin KV interfaces (swap in etcd/Spanner/whatever in prod).
Workers read them to start instances; smarter load balancers may read them too.
"""
from __future__ import annotations

import json
import threading
from typing import Callable, Dict

from repro.core.types import FunctionConfig


class ConfigStore:
    """Versioned KV store of FunctionConfigs (thread-safe, watchable)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._data: Dict[str, FunctionConfig] = {}
        self._version: Dict[str, int] = {}
        self._watchers = []

    def put(self, cfg: FunctionConfig):
        with self._lock:
            self._data[cfg.name] = cfg
            self._version[cfg.name] = self._version.get(cfg.name, 0) + 1
            watchers = list(self._watchers)
        for w in watchers:
            w(cfg)

    def get(self, name: str) -> FunctionConfig:
        with self._lock:
            if name not in self._data:
                raise KeyError(f"function {name!r} not registered")
            return self._data[name]

    def version(self, name: str) -> int:
        with self._lock:
            return self._version.get(name, 0)

    def list(self):
        with self._lock:
            return sorted(self._data)

    def watch(self, fn: Callable[[FunctionConfig], None]):
        self._watchers.append(fn)

    def dump_json(self) -> str:
        with self._lock:
            return json.dumps({k: vars(v) for k, v in self._data.items()},
                              sort_keys=True, default=str)


class ImageRegistry:
    """Function "images": factories that materialize an executable instance.

    In HyperFaaS an image is a Docker container; here it is a builder that
    returns a compiled model closure (weights init + jit = the cold start).
    """

    def __init__(self):
        self._builders: Dict[str, Callable] = {}

    def register(self, arch: str, builder: Callable):
        self._builders[arch] = builder

    def pull(self, arch: str) -> Callable:
        if arch not in self._builders:
            raise KeyError(f"image {arch!r} not in registry")
        return self._builders[arch]

    def list(self):
        return sorted(self._builders)

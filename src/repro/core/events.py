"""The event engine: the simulator's hot loop, behind a narrow interface.

The testbed's credibility rests on request volumes an order of magnitude
beyond toy probes (SeBS; Barcelona-Pons & Garcia-Lopez both push past
10M invocations), and at that scale the *event queue* — not the worker
model — becomes the simulator's bottleneck: a single binary heap holding
millions of pre-loaded arrivals pays O(log n) pointer-chasing tuple
comparisons on every push and pop, over a working set far larger than
cache. This module makes the queue a pluggable architectural axis, like
LB policies, placers, and autoscalers:

- :class:`EventEngine` — seq-stamping, pending-event accounting, and the
  ``pop(until=...)`` peek-don't-requeue contract the simulator's
  ``run(until)`` resume path relies on.
- ``single_heap`` (:class:`SingleHeapQueue`) — one ``heapq``; byte
  identical to the pre-split simulator (the golden-digest contract).
- ``sharded`` (:class:`ShardedQueue`) — a calendar queue: time-bucketed
  per-shard heaps drained in bucket order and merged by ``(t, seq)``.
  Pre-loaded arrivals are staged and cut into per-bucket *sorted runs*
  on first pop, so steady-state pops cost O(1)-ish comparisons against
  a cache-hot bucket instead of O(log 10M) against the whole future.

Determinism contract: every backend yields events in exactly ascending
``(t, seq)`` order — the total order a single heap produces — so the
same seed gives byte-identical results on *any* backend (enforced by
``tests/test_events.py`` and the shared property driver in
``tests/_prop_drivers.py``).

Events are plain tuples ``(t, seq, kind, payload)``. ``seq`` is stamped
by the engine from one monotone counter, which is what makes ``(t,
seq)`` a total order: payloads are never compared.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Tuple

Event = Tuple[float, int, str, object]

EVENT_BACKENDS: Dict[str, Callable[..., "EventQueue"]] = {}


def register_event_backend(cls):
    """Class decorator: add an EventQueue backend to the registry."""
    EVENT_BACKENDS[cls.kind] = cls
    return cls


def get_event_backend(name: str, **params) -> "EventQueue":
    """Construct a registered event-queue backend by name."""
    if name not in EVENT_BACKENDS:
        raise KeyError(f"event backend {name!r} not registered "
                       f"(have: {sorted(EVENT_BACKENDS)})")
    return EVENT_BACKENDS[name](**params)


def list_event_backends() -> List[str]:
    return sorted(EVENT_BACKENDS)


class EventQueue:
    """Backend interface: a priority queue over ``(t, seq, ...)`` tuples.

    ``push`` never compares payloads (``seq`` is unique), ``pop``/``peek``
    surface the globally smallest ``(t, seq)`` entry. ``peek`` must not
    remove — the engine's ``pop(until)`` peeks first so an event beyond
    the horizon is simply *left in place* (no pop-and-requeue churn).
    """

    kind = "base"

    def push(self, entry: Event) -> None:
        raise NotImplementedError

    def pop(self) -> Event:
        raise NotImplementedError

    def peek(self) -> Optional[Event]:
        raise NotImplementedError

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        """Pop the head iff it lies at or before ``until`` (None = no
        horizon); otherwise leave the queue untouched and return None.
        One traversal on backends that override it — the engine's hot
        path."""
        entry = self.peek()
        if entry is None or (until is not None and entry[0] > until):
            return None
        return self.pop()

    def __len__(self) -> int:
        raise NotImplementedError


@register_event_backend
class SingleHeapQueue(EventQueue):
    """One ``heapq`` over all pending events — the reference backend.

    Exactly the pre-split simulator's queue: same tuples, same heap, same
    pop order, so every golden digest recorded before the event-engine
    refactor still matches byte for byte.
    """

    kind = "single_heap"

    __slots__ = ("_heap",)

    def __init__(self):
        self._heap: list = []

    def push(self, entry: Event) -> None:
        heapq.heappush(self._heap, entry)

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        heap = self._heap
        if not heap or (until is not None and heap[0][0] > until):
            return None
        return heapq.heappop(heap)

    def __len__(self) -> int:
        return len(self._heap)


@register_event_backend
class ShardedQueue(EventQueue):
    """Calendar queue: per-time-bucket shards merged by ``(t, seq)``.

    Two regimes, matching how the simulator actually produces events:

    - **Staged bulk load.** Everything pushed before the first pop (the
      ``sim.load(workload)`` pattern: millions of arrivals, already in
      nearly ascending time order) accumulates in a flat list. The first
      pop *seals* the stage: one adaptive ``sort`` (Timsort is ~O(n) on
      the nearly-sorted stream), a bucket width chosen so each shard
      holds ~``target_per_bucket`` events, and a single pass cutting the
      run into per-bucket sorted lists consumed by index — no heap
      discipline needed for the entire pre-loaded future.
    - **Dynamic events.** Pushes after sealing (finish/poke/enqueue at
      near-``now`` times) go into the destination bucket's *overflow
      heap*. Those heaps stay small — operational events cluster around
      the present — so pushes and pops are a handful of comparisons
      against cache-hot shards instead of O(log total-pending).

    ``pop`` merges the current bucket's sorted run with its overflow
    heap by ``(t, seq)`` and advances through buckets in index order;
    since ``floor(t / width)`` is monotone in ``t``, the drain order is
    exactly ascending ``(t, seq)`` — identical to the single heap. An
    entry pushed behind the bucket currently draining (only possible for
    ``t`` at the bucket boundary, or a caller scheduling in the past,
    which the simulator never does) is clamped into the current bucket,
    where ``(t, seq)`` ordering still places it correctly relative to
    everything not yet popped.

    When the queue fully drains it returns to staging mode, so a
    drain-then-bulk-load cycle (``run()``, then another ``load()``)
    re-tunes the bucket width to the new horizon.
    """

    kind = "sharded"

    __slots__ = ("bucket_s", "target_per_bucket", "_staged", "_width",
                 "_runs", "_heaps", "_active", "_cur", "_cur_end",
                 "_cur_run", "_cur_pos", "_cur_heap", "_len")

    def __init__(self, bucket_s: Optional[float] = None,
                 target_per_bucket: int = 4096):
        self.bucket_s = bucket_s           # None => size from staged span
        self.target_per_bucket = target_per_bucket
        self._staged: Optional[list] = []  # None once sealed
        self._width = bucket_s or 0.01
        self._runs: Dict[int, list] = {}   # future idx -> sorted staged slice
        self._heaps: Dict[int, list] = {}  # future idx -> overflow heap
        self._active: list = []            # heap of not-yet-drained idxs
        # the bucket currently draining, held in slots so the hot pop
        # path touches no dicts at all — and pushes into it take a
        # single float compare (t < _cur_end), no division, no dicts
        self._cur: Optional[int] = None
        self._cur_end = -1e300             # (cur + 1) * width
        self._cur_run: Optional[list] = None
        self._cur_pos = 0
        self._cur_heap: Optional[list] = None
        self._len = 0

    # ------------------------------------------------------------ internals
    def _seal(self) -> None:
        """Cut the staged bulk load into per-bucket sorted runs."""
        staged = self._staged
        self._staged = None
        if not staged:
            return
        staged.sort()
        if self.bucket_s is None:
            span = staged[-1][0] - staged[0][0]
            buckets = max(1, len(staged) // self.target_per_bucket)
            self._width = max(span / buckets, 1e-9)
        width = self._width
        runs, active = self._runs, self._active
        lo = 0
        idx = int(staged[0][0] / width)
        for i, entry in enumerate(staged):
            j = int(entry[0] / width)
            if j != idx:
                runs[idx] = staged[lo:i]
                active.append(idx)
                lo, idx = i, j
        runs[idx] = staged[lo:]
        active.append(idx)
        heapq.heapify(active)

    def _head(self):
        """(head entry, came-from-overflow-heap) for the next live bucket;
        advances the current-bucket slots past exhausted buckets. The
        caller has already checked ``_len > 0``."""
        if self._staged is not None:
            self._seal()
        while True:
            head = None
            run = self._cur_run
            if run is not None:
                p = self._cur_pos
                if p < len(run):
                    head = run[p]
                else:
                    self._cur_run = None
            heap = self._cur_heap
            if heap:
                h0 = heap[0]
                if head is None or h0 < head:
                    return h0, True
                return head, False
            if head is not None:
                return head, False
            # current bucket exhausted: load the next active one
            cur = self._cur = heapq.heappop(self._active)
            self._cur_end = (cur + 1) * self._width
            self._cur_run = self._runs.pop(cur, None)
            self._cur_pos = 0
            self._cur_heap = self._heaps.pop(cur, None)

    def _restage(self) -> None:
        """Fully drained: return to staging so the next bulk load
        re-tunes the bucket width to its own horizon."""
        self._staged = []
        self._runs.clear()
        self._heaps.clear()
        self._active.clear()
        self._cur = None
        self._cur_end = -1e300
        self._cur_run = None
        self._cur_pos = 0
        self._cur_heap = None

    # ------------------------------------------------------------- interface
    def push(self, entry: Event) -> None:
        self._len += 1
        staged = self._staged
        if staged is not None:
            staged.append(entry)
            return
        if entry[0] < self._cur_end:
            # the draining bucket — the overwhelmingly common case for
            # operational (near-now) events. Past-t pushes clamp here
            # too: ``(t, seq)`` ordering still places them correctly
            # among the not-yet-popped entries.
            heap = self._cur_heap
            if heap is None:
                self._cur_heap = [entry]
            else:
                heapq.heappush(heap, entry)
            return
        idx = int(entry[0] / self._width)
        cur = self._cur
        if cur is not None and idx <= cur:
            # float-boundary guard: t >= _cur_end (a rounded product) can
            # still floor-divide into the draining bucket's index; never
            # re-activate a bucket at or behind the drain
            idx = cur + 1
        heaps = self._heaps
        heap = heaps.get(idx)
        if heap is None:
            heaps[idx] = [entry]
            if idx not in self._runs:
                heapq.heappush(self._active, idx)
            return
        heapq.heappush(heap, entry)

    def _take(self, entry: Event, from_heap: bool) -> Event:
        self._len -= 1
        if from_heap:
            heap = self._cur_heap
            heapq.heappop(heap)
            if not heap:
                self._cur_heap = None
        else:
            self._cur_pos += 1
        if self._len == 0:
            self._restage()
        return entry

    def pop(self) -> Event:
        if self._len == 0:
            raise IndexError("pop from an empty ShardedQueue")
        entry, from_heap = self._head()
        return self._take(entry, from_heap)

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        if self._len == 0:
            return None
        entry, from_heap = self._head()
        if until is not None and entry[0] > until:
            return None
        return self._take(entry, from_heap)

    def peek(self) -> Optional[Event]:
        if self._len == 0:
            return None
        return self._head()[0]

    def __len__(self) -> int:
        return self._len


class EventEngine:
    """Seq-stamping event queue over a pluggable backend.

    The engine owns the one monotone ``seq`` counter (what makes ``(t,
    seq)`` a total order across backends) and the pending-event
    accounting the simulator's termination logic reads: kinds listed in
    ``background`` (the autoscaler's self-re-arming tick) are excluded
    from :attr:`pending_real`, so a control loop can ask "is there real
    work left?" without scanning the queue.

    ``pop(until=...)`` peeks before popping: an event beyond the horizon
    is *left in the queue* untouched — same ``(t, seq)``, no
    pop-and-requeue round trip — which is what makes a segmented
    ``run(until=...); run()`` byte-identical to one straight ``run()``
    (including ``events_processed``; pinned by
    ``tests/test_events.py``).
    """

    def __init__(self, backend="single_heap", *,
                 background: Tuple[str, ...] = (), **backend_kw):
        self.queue: EventQueue = (get_event_backend(backend, **backend_kw)
                                  if isinstance(backend, str) else backend)
        self.backend = self.queue.kind
        self.background = frozenset(background)
        self.pending_real = 0              # pending events minus background
        self._seq = 0

    def push(self, t: float, kind: str, payload) -> None:
        if kind not in self.background:
            self.pending_real += 1
        seq = self._seq
        self._seq = seq + 1
        self.queue.push((t, seq, kind, payload))

    def pop(self, until: Optional[float] = None) -> Optional[Event]:
        """Next event in ``(t, seq)`` order, or None if the queue is
        empty or the next event lies beyond ``until`` (left in place)."""
        entry = self.queue.pop_until(until)
        if entry is None:
            return None
        if entry[2] not in self.background:
            self.pending_real -= 1
        return entry

    def peek_t(self) -> Optional[float]:
        entry = self.queue.peek()
        return entry[0] if entry is not None else None

    def __len__(self) -> int:
        return len(self.queue)

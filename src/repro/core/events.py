"""The event engine: the simulator's hot loop, behind a narrow interface.

The testbed's credibility rests on request volumes an order of magnitude
beyond toy probes (SeBS; Barcelona-Pons & Garcia-Lopez both push past
10M invocations), and at that scale the *event queue* — not the worker
model — becomes the simulator's bottleneck: a single binary heap holding
millions of pre-loaded arrivals pays O(log n) pointer-chasing tuple
comparisons on every push and pop, over a working set far larger than
cache. This module makes the queue a pluggable architectural axis, like
LB policies, placers, and autoscalers:

- :class:`EventEngine` — seq-stamping, pending-event accounting, and the
  ``pop(until=...)`` peek-don't-requeue contract the simulator's
  ``run(until)`` resume path relies on.
- ``single_heap`` (:class:`SingleHeapQueue`) — one ``heapq``; byte
  identical to the pre-split simulator (the golden-digest contract).
- ``sharded`` (:class:`ShardedQueue`) — a calendar queue: time-bucketed
  per-shard heaps drained in bucket order and merged by ``(t, seq)``.
  Pre-loaded arrivals are staged and cut into per-bucket *sorted runs*
  on first pop, so steady-state pops cost O(1)-ish comparisons against
  a cache-hot bucket instead of O(log 10M) against the whole future.

Determinism contract: every backend yields events in exactly ascending
``(t, seq)`` order — the total order a single heap produces — so the
same seed gives byte-identical results on *any* backend (enforced by
``tests/test_events.py`` and the shared property driver in
``tests/_prop_drivers.py``).

Events are plain tuples ``(t, seq, kind, payload)``. ``seq`` is stamped
by the engine from one monotone counter, which is what makes ``(t,
seq)`` a total order: payloads are never compared.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_left, bisect_right
from itertools import repeat as _repeat
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

_INF = float("inf")

Event = Tuple[float, int, str, object]

EVENT_BACKENDS: Dict[str, Callable[..., "EventQueue"]] = {}


def register_event_backend(cls):
    """Class decorator: add an EventQueue backend to the registry."""
    EVENT_BACKENDS[cls.kind] = cls
    return cls


def get_event_backend(name: str, **params) -> "EventQueue":
    """Construct a registered event-queue backend by name."""
    if name not in EVENT_BACKENDS:
        raise KeyError(f"event backend {name!r} not registered "
                       f"(have: {sorted(EVENT_BACKENDS)})")
    return EVENT_BACKENDS[name](**params)


def list_event_backends() -> List[str]:
    return sorted(EVENT_BACKENDS)


class EventQueue:
    """Backend interface: a priority queue over ``(t, seq, ...)`` tuples.

    ``push`` never compares payloads (``seq`` is unique), ``pop``/``peek``
    surface the globally smallest ``(t, seq)`` entry. ``peek`` must not
    remove — the engine's ``pop(until)`` peeks first so an event beyond
    the horizon is simply *left in place* (no pop-and-requeue churn).
    """

    kind = "base"

    def push(self, entry: Event) -> None:
        raise NotImplementedError

    def pop(self) -> Event:
        raise NotImplementedError

    def peek(self) -> Optional[Event]:
        raise NotImplementedError

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        """Pop the head iff it lies at or before ``until`` (None = no
        horizon); otherwise leave the queue untouched and return None.
        One traversal on backends that override it — the engine's hot
        path."""
        entry = self.peek()
        if entry is None or (until is not None and entry[0] > until):
            return None
        return self.pop()

    def push_bulk_run(self, times, seq0: int, kind: str,
                      payloads=None) -> None:
        """Bulk-push one same-kind run: entry ``i`` is ``(times[i],
        seq0 + i, kind, payloads[i])`` (``None`` payloads throughout
        when ``payloads`` is None). Must be order-identical to pushing
        the entries one by one — this reference implementation does
        exactly that; backends override with batch paths."""
        if hasattr(times, "tolist"):           # numpy -> Python floats
            times = times.tolist()
        if payloads is None:
            seq = seq0
            for t in times:
                self.push((t, seq, kind, None))
                seq += 1
        else:
            for seq, (t, p) in enumerate(zip(times, payloads), start=seq0):
                self.push((t, seq, kind, p))

    def pop_batch(self, max_n: int,
                  until: Optional[float] = None) -> List[Event]:
        """Pop up to ``max_n`` events in ``(t, seq)`` order, stopping
        early only at the ``until`` horizon or an empty queue. Greedy
        by contract — every backend returns exactly
        ``min(max_n, available-within-horizon)`` entries, so batch
        *partitions* (not just the concatenated stream) are
        backend-identical."""
        out: List[Event] = []
        while len(out) < max_n:
            entry = self.pop_until(until)
            if entry is None:
                break
            out.append(entry)
        return out

    def __len__(self) -> int:
        raise NotImplementedError


@register_event_backend
class SingleHeapQueue(EventQueue):
    """One ``heapq`` over all pending events — the reference backend.

    Exactly the pre-split simulator's queue: same tuples, same heap, same
    pop order, so every golden digest recorded before the event-engine
    refactor still matches byte for byte.
    """

    kind = "single_heap"

    __slots__ = ("_heap",)

    def __init__(self):
        self._heap: list = []

    def push(self, entry: Event) -> None:
        heapq.heappush(self._heap, entry)

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        heap = self._heap
        if not heap or (until is not None and heap[0][0] > until):
            return None
        return heapq.heappop(heap)

    def push_bulk_run(self, times, seq0: int, kind: str,
                      payloads=None) -> None:
        # heapify-based reference: an empty heap takes the whole run in
        # O(n); otherwise per-entry sift. Either way the heap's pop
        # order is the (t, seq) total order — identical to per-push.
        if hasattr(times, "tolist"):
            times = times.tolist()
        entries = zip(times, range(seq0, seq0 + len(times)), _repeat(kind),
                      payloads if payloads is not None else _repeat(None))
        heap = self._heap
        if heap:
            push = heapq.heappush
            for e in entries:
                push(heap, e)
        else:
            heap.extend(entries)
            heapq.heapify(heap)

    def pop_batch(self, max_n: int,
                  until: Optional[float] = None) -> List[Event]:
        heap = self._heap
        out: List[Event] = []
        pop = heapq.heappop
        if until is None:
            for _ in range(min(max_n, len(heap))):
                out.append(pop(heap))
        else:
            while len(out) < max_n and heap and heap[0][0] <= until:
                out.append(pop(heap))
        return out

    def __len__(self) -> int:
        return len(self._heap)


@register_event_backend
class ShardedQueue(EventQueue):
    """Calendar queue: per-time-bucket shards merged by ``(t, seq)``.

    Two regimes, matching how the simulator actually produces events:

    - **Staged bulk load.** Everything pushed before the first pop (the
      ``sim.load(workload)`` pattern: millions of arrivals, already in
      nearly ascending time order) accumulates in a flat list. The first
      pop *seals* the stage: one adaptive ``sort`` (Timsort is ~O(n) on
      the nearly-sorted stream), a bucket width chosen so each shard
      holds ~``target_per_bucket`` events, and a single pass cutting the
      run into per-bucket sorted lists consumed by index — no heap
      discipline needed for the entire pre-loaded future.
    - **Dynamic events.** Pushes after sealing (finish/poke/enqueue at
      near-``now`` times) go into the destination bucket's *overflow
      heap*. Those heaps stay small — operational events cluster around
      the present — so pushes and pops are a handful of comparisons
      against cache-hot shards instead of O(log total-pending).

    ``pop`` merges the current bucket's sorted run with its overflow
    heap by ``(t, seq)`` and advances through buckets in index order;
    since ``floor(t / width)`` is monotone in ``t``, the drain order is
    exactly ascending ``(t, seq)`` — identical to the single heap. An
    entry pushed behind the bucket currently draining (only possible for
    ``t`` at the bucket boundary, or a caller scheduling in the past,
    which the simulator never does) is clamped into the current bucket,
    where ``(t, seq)`` ordering still places it correctly relative to
    everything not yet popped.

    When the queue fully drains it returns to staging mode, so a
    drain-then-bulk-load cycle (``run()``, then another ``load()``)
    re-tunes the bucket width to the new horizon.
    """

    kind = "sharded"

    __slots__ = ("bucket_s", "target_per_bucket", "_staged", "_bulk",
                 "_width", "_runs", "_heaps", "_active", "_cur",
                 "_cur_end", "_cur_run", "_cur_pos", "_cur_heap", "_len")

    def __init__(self, bucket_s: Optional[float] = None,
                 target_per_bucket: int = 4096):
        self.bucket_s = bucket_s           # None => size from staged span
        self.target_per_bucket = target_per_bucket
        self._staged: Optional[list] = []  # None once sealed
        self._bulk: list = []              # staged columnar runs (ISSUE-8)
        self._width = bucket_s or 0.01
        self._runs: Dict[int, list] = {}   # future idx -> sorted staged slice
        self._heaps: Dict[int, list] = {}  # future idx -> overflow heap
        self._active: list = []            # heap of not-yet-drained idxs
        # the bucket currently draining, held in slots so the hot pop
        # path touches no dicts at all — and pushes into it take a
        # single float compare (t < _cur_end), no division, no dicts
        self._cur: Optional[int] = None
        self._cur_end = -1e300             # (cur + 1) * width
        self._cur_run: Optional[list] = None
        self._cur_pos = 0
        self._cur_heap: Optional[list] = None
        self._len = 0

    # ------------------------------------------------------------ internals
    def _seal(self) -> None:
        """Cut the staged bulk load into per-bucket sorted runs.

        Scalar staged entries keep the original adaptive-sort path
        byte-for-byte. Columnar runs staged via ``push_bulk_run`` take
        the vectorized path: entry tuples are built exactly once, the
        global order comes from one ``np.lexsort`` over ``(t, seq)`` —
        or no sort at all when run concatenation is already globally
        nondecreasing (the multi-stream ascending-ingest common case;
        concat order is seq order because the engine stamps runs
        monotonically) — and bucket cuts come from one vectorized
        index-change scan instead of a per-entry Python loop."""
        staged = self._staged
        bulk = self._bulk
        self._staged = None
        self._bulk = []
        ts = None                          # numpy times iff vector path
        if not bulk:
            if not staged:
                return
            staged.sort()
            entries = staged
        else:
            times = (bulk[0][0] if len(bulk) == 1 else
                     np.concatenate([r[0] for r in bulk]))
            kinds = {r[2] for r in bulk}
            if (not staged and len(kinds) == 1
                    and all(r[3] is None for r in bulk)):
                # column fast path (the pre-loaded-arrivals shape: one
                # kind, no payloads): sort the columns, then build the
                # tuples already in order — no per-entry gather
                seqs = (np.arange(bulk[0][1], bulk[0][1] + len(times))
                        if len(bulk) == 1 else
                        np.concatenate([np.arange(s0, s0 + len(t_arr))
                                        for t_arr, s0, _k, _p in bulk]))
                if not bool(np.all(times[:-1] <= times[1:])):
                    order = np.lexsort((seqs, times))
                    times, seqs = times[order], seqs[order]
                ts = times
                entries = list(zip(times.tolist(), seqs.tolist(),
                                   _repeat(next(iter(kinds))),
                                   _repeat(None)))
            else:
                chunks = [zip(t_arr.tolist(), range(s0, s0 + len(t_arr)),
                              _repeat(kind),
                              pl if pl is not None else _repeat(None))
                          for t_arr, s0, kind, pl in bulk]
                entries = (list(chunks[0]) if len(chunks) == 1 else
                           list(itertools.chain.from_iterable(chunks)))
                if staged:
                    # scalar pushes interleaved with bulk runs while
                    # staging (e.g. an autoscale tick armed before
                    # load_bulk): rare and small — merge through the
                    # adaptive sort
                    entries.extend(staged)
                    entries.sort()
                elif bool(np.all(times[:-1] <= times[1:])):
                    ts = times
                else:
                    seqs = np.concatenate(
                        [np.arange(s0, s0 + len(t_arr))
                         for t_arr, s0, _k, _p in bulk])
                    order = np.lexsort((seqs, times))
                    entries = [entries[i] for i in order.tolist()]
                    ts = times[order]
        if self.bucket_s is None:
            span = entries[-1][0] - entries[0][0]
            buckets = max(1, len(entries) // self.target_per_bucket)
            self._width = max(span / buckets, 1e-9)
        width = self._width
        runs, active = self._runs, self._active
        if ts is not None:
            # C-cast truncation matches int() for every float, so both
            # paths agree on bucket indices
            idx = (ts / width).astype(np.int64)
            starts = [0, *(np.flatnonzero(idx[1:] != idx[:-1]) + 1).tolist()]
            bounds = [*starts, len(entries)]
            for lo, hi in zip(bounds, bounds[1:]):
                runs[int(idx[lo])] = entries[lo:hi]
                active.append(int(idx[lo]))
        else:
            lo = 0
            idx = int(entries[0][0] / width)
            for i, entry in enumerate(entries):
                j = int(entry[0] / width)
                if j != idx:
                    runs[idx] = entries[lo:i]
                    active.append(idx)
                    lo, idx = i, j
            runs[idx] = entries[lo:]
            active.append(idx)
        heapq.heapify(active)

    def _head(self):
        """(head entry, came-from-overflow-heap) for the next live bucket;
        advances the current-bucket slots past exhausted buckets. The
        caller has already checked ``_len > 0``."""
        if self._staged is not None:
            self._seal()
        while True:
            head = None
            run = self._cur_run
            if run is not None:
                p = self._cur_pos
                if p < len(run):
                    head = run[p]
                else:
                    self._cur_run = None
            heap = self._cur_heap
            if heap:
                h0 = heap[0]
                if head is None or h0 < head:
                    return h0, True
                return head, False
            if head is not None:
                return head, False
            # current bucket exhausted: load the next active one
            cur = self._cur = heapq.heappop(self._active)
            self._cur_end = (cur + 1) * self._width
            self._cur_run = self._runs.pop(cur, None)
            self._cur_pos = 0
            self._cur_heap = self._heaps.pop(cur, None)

    def _restage(self) -> None:
        """Fully drained: return to staging so the next bulk load
        re-tunes the bucket width to its own horizon."""
        self._staged = []
        self._bulk = []
        self._runs.clear()
        self._heaps.clear()
        self._active.clear()
        self._cur = None
        self._cur_end = -1e300
        self._cur_run = None
        self._cur_pos = 0
        self._cur_heap = None

    # ------------------------------------------------------------- interface
    def push(self, entry: Event) -> None:
        self._len += 1
        staged = self._staged
        if staged is not None:
            staged.append(entry)
            return
        if entry[0] < self._cur_end:
            # the draining bucket — the overwhelmingly common case for
            # operational (near-now) events. Past-t pushes clamp here
            # too: ``(t, seq)`` ordering still places them correctly
            # among the not-yet-popped entries.
            heap = self._cur_heap
            if heap is None:
                self._cur_heap = [entry]
            else:
                heapq.heappush(heap, entry)
            return
        idx = int(entry[0] / self._width)
        cur = self._cur
        if cur is not None and idx <= cur:
            # float-boundary guard: t >= _cur_end (a rounded product) can
            # still floor-divide into the draining bucket's index; never
            # re-activate a bucket at or behind the drain
            idx = cur + 1
        heaps = self._heaps
        heap = heaps.get(idx)
        if heap is None:
            heaps[idx] = [entry]
            if idx not in self._runs:
                heapq.heappush(self._active, idx)
            return
        heapq.heappush(heap, entry)

    def push_bulk_run(self, times, seq0: int, kind: str,
                      payloads=None) -> None:
        n = len(times)
        if n == 0:
            return
        self._len += n
        if self._staged is not None:
            # staging: keep the run columnar — _seal merges every run
            # (plus any scalar staged entries) without per-entry heap
            # discipline or double tuple builds
            self._bulk.append(
                (np.ascontiguousarray(times, dtype=np.float64), seq0,
                 kind, None if payloads is None else list(payloads)))
            return
        # sealed: near-now follow-on runs (the batched-drain pattern).
        # Small runs route per entry with the draining-bucket fast path
        # inlined; big runs take the vectorized merge below, which keeps
        # follow-ons on the sorted-run slice path instead of feeding the
        # overflow heaps one sift at a time
        if n < 64:
            if hasattr(times, "tolist"):
                times = times.tolist()
            entries = zip(times, range(seq0, seq0 + n), _repeat(kind),
                          payloads if payloads is not None else _repeat(None))
            cur_end = self._cur_end
            cur_heap = self._cur_heap
            hpush = heapq.heappush
            for e in entries:
                if e[0] < cur_end:
                    if cur_heap is None:
                        cur_heap = self._cur_heap = [e]
                    else:
                        hpush(cur_heap, e)
                else:
                    self._len -= 1         # push() re-counts the entry
                    self.push(e)
            return
        self._push_bulk_sealed(times, seq0, kind, payloads)

    def _push_bulk_sealed(self, times, seq0: int, kind: str,
                          payloads) -> None:
        """Vectorized sealed-mode bulk insert: split the run into the
        draining bucket's portion and per-future-bucket pieces (one
        ``astype`` + group scan), then *merge each piece into the
        bucket's sorted run* — one adaptive Timsort per piece, folding
        any overflow heap in along the way — so the subsequent drain
        slices run prefixes wholesale instead of paying a per-entry
        ``heappop`` against a deep overflow heap. Order contract is
        untouched: every bucket still holds ascending ``(t, seq)``."""
        if isinstance(times, np.ndarray):
            ts = np.ascontiguousarray(times, dtype=np.float64)
            tl = ts.tolist()
        else:                              # list in: no numpy round trip
            tl = times if isinstance(times, list) else list(times)
            ts = np.asarray(tl, dtype=np.float64)
        entries = list(zip(tl, range(seq0, seq0 + len(tl)),
                           _repeat(kind),
                           payloads if payloads is not None
                           else _repeat(None)))
        cur = self._cur
        mask_cur = ts < self._cur_end
        k_cur = int(np.count_nonzero(mask_cur))
        if k_cur == len(entries):
            piece, fut_entries, fts = entries, [], None
        elif k_cur == 0:
            piece, fut_entries, fts = [], entries, ts
        elif bool(mask_cur[:k_cur].all()):   # prefix split (sorted run)
            piece, fut_entries = entries[:k_cur], entries[k_cur:]
            fts = ts[k_cur:]
        else:
            sel = np.flatnonzero(mask_cur).tolist()
            piece = [entries[i] for i in sel]
            fut_entries = [e for i, e in enumerate(entries)
                           if not mask_cur[i]]
            fts = ts[~mask_cur]
        if piece:
            run = self._cur_run
            if run is not None and self._cur_pos < len(run):
                piece += run[self._cur_pos:]
            heap = self._cur_heap
            if heap:
                piece += heap
                self._cur_heap = None
            piece.sort()
            self._cur_run = piece
            self._cur_pos = 0
        if not fut_entries:
            return
        b = (fts / self._width).astype(np.int64)
        if cur is not None:
            # float-boundary guard (see push()): never re-activate a
            # bucket at or behind the drain
            np.maximum(b, cur + 1, out=b)
        if bool(np.any(b[:-1] > b[1:])):
            order = np.argsort(b, kind="stable")
            fut_entries = [fut_entries[i] for i in order.tolist()]
            b = b[order]
        starts = [0, *(np.flatnonzero(b[1:] != b[:-1]) + 1).tolist(),
                  len(fut_entries)]
        runs, heaps = self._runs, self._heaps
        for lo, hi in zip(starts, starts[1:]):
            bi = int(b[lo])
            piece = fut_entries[lo:hi]
            run = runs.get(bi)
            heap = heaps.pop(bi, None)
            fresh = run is None and heap is None
            if run is not None:
                piece += run
            if heap:
                piece += heap
            piece.sort()
            runs[bi] = piece
            if fresh:                      # else already in _active
                heapq.heappush(self._active, bi)

    def pop_batch(self, max_n: int,
                  until: Optional[float] = None) -> List[Event]:
        """Batched bucket drain (the carried ISSUE-5 follow-on): up to
        ``max_n`` events in exact ``(t, seq)`` order, slicing sorted-run
        *prefixes* wholesale — bounded by the overflow-heap head and the
        ``until`` horizon via bisect — instead of entry-at-a-time
        merges. Greedy like every backend (see the base class): batch
        partitions are backend-identical."""
        out: List[Event] = []
        if self._len == 0:
            return out
        if self._staged is not None:
            self._seal()
        take = min(max_n, self._len)
        while take > 0:
            run = self._cur_run
            p = self._cur_pos
            if run is not None and p >= len(run):
                run = self._cur_run = None
            heap = self._cur_heap
            if run is not None:
                hi = len(run)
                if heap:
                    # run entries strictly before the heap head pop in
                    # run order; (t, seq) never ties so left==right
                    hi = bisect_left(run, heap[0], p, hi)
                if until is not None:
                    # (until, inf) sorts after any (t<=until, seq, ...)
                    hi = bisect_right(run, (until, _INF), p, hi)
                if hi - p > take:
                    hi = p + take
                if hi > p:
                    out.extend(run[p:hi])
                    self._cur_pos = hi
                    self._len -= hi - p
                    take -= hi - p
                    continue
            if heap:
                h0 = heap[0]
                if (run is None or self._cur_pos >= len(run)
                        or h0 < run[self._cur_pos]):
                    if until is not None and h0[0] > until:
                        break
                    out.append(heapq.heappop(heap))
                    if not heap:
                        self._cur_heap = None
                    self._len -= 1
                    take -= 1
                    continue
                break                      # run head next, but > until
            if run is not None:
                break                      # only `until` blocks the run
            if not self._active:
                break
            cur = self._cur = heapq.heappop(self._active)
            self._cur_end = (cur + 1) * self._width
            self._cur_run = self._runs.pop(cur, None)
            self._cur_pos = 0
            self._cur_heap = self._heaps.pop(cur, None)
        if self._len == 0:
            self._restage()
        return out

    def _take(self, entry: Event, from_heap: bool) -> Event:
        self._len -= 1
        if from_heap:
            heap = self._cur_heap
            heapq.heappop(heap)
            if not heap:
                self._cur_heap = None
        else:
            self._cur_pos += 1
        if self._len == 0:
            self._restage()
        return entry

    def pop(self) -> Event:
        if self._len == 0:
            raise IndexError("pop from an empty ShardedQueue")
        entry, from_heap = self._head()
        return self._take(entry, from_heap)

    def pop_until(self, until: Optional[float]) -> Optional[Event]:
        if self._len == 0:
            return None
        entry, from_heap = self._head()
        if until is not None and entry[0] > until:
            return None
        return self._take(entry, from_heap)

    def peek(self) -> Optional[Event]:
        if self._len == 0:
            return None
        return self._head()[0]

    def __len__(self) -> int:
        return self._len


class EventEngine:
    """Seq-stamping event queue over a pluggable backend.

    The engine owns the one monotone ``seq`` counter (what makes ``(t,
    seq)`` a total order across backends) and the pending-event
    accounting the simulator's termination logic reads: kinds listed in
    ``background`` (the autoscaler's self-re-arming tick) are excluded
    from :attr:`pending_real`, so a control loop can ask "is there real
    work left?" without scanning the queue.

    ``pop(until=...)`` peeks before popping: an event beyond the horizon
    is *left in the queue* untouched — same ``(t, seq)``, no
    pop-and-requeue round trip — which is what makes a segmented
    ``run(until=...); run()`` byte-identical to one straight ``run()``
    (including ``events_processed``; pinned by
    ``tests/test_events.py``).
    """

    def __init__(self, backend="single_heap", *,
                 background: Tuple[str, ...] = (), **backend_kw):
        self.queue: EventQueue = (get_event_backend(backend, **backend_kw)
                                  if isinstance(backend, str) else backend)
        self.backend = self.queue.kind
        self.background = frozenset(background)
        self.pending_real = 0              # pending events minus background
        self._seq = 0

    def push(self, t: float, kind: str, payload) -> None:
        if kind not in self.background:
            self.pending_real += 1
        seq = self._seq
        self._seq = seq + 1
        self.queue.push((t, seq, kind, payload))

    def push_bulk(self, times, kind: str, payloads=None) -> int:
        """Bulk-push one same-kind run with contiguous seq stamps:
        entry ``i`` is ``(times[i], seq0 + i, kind, payloads[i])`` —
        byte-identical to pushing them one by one in run order, without
        the per-event call and tuple churn. ``times`` may be a numpy
        array or a list; returns the number pushed."""
        n = len(times)
        if n == 0:
            return 0
        seq0 = self._seq
        self._seq = seq0 + n
        if kind not in self.background:
            self.pending_real += n
        self.queue.push_bulk_run(times, seq0, kind, payloads)
        return n

    def pop(self, until: Optional[float] = None) -> Optional[Event]:
        """Next event in ``(t, seq)`` order, or None if the queue is
        empty or the next event lies beyond ``until`` (left in place)."""
        entry = self.queue.pop_until(until)
        if entry is None:
            return None
        if entry[2] not in self.background:
            self.pending_real -= 1
        return entry

    def pop_batch(self, max_n: int,
                  until: Optional[float] = None) -> List[Event]:
        """Up to ``max_n`` events in ``(t, seq)`` order — the batched
        drain for replay/probe loops whose handlers never schedule
        *before* the end of the batch they are consuming. NOT safe for
        ``Simulator.run()``: its handlers push near-now events (e.g.
        enqueue at ``t + hop_s``) that may sort before later entries of
        an already-popped batch."""
        batch = self.queue.pop_batch(max_n, until)
        if batch:
            bg = self.background
            if bg:
                self.pending_real -= sum(
                    1 for e in batch if e[2] not in bg)
            else:
                self.pending_real -= len(batch)
        return batch

    def peek_t(self) -> Optional[float]:
        entry = self.queue.peek()
        return entry[0] if entry is not None else None

    def __len__(self) -> int:
        return len(self.queue)

"""RQ-B: emulating worker nodes (paper §III.B, Fig. 2).

Pipeline, exactly as the figure prescribes:
  1. put a REAL worker under artificial load (``repro.serving.engine`` or the
     synthetic ground-truth sim) and save invocation telemetry;
  2. fit a model of the worker — "a simple linear regression model, or a more
     complicated model using machine learning": we provide closed-form ridge
     regression (jnp.linalg) and a small MLP trained with the framework's own
     AdamW;
  3. serve many emulated workers from the model (:class:`EmulatedServiceModel`
     plugs into the simulator as a service-time source);
  4. evaluate fidelity by replaying the step-1 load and comparing latency
     distributions (:func:`fidelity_report`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import FunctionConfig, TelemetryRecord


def telemetry_matrix(records: Sequence[TelemetryRecord]):
    X = np.array([r.features() for r in records], np.float32)
    y = np.array([r.latency for r in records], np.float32)
    ok = np.array([r.ok for r in records], np.float32)
    return X, y, ok


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------

@dataclass
class RidgeWorkerModel:
    """Closed-form ridge on standardized features; log-latency target."""
    w: np.ndarray = None
    mu: np.ndarray = None
    sd: np.ndarray = None
    resid_std: float = 0.05
    fail_rate: float = 0.0

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, ok: np.ndarray, lam: float = 1e-3):
        mu, sd = X.mean(0), X.std(0) + 1e-8
        Xs = jnp.asarray((X - mu) / sd)
        Xs = jnp.concatenate([Xs, jnp.ones((Xs.shape[0], 1))], 1)
        ty = jnp.log(jnp.asarray(y) + 1e-6)
        A = Xs.T @ Xs + lam * jnp.eye(Xs.shape[1])
        w = jnp.linalg.solve(A, Xs.T @ ty)
        resid = np.asarray(ty - Xs @ w)
        return RidgeWorkerModel(w=np.asarray(w), mu=mu, sd=sd,
                                resid_std=float(resid.std()),
                                fail_rate=float(1 - ok.mean()))

    def predict(self, feats: np.ndarray, rng: np.random.Generator):
        xs = (feats - self.mu) / self.sd
        xs = np.append(xs, 1.0)
        ly = float(xs @ self.w) + rng.normal(0, self.resid_std)
        return float(np.exp(ly)), rng.random() >= self.fail_rate


@dataclass
class MLPWorkerModel:
    """2-hidden-layer MLP on standardized features, trained with repro's AdamW.
    The "more complicated model using machine learning" of the paper."""
    params: dict = None
    mu: np.ndarray = None
    sd: np.ndarray = None
    resid_std: float = 0.05
    fail_rate: float = 0.0

    @staticmethod
    def _fwd(params, x):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        h = jnp.tanh(h @ params["w2"] + params["b2"])
        return (h @ params["w3"] + params["b3"])[..., 0]

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, ok: np.ndarray, *, hidden: int = 32,
            steps: int = 400, lr: float = 3e-3, seed: int = 0):
        from repro.train.optimizer import AdamW
        mu, sd = X.mean(0), X.std(0) + 1e-8
        Xs = jnp.asarray((X - mu) / sd)
        ty = jnp.log(jnp.asarray(y) + 1e-6)
        k = jax.random.split(jax.random.PRNGKey(seed), 3)
        d = X.shape[1]
        params = {
            "w1": 0.5 * jax.random.normal(k[0], (d, hidden)) / np.sqrt(d),
            "b1": jnp.zeros(hidden),
            "w2": 0.5 * jax.random.normal(k[1], (hidden, hidden)) / np.sqrt(hidden),
            "b2": jnp.zeros(hidden),
            "w3": 0.5 * jax.random.normal(k[2], (hidden, 1)) / np.sqrt(hidden),
            "b3": jnp.zeros(1),
        }
        opt = AdamW(lr=lr)
        state = opt.init(params)

        @jax.jit
        def step(params, state):
            def loss(p):
                return jnp.mean((MLPWorkerModel._fwd(p, Xs) - ty) ** 2)
            l, g = jax.value_and_grad(loss)(params)
            params, state = opt.update(g, state, params)
            return params, state, l

        for _ in range(steps):
            params, state, l = step(params, state)
        resid = np.asarray(MLPWorkerModel._fwd(params, Xs) - ty)
        return MLPWorkerModel(params=jax.tree.map(np.asarray, params), mu=mu,
                              sd=sd, resid_std=float(resid.std()),
                              fail_rate=float(1 - ok.mean()))

    def predict(self, feats: np.ndarray, rng: np.random.Generator):
        xs = (feats - self.mu) / self.sd
        ly = float(self._fwd(self.params, jnp.asarray(xs[None]))[0])
        ly += rng.normal(0, self.resid_std)
        return float(np.exp(ly)), rng.random() >= self.fail_rate


# ---------------------------------------------------------------------------
# Simulator adapter + fidelity
# ---------------------------------------------------------------------------

class EmulatedServiceModel:
    """Plugs a fitted worker model into the Simulator (Fig. 2 step 3):
    'whenever a function is called on this emulated worker, it should have
    the same kind of answer within the same timeframes with a comparable
    failure rate.'"""

    def __init__(self, model, seed: int = 0):
        self.model = model
        self.rng = np.random.default_rng(seed)

    def sample(self, cfg: FunctionConfig, *, batch_size: int, queue_len: int,
               prompt: int, cold: bool, fn_cost: float):
        feats = np.array([queue_len, max(batch_size - 1, 0), batch_size,
                          1.0 if cold else 0.0, prompt, cfg.gen_tokens,
                          fn_cost], np.float32)
        lat, ok = self.model.predict(feats, self.rng)
        # clip to the function timeout: an unclipped lognormal tail on a noisy
        # fit can otherwise stall the event loop with day-long service times
        return min(lat, cfg.timeout_s), ok


def fidelity_report(real: np.ndarray, emulated: np.ndarray,
                    real_fail: float = 0.0, emu_fail: float = 0.0) -> dict:
    """Distribution closeness of latencies: percentile errors + KS distance."""
    qs = [50, 90, 95, 99]
    rep = {}
    for q in qs:
        r, e = np.percentile(real, q), np.percentile(emulated, q)
        rep[f"p{q}_rel_err"] = abs(e - r) / max(r, 1e-9)
    rep["mean_rel_err"] = abs(emulated.mean() - real.mean()) / max(real.mean(), 1e-9)
    # two-sample KS statistic
    allv = np.sort(np.concatenate([real, emulated]))
    cdf_r = np.searchsorted(np.sort(real), allv, side="right") / len(real)
    cdf_e = np.searchsorted(np.sort(emulated), allv, side="right") / len(emulated)
    rep["ks"] = float(np.abs(cdf_r - cdf_e).max())
    rep["fail_rate_err"] = abs(real_fail - emu_fail)
    return rep

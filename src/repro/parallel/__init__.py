"""Parallel discrete-event simulation: partitioned sim processes with
conservative lookahead and a deterministic merge.

Public surface::

    from repro.parallel import run_partitioned, MergedRun

    merged = run_partitioned(build, n_partitions=8, collect="summary")
    merged.summary()            # == summarize() over the union
    merged.digest()             # byte-identity projection

See ``repro.parallel.runner`` for the synchronization model and
``repro.parallel.partition`` for the lookahead derivation, ceiling
apportionment, and the memory-bounded ``ResultSink``.
"""
from repro.parallel.partition import (ResultSink, combined_digest,
                                      conservative_window, partition_streams,
                                      split_ceiling)
from repro.parallel.runner import MergedRun, run_partitioned

__all__ = [
    "MergedRun",
    "ResultSink",
    "combined_digest",
    "conservative_window",
    "partition_streams",
    "run_partitioned",
    "split_ceiling",
]

"""Partitioning primitives for the parallel simulation runner.

A scenario parallelises along its *tenant streams*: each per-tenant
workload (one ``MixedWorkload`` / trace stream) is assigned to exactly
one partition, each partition owns a disjoint LB-branch subtree, and no
request, retry, hedge, or replica ever crosses a partition boundary.
That makes each partition an ordinary serial :class:`Simulator` — the
parallel layer adds no new event semantics, only a driver and a merge.

Three pieces live here, all pure functions of their inputs (no RNG, no
wall clock) so the coordinator's directives are byte-reproducible:

- :func:`conservative_window` — the lookahead bound. Cross-partition
  interaction in this testbed flows through capacity (gateway ceiling,
  fleet autoscale), and no capacity change can take effect faster than
  the shortest cold start or the autoscale tick period: a directive
  issued at a barrier cannot influence any event earlier than one
  window later, so partitions may free-run a full window between
  exchanges without missing an interaction.
- :func:`split_ceiling` — largest-remainder apportionment of a global
  concurrency ceiling across partitions, proportional to demand.
- :class:`ResultSink` — a drop-in for ``sim.results`` that folds each
  row into mergeable summary partials + a running stream digest instead
  of retaining row objects (10M ``RequestResult`` rows ≈ 3 GB; the sink
  keeps ~8 bytes per ok row).
"""
from __future__ import annotations

import hashlib
import math
from array import array
from typing import List, Optional, Sequence


def conservative_window(sim) -> float:
    """Lookahead window for one partition: min(shortest cold start,
    autoscale tick period), floored at 1 ms.

    Derivation: the only cross-partition couplings are capacity-shaped
    (gateway ``max_inflight`` splits, whole-fleet autoscale). A capacity
    grant can't convert into served work faster than a cold start warms
    a replica, and fleet-scale decisions only happen on autoscale
    ticks — so a barrier directive has no observable effect for at
    least this long, and exchanging summaries once per window is
    conservative (never late).
    """
    colds = []
    for name in sim.store.list():
        c = sim.store.get(name).cold_start_s
        colds.append(sim.cold_default if c is None else c)
    w = min(colds) if colds else sim.cold_default
    scaler = sim.autoscaler
    if scaler is not None:
        w = min(w, scaler.interval_s)
    return max(float(w), 1e-3)


def split_ceiling(total: int, demands: Sequence[float]) -> List[int]:
    """Apportion a global concurrency ceiling across partitions.

    Largest-remainder (Hamilton) apportionment proportional to
    ``demands``: allocations are integers, sum exactly to ``total``,
    ties break toward the lower partition index, and — when ``total``
    covers every partition — each partition keeps a floor of 1 so a
    momentarily idle tenant group is never locked out entirely (it
    could then never generate the occupancy that would win it quota
    back). Deterministic: same inputs ⇒ same split, which keeps
    barrier-coupled runs byte-reproducible.
    """
    k = len(demands)
    if k == 0:
        return []
    total = int(total)
    sd = float(sum(demands))
    if sd <= 0:
        demands = [1.0] * k
        sd = float(k)
    quota = [total * float(d) / sd for d in demands]
    alloc = [int(math.floor(q)) for q in quota]
    rem = total - sum(alloc)
    order = sorted(range(k), key=lambda i: (-(quota[i] - alloc[i]), i))
    for i in order[:rem]:
        alloc[i] += 1
    if total >= k:
        # floor of 1, funded by the largest allocation (lowest index on
        # ties). total >= k guarantees a donor with alloc >= 2 exists
        # whenever anyone sits at 0.
        for i in range(k):
            while alloc[i] == 0:
                j = max(range(k), key=lambda j: (alloc[j], -j))
                alloc[j] -= 1
                alloc[i] += 1
    return alloc


def partition_streams(streams, n_partitions: int, *, key=None) -> List[list]:
    """Bucket per-tenant workload streams into ``n_partitions`` groups
    by the cross-process-stable tenant hash — the same crc32 assignment
    ``tenant_hash`` routing uses (``repro.core.router.tenant_index``),
    so a union tree whose root routes by ``tenant_hash`` sends every
    request to the branch whose partition owns its stream. ``key`` maps
    a stream to its tenant name; the default reads the first profile's
    function name (each per-tenant stream carries one tenant's mix).
    """
    from repro.core.router import tenant_index
    buckets: List[list] = [[] for _ in range(n_partitions)]
    for s in streams:
        name = key(s) if key is not None else s.profiles[0].fn
        buckets[tenant_index(name, n_partitions)].append(s)
    return buckets


class ResultSink:
    """Memory-bounded stand-in for the ``sim.results`` list.

    Supports exactly the surface the hot path touches — ``append`` and
    ``len`` — and folds each appended row into (a) the
    :func:`repro.core.simulator.part_summary` partials and (b) the same
    per-row hash :func:`repro.core.simulator.stream_digest` computes, so
    a summary-collected partition still reports a byte-identity digest
    of its *result stream* (telemetry is separately disabled on the
    probes that need a sink). NOT usable with an attached autoscaler:
    the controller slices ``sim.results[last:]`` each tick, which needs
    the real list — ``run_partitioned`` only substitutes a sink when no
    autoscaler is bound.
    """

    __slots__ = ("n", "ok", "served", "cold", "t0", "t1", "_lat", "_h")

    def __init__(self):
        self.n = 0
        self.ok = 0
        self.served = 0
        self.cold = 0
        self.t0 = float("inf")
        self.t1 = -float("inf")
        self._lat = array("d")
        self._h = hashlib.sha256()

    def append(self, r) -> None:
        self.n += 1
        if r.arrival_t < self.t0:
            self.t0 = r.arrival_t
        if r.instance != "-":
            self.served += 1
        if r.cold_start:
            self.cold += 1
        if r.ok:
            self.ok += 1
            self._lat.append(r.finish_t - r.arrival_t)
            if r.finish_t > self.t1:
                self.t1 = r.finish_t
        self._h.update(repr(
            (r.rid, r.fn, r.ok, r.arrival_t, r.start_t, r.finish_t,
             r.cold_start, r.worker, r.instance, r.error)).encode())

    def __len__(self) -> int:
        return self.n

    def part(self) -> dict:
        """The :func:`repro.core.simulator.part_summary` dict of every
        row appended so far (mergeable via ``merge_part_summaries``)."""
        import numpy as np
        return {"n": self.n, "ok": self.ok, "served": self.served,
                "cold": self.cold,
                "lat": np.frombuffer(self._lat, dtype=np.float64)
                if self.n else np.zeros(0),
                "t0": self.t0, "t1": self.t1}

    def digest(self) -> str:
        """sha256[:16] over the result stream seen so far — the results
        portion of ``stream_digest``, computed incrementally."""
        return self._h.hexdigest()[:16]


def window_summary(sim) -> dict:
    """One partition's barrier report: the simulator's deterministic
    ``occupancy_summary`` plus the engine's next pending event time
    (``None`` when drained), which lets the coordinator skip empty
    windows instead of spinning barriers across idle gaps."""
    d = sim.occupancy_summary()
    d["next_t"] = sim.engine.peek_t()
    return d


def demand_of(summary: dict) -> float:
    """Apportionment weight from one barrier summary: outstanding work
    (queued + in flight, plus gateway-held slots when a front door is
    attached) with a +1 smoothing term so an all-idle barrier still
    yields a well-defined proportional split."""
    return (summary["queued"] + summary["inflight"]
            + summary.get("gw_inflight", 0) + 1.0)


def combined_digest(digests: Sequence[str]) -> str:
    """Order-sensitive combination of per-partition digests — the
    byte-identity projection of a summary-collected merged run (full
    collects hash the merged streams directly via ``stream_digest``)."""
    h = hashlib.sha256()
    for d in digests:
        h.update(d.encode())
    return h.hexdigest()[:16]


def maybe_attach_sink(sim) -> Optional[ResultSink]:
    """Swap ``sim.results`` for a :class:`ResultSink` when legal (no
    autoscaler bound — see the class docstring). Returns the sink, or
    None when the real list must stay."""
    if sim.autoscaler is not None:
        return None
    if len(sim.results):        # rows already recorded: too late to fold
        return None
    sink = ResultSink()
    sim.results = sink
    return sink

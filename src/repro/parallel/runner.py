"""Partitioned simulation runner: conservative lookahead + deterministic merge.

The coordinator drives K independent :class:`Simulator` instances — one
per tenant-stream / LB-branch partition, built by a user callback — and
merges their result/telemetry/decision/fault streams back into one
byte-stable ``(t, seq)``-ordered record (:class:`MergedRun`). Two
execution modes share one driver protocol, so they are byte-identical
by construction:

- ``inline``   — partitions advance in-process, one after another (the
  reference; ``parallelism=1`` degenerates to a plain serial run).
- ``process``  — each partition runs in a forked worker process and the
  coordinator speaks a small message protocol over a pipe. The *fork*
  start method is required: the builder closure is inherited, never
  pickled.

Two synchronization regimes:

- **Fast path (no global coupling).** With no platform-wide
  ``max_inflight`` and no forced window, partitions share nothing:
  per-tenant token buckets are partition-local by construction (a
  tenant lives in exactly one partition), so every partition free-runs
  to completion and only the merge is serial. This is the documented
  "partition-local quota split" — *exactly* equivalent to the serial
  run whenever tenants don't share branches, which is the common
  multi_tenant / noisy_neighbor / Azure-trace shape.
- **Windowed barriers (global coupling).** A platform-wide
  ``max_inflight`` (or an explicit ``window_s``) switches to
  conservative-lookahead rounds: every partition advances to the next
  window edge, reports its deterministic occupancy summary, and the
  coordinator re-apportions the global ceiling across partition-local
  gateways (largest-remainder on demand — ``partition.split_ceiling``)
  before the next round. The window is the natural lookahead — no
  capacity directive can take effect sooner than the shortest cold
  start or the autoscale tick period (``partition.conservative_window``)
  — so exchanging once per window never misses an interaction.

Merge determinism: every per-partition stream is nondecreasing in time,
so a k-way merge keyed ``(t, partition, position)`` is a total order
independent of process scheduling; same seed + same partition count ⇒
byte-identical merged output, and ``stream_digest`` applies to a
:class:`MergedRun` exactly as to a :class:`Simulator`.
"""
from __future__ import annotations

import heapq
import multiprocessing
from typing import Callable, List, Optional

from repro.parallel.partition import (combined_digest, conservative_window,
                                      demand_of, maybe_attach_sink,
                                      split_ceiling, window_summary)


# ------------------------------------------------------------ collection
def _collect(sim, mode: str, sink) -> dict:
    """One partition's final payload: counters, decision/fault logs, the
    mergeable summary partial, the stream digest, and (``mode="full"``)
    the raw result/telemetry/workflow streams."""
    from repro.core.simulator import part_summary, stream_digest
    counters = {
        "events_processed": sim.events_processed,
        "arrivals_seen": sim.arrivals_seen,
        "hedges_seen": sim.hedges_seen,
        "cold_starts_total": sim.cold_starts_total,
        "retries_scheduled": sim.retries_scheduled,
        "retries_shed": sim.retries_shed,
        "retries_dropped": sim.retries_dropped,
        "results": len(sim.results),
        "arrivals_by_fn": dict(sim.arrivals_by_fn),
    }
    if sim.gateway is not None:
        counters["gw_admitted"] = sim.gateway.admitted_total
        counters["gw_shed"] = sim.gateway.shed_total
    payload = {
        "counters": counters,
        "fault_log": sim.fault_log(),
        "placement": list(sim.placement_records),
        "routing": list(sim.routing_records),
        "gateway": list(sim.gateway_records),
    }
    if sink is not None:
        payload["part"] = sink.part()
        payload["digest"] = sink.digest()
    else:
        payload["part"] = part_summary(sim.results)
        payload["digest"] = stream_digest(sim)
    if mode == "full":
        payload["results"] = list(sim.results)
        payload["telemetry"] = list(sim.telemetry)
        payload["workflow_results"] = list(sim.workflow_results)
    return payload


# ------------------------------------------------------- driver protocol
# One protocol, two transports. Ops: run_until(t) -> summary,
# run_all/drain -> summary, set_ceiling(c) -> None, collect(mode) ->
# payload, close. ``start`` issues an op, ``finish`` returns its reply —
# split so the coordinator can issue one op to *every* partition before
# waiting on any (that concurrency is the whole point of process mode).

class _InlineDriver:
    """Reference transport: the partition simulator lives in-process and
    every op executes synchronously in ``start`` (``finish`` just hands
    the stored reply back). Byte-identical to process mode because both
    run exactly this op sequence against identical simulators."""

    def __init__(self, build, k: int, n: int, collect_mode: str):
        self.sim = build(k, n)
        self.sink = (maybe_attach_sink(self.sim)
                     if collect_mode == "summary" else None)
        self.window = conservative_window(self.sim)
        self._reply = None

    def start(self, op: str, *a) -> None:
        sim = self.sim
        if op == "run_until":
            sim.run(until=a[0])
            self._reply = window_summary(sim)
        elif op in ("run_all", "drain"):
            sim.run()
            self._reply = window_summary(sim)
        elif op == "set_ceiling":
            if sim.gateway is not None:
                sim.gateway.set_ceiling(a[0])
            self._reply = None
        elif op == "collect":
            self._reply = _collect(sim, a[0], self.sink)
        else:
            raise ValueError(f"unknown driver op {op!r}")

    def finish(self):
        reply, self._reply = self._reply, None
        return reply

    def close(self) -> None:
        pass


def _worker_main(conn, build, k: int, n: int, collect_mode: str) -> None:
    """Process-mode partition loop: build the simulator, report the
    lookahead window, then serve coordinator ops until ``close``. Any
    exception is shipped back as an ``("error", traceback)`` reply so
    the coordinator can surface it instead of hanging on a dead pipe."""
    try:
        sim = build(k, n)
        sink = maybe_attach_sink(sim) if collect_mode == "summary" else None
        conn.send(("ready", conservative_window(sim)))
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "run_until":
                sim.run(until=msg[1])
                conn.send(("ok", window_summary(sim)))
            elif op in ("run_all", "drain"):
                sim.run()
                conn.send(("ok", window_summary(sim)))
            elif op == "set_ceiling":
                if sim.gateway is not None:
                    sim.gateway.set_ceiling(msg[1])
                conn.send(("ok", None))
            elif op == "collect":
                conn.send(("ok", _collect(sim, msg[1], sink)))
            elif op == "close":
                conn.send(("ok", None))
                conn.close()
                return
            else:
                conn.send(("error", f"unknown driver op {op!r}"))
    except BaseException as e:           # noqa: BLE001 — shipped to coordinator
        import traceback
        try:
            conn.send(("error",
                       f"{type(e).__name__}: {e}\n{traceback.format_exc()}"))
        except Exception:
            pass


class _ProcessDriver:
    """Pipe transport to a forked partition worker (``_worker_main``)."""

    def __init__(self, ctx, build, k: int, n: int, collect_mode: str):
        self.k = k
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_worker_main,
                                args=(child, build, k, n, collect_mode),
                                daemon=True)
        self.proc.start()
        child.close()
        tag, val = self.conn.recv()
        if tag != "ready":
            raise RuntimeError(f"partition {k} failed to build:\n{val}")
        self.window = val

    def start(self, op: str, *a) -> None:
        self.conn.send((op,) + a)

    def finish(self):
        tag, val = self.conn.recv()
        if tag == "error":
            raise RuntimeError(f"partition {self.k} failed:\n{val}")
        return val

    def close(self) -> None:
        try:
            self.start("close")
            self.finish()
        except Exception:
            pass
        self.proc.join(timeout=30)
        if self.proc.is_alive():
            self.proc.terminate()
        try:
            self.conn.close()
        except Exception:
            pass


# ------------------------------------------------------------------ merge
def _merge_stream(parts: List[list], key) -> list:
    """k-way merge of per-partition streams, each nondecreasing under
    ``key``, into the total order ``(key, partition, position)`` — the
    ``(t, seq)`` contract. Ties across partitions break toward the lower
    partition index; the decorated tuples are unique, so the payload
    objects themselves are never compared."""
    runs = [((key(x), k, i, x) for i, x in enumerate(lst))
            for k, lst in enumerate(parts)]
    return [e[3] for e in heapq.merge(*runs)]


def _line_t(line: str) -> float:
    """Timestamp of one decision/fault log line — every record layer
    writes ``t=<float> ...`` as its prefix."""
    return float(line[2:line.index(" ", 2)])


def _merge_lines(parts: List[List[str]]) -> List[str]:
    return _merge_stream(parts, _line_t)


def _merge_counters(parts: List[dict]) -> dict:
    out: dict = {}
    by_fn: dict = {}
    for c in parts:
        for k, v in c.items():
            if k == "arrivals_by_fn":
                for fn, n in v.items():
                    by_fn[fn] = by_fn.get(fn, 0) + n
            else:
                out[k] = out.get(k, 0) + v
    out["arrivals_by_fn"] = by_fn
    return out


class MergedRun:
    """The deterministic union of K partition runs.

    Exposes the same reporting surface a :class:`Simulator` does —
    ``results`` / ``telemetry`` / ``workflow_results`` streams (full
    collects), ``placement_log()`` / ``routing_log()`` /
    ``gateway_log()`` / ``fault_log()``, and ``summary()`` — so golden
    and equivalence suites (``stream_digest``) apply unchanged. Also
    carries the merge provenance: per-partition ``digests``, summed
    ``counters``, and the barrier exchange history (``barriers``)."""

    def __init__(self, payloads: List[dict], *, window_s, mode: str,
                 collect: str, barriers: List[dict]):
        self.n_partitions = len(payloads)
        self.window_s = window_s
        self.mode = mode
        self.collect = collect
        self.barriers = barriers
        self.digests = [p["digest"] for p in payloads]
        self._parts = [p["part"] for p in payloads]
        self.counters = _merge_counters([p["counters"] for p in payloads])
        self.placement_records = _merge_lines(
            [p["placement"] for p in payloads])
        self.routing_records = _merge_lines([p["routing"] for p in payloads])
        self.gateway_records = _merge_lines([p["gateway"] for p in payloads])
        self._fault_lines = _merge_lines(
            [p["fault_log"].splitlines() for p in payloads])
        if collect == "full":
            self.results = _merge_stream(
                [p["results"] for p in payloads], lambda r: r.finish_t)
            self.telemetry = _merge_stream(
                [p["telemetry"] for p in payloads], lambda t: t.t)
            self.workflow_results = _merge_stream(
                [p["workflow_results"] for p in payloads],
                lambda w: w.finish_t)
        else:
            self.results = []
            self.telemetry = []
            self.workflow_results = []

    # ------------------------------------------------ simulator-shaped API
    def placement_log(self) -> str:
        return "\n".join(self.placement_records)

    def routing_log(self) -> str:
        return "\n".join(self.routing_records)

    def gateway_log(self) -> str:
        return "\n".join(self.gateway_records)

    def fault_log(self) -> str:
        return "\n".join(self._fault_lines)

    def summary(self) -> dict:
        """Exactly ``summarize()`` over the union of all partitions'
        results, computed from mergeable partials (works for summary
        collects too, where the raw rows were never shipped)."""
        from repro.core.simulator import merge_part_summaries
        return merge_part_summaries(self._parts)

    def digest(self) -> str:
        """Byte-identity projection: ``stream_digest`` of the merged
        streams (full collects), else the order-sensitive combination
        of the per-partition stream digests."""
        if self.collect == "full":
            from repro.core.simulator import stream_digest
            return stream_digest(self)
        return combined_digest(self.digests)


# ------------------------------------------------------------ coordinator
def _fork_available() -> bool:
    return "fork" in multiprocessing.get_all_start_methods()


def run_partitioned(build: Callable, n_partitions: int, *,
                    window_s: Optional[float] = None,
                    mode: str = "auto",
                    processes: Optional[int] = None,
                    max_inflight: Optional[int] = None,
                    collect: str = "full") -> MergedRun:
    """Run a K-partitioned scenario and merge the streams.

    ``build(k, n_partitions)`` must return a fully *loaded* Simulator
    for partition ``k`` — its own LB subtree, config store, and its
    disjoint share of the tenant streams (``partition.partition_streams``
    buckets them the way ``tenant_hash`` routing would). The callback
    runs inside the worker process in process mode, so generation
    parallelises with everything else.

    ``max_inflight`` turns on the barrier-coupled regime: partition
    gateways are treated as shards of one platform-wide ceiling,
    re-apportioned from exchanged occupancy at every window barrier.
    ``window_s=None`` derives the lookahead from the scenario
    (``conservative_window``); setting it forces barrier cadence even
    uncoupled (useful for invariants tests). With neither, the
    partition-local fast path free-runs every partition to completion.

    ``collect="summary"`` skips shipping raw result/telemetry rows and
    (when no autoscaler is bound) folds results through a
    ``ResultSink`` in the worker — the 10M-row memory/IPC path;
    ``summary()``, ``counters``, decision logs, and per-partition
    digests still work. ``processes`` caps concurrently-live partitions
    on the fast path (waves); barrier-coupled runs keep all partitions
    live, as the exchange requires.
    """
    if n_partitions < 1:
        raise ValueError("n_partitions must be >= 1")
    if collect not in ("full", "summary"):
        raise ValueError(f"collect must be 'full' or 'summary', "
                         f"got {collect!r}")
    if mode == "auto":
        mode = ("process" if n_partitions > 1 and _fork_available()
                else "inline")
    if mode not in ("inline", "process"):
        raise ValueError(f"mode must be 'auto', 'inline' or 'process', "
                         f"got {mode!r}")
    ctx = multiprocessing.get_context("fork") if mode == "process" else None

    def make(k: int):
        if mode == "process":
            return _ProcessDriver(ctx, build, k, n_partitions, collect)
        return _InlineDriver(build, k, n_partitions, collect)

    K = n_partitions
    coupled = max_inflight is not None
    windowed = coupled or window_s is not None
    barriers: List[dict] = []
    payloads: List[Optional[dict]] = [None] * K

    if not windowed:
        # fast path: nothing is shared, so partitions free-run in waves
        wave = K if processes is None else max(1, int(processes))
        for lo in range(0, K, wave):
            ks = list(range(lo, min(lo + wave, K)))
            drivers = [make(k) for k in ks]
            try:
                for d in drivers:
                    d.start("run_all")
                for d in drivers:
                    d.finish()
                for d in drivers:
                    d.start("collect", collect)
                for d, k in zip(drivers, ks):
                    payloads[k] = d.finish()
            finally:
                for d in drivers:
                    d.close()
        return MergedRun(payloads, window_s=None, mode=mode,
                         collect=collect, barriers=barriers)

    drivers = [make(k) for k in range(K)]
    try:
        w = (float(window_s) if window_s is not None
             else min(d.window for d in drivers))
        if coupled:
            # pre-run split: no occupancy yet, so apportion evenly
            ceilings = split_ceiling(max_inflight, [1.0] * K)
            for d, c in zip(drivers, ceilings):
                d.start("set_ceiling", c)
            for d in drivers:
                d.finish()
        target = w
        while True:
            for d in drivers:
                d.start("run_until", target)
            summaries = [d.finish() for d in drivers]
            rec = {"t": target,
                   "pending": [s["pending_real"] for s in summaries]}
            if coupled:
                demands = [demand_of(s) for s in summaries]
                ceilings = split_ceiling(max_inflight, demands)
                for d, c in zip(drivers, ceilings):
                    d.start("set_ceiling", c)
                for d in drivers:
                    d.finish()
                rec["demands"] = demands
                rec["ceilings"] = ceilings
            barriers.append(rec)
            if all(s["pending_real"] == 0 for s in summaries):
                break
            # skip idle gaps: jump the barrier clock when every live
            # partition's next event is beyond the next window edge
            # (exchanges across a dead gap would re-derive identical
            # directives from unchanged summaries)
            nxt = target + w
            nts = [s["next_t"] for s in summaries
                   if s["pending_real"] > 0 and s["next_t"] is not None]
            if nts and min(nts) > nxt:
                nxt = min(nts) + w
            target = nxt
        for d in drivers:
            d.start("drain")            # settle background events
        for d in drivers:
            d.finish()
        for d in drivers:
            d.start("collect", collect)
        for k, d in enumerate(drivers):
            payloads[k] = d.finish()
    finally:
        for d in drivers:
            d.close()
    return MergedRun(payloads, window_s=w, mode=mode, collect=collect,
                     barriers=barriers)

"""Data pipeline: deterministic synthetic token streams + memmap-backed files,
sharded per data-parallel host group, with background prefetch.

The synthetic stream is a fixed-seed Zipf-ish mixture so train loss curves are
reproducible across restarts (the checkpoint test resumes mid-stream by step
index — the stream is stateless-indexable, a requirement for elastic restore).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"        # synthetic | memmap
    path: Optional[str] = None
    dp_rank: int = 0
    dp_size: int = 1


class TokenStream:
    """Stateless-indexable batches: batch(i) is pure in (seed, i, dp_rank)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.kind == "memmap":
            assert cfg.path, "memmap stream needs a path"
            self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        else:
            self._data = None
        assert cfg.global_batch % cfg.dp_size == 0
        self.local_batch = cfg.global_batch // cfg.dp_size

    def batch(self, step: int) -> dict:
        c = self.cfg
        if self._data is not None:
            n = self._data.shape[0]
            rng = np.random.default_rng((c.seed, step, c.dp_rank))
            starts = rng.integers(0, n - c.seq_len - 1, size=self.local_batch)
            toks = np.stack([self._data[s:s + c.seq_len + 1] for s in starts])
        else:
            rng = np.random.default_rng((c.seed, step, c.dp_rank))
            # Zipf-ish marginal + short-range repetition => learnable signal
            base = rng.zipf(1.3, size=(self.local_batch, c.seq_len + 1))
            toks = (base % (c.vocab_size - 2)) + 2
            rep = rng.random((self.local_batch, c.seq_len + 1)) < 0.3
            toks[:, 1:][rep[:, 1:]] = toks[:, :-1][rep[:, 1:]]   # bigram signal
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering on the host)."""

    def __init__(self, stream: TokenStream, start_step: int = 0, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self.q.put(stream.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self) -> dict:
        return self.q.get()

    def stop(self):
        self._stop.set()

"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

  PYTHONPATH=src python -m repro.telemetry.report [artifacts/dryrun]
"""
from __future__ import annotations

import json
import os
import sys

from repro.configs import SHAPES, assigned_archs

MESHES = ("single", "multi")


def load(art_dir):
    cells = {}
    for f in os.listdir(art_dir):
        if f.endswith(".json"):
            with open(os.path.join(art_dir, f)) as fh:
                d = json.load(fh)
            cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def fmt_ms(s):
    return f"{s*1e3:.1f}" if s < 10 else f"{s*1e3:.0f}"


def dryrun_table(cells, mesh):
    rows = ["| arch | shape | status | peak GiB | fits | compile s | collectives (per-device ops) |",
            "|---|---|---|---|---|---|---|"]
    for arch in assigned_archs():
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None:
                rows.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if d["status"] == "skip":
                rows.append(f"| {arch} | {shape} | skip | — | — | — | {d['reason']} |")
                continue
            if d["status"] != "ok":
                rows.append(f"| {arch} | {shape} | ERROR | | | | |")
                continue
            r = d["report"]
            ops = ", ".join(f"{k}×{v}" for k, v in sorted(r["coll_ops"].items()))
            rows.append(
                f"| {arch} | {shape} | ok | {r['mem']['peak_gib']:.2f} | "
                f"{'✓' if d['fits'] else '✗'} | {d['compile_s']:.0f} | {ops} |")
    return "\n".join(rows)


def roofline_table(cells, mesh="single"):
    rows = ["| arch | shape | compute ms | memory ms | collective ms | bottleneck "
            "| useful FLOPs | roofline frac | one-line lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in assigned_archs():
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if not d or d.get("status") != "ok":
                continue
            r = d["report"]
            lever = LEVERS.get(r["bottleneck"], "")
            rows.append(
                f"| {arch} | {shape} | {fmt_ms(r['t_compute'])} | "
                f"{fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} | "
                f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
                f"{r['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(rows)


LEVERS = {
    "memory": "fuse SSM/attn HBM traffic (Pallas kernel path) / cast & remat policy",
    "collective": "weight-stationary decode matmuls; defer FSDP gathers; compress pod sync",
    "compute": "cut remat recompute; exact-triangle attention; pad-free head sharding",
}


def worst_cells(cells, n=6, mesh="single"):
    rs = [(d["report"]["roofline_fraction"], k) for k, d in cells.items()
          if d.get("status") == "ok" and k[2] == mesh]
    rs.sort()
    return rs[:n]


def main():
    art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    cells = load(art)
    n_ok = sum(1 for d in cells.values() if d.get("status") == "ok")
    n_skip = sum(1 for d in cells.values() if d.get("status") == "skip")
    n_fit = sum(1 for d in cells.values() if d.get("fits"))
    print(f"<!-- {n_ok} ok / {n_skip} skip / {len(cells)} total; "
          f"{n_fit}/{n_ok} fit 16GiB -->\n")
    for mesh in MESHES:
        print(f"### Dry-run — {mesh} mesh "
              f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)\n")
        print(dryrun_table(cells, mesh))
        print()
    print("### Roofline (single-pod, per-device terms)\n")
    print(roofline_table(cells))
    print("\nWorst roofline fractions:", [(f"{f:.4f}", *k[:2])
                                          for f, k in worst_cells(cells)])


if __name__ == "__main__":
    main()

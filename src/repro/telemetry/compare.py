"""Diff two dry-run artifact dirs (baseline vs optimized) for §Perf.

  PYTHONPATH=src python -m repro.telemetry.compare \
      artifacts/dryrun_baseline artifacts/dryrun [--cells a,b,...]
"""
from __future__ import annotations

import json
import os
import sys


def load(d):
    out = {}
    for f in os.listdir(d):
        if f.endswith(".json"):
            j = json.load(open(os.path.join(d, f)))
            if j.get("status") == "ok":
                out[(j["arch"], j["shape"], j["mesh"])] = j
    return out


def main():
    base = load(sys.argv[1])
    new = load(sys.argv[2])
    print("| cell | term | baseline | optimized | Δ |")
    print("|---|---|---|---|---|")
    for key in sorted(set(base) & set(new)):
        b, n = base[key]["report"], new[key]["report"]
        cell = f"{key[0]} × {key[1]} ({key[2]})"
        changed = False
        for term, fmt in (("t_compute", 1e3), ("t_memory", 1e3),
                          ("t_collective", 1e3)):
            bv, nv = b[term], n[term]
            if bv > 0 and abs(nv - bv) / bv > 0.05:
                changed = True
        pk_b, pk_n = b["mem"]["peak_gib"], n["mem"]["peak_gib"]
        if abs(pk_n - pk_b) / max(pk_b, 1e-9) > 0.05:
            changed = True
        if not changed:
            continue
        for term, label in (("t_compute", "compute ms"),
                            ("t_memory", "memory ms"),
                            ("t_collective", "collective ms")):
            bv, nv = b[term] * 1e3, n[term] * 1e3
            d = (nv - bv) / bv * 100 if bv else 0
            print(f"| {cell} | {label} | {bv:.1f} | {nv:.1f} | {d:+.0f}% |")
        d = (pk_n - pk_b) / pk_b * 100
        print(f"| {cell} | peak GiB | {pk_b:.1f} | {pk_n:.1f} | {d:+.0f}% |")
        fb, fn = b["roofline_fraction"], n["roofline_fraction"]
        print(f"| {cell} | roofline frac | {fb:.4f} | {fn:.4f} | "
              f"{(fn-fb)/max(fb,1e-9)*100:+.0f}% |")


if __name__ == "__main__":
    main()

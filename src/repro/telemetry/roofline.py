"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §7).

The container is CPU-only; TPU v5e is the *target*. We therefore derive the
three roofline terms from the compiled (SPMD-partitioned, per-device) module:

    compute    = flops_per_device              / PEAK_FLOPS      (197e12 bf16)
    memory     = hbm_bytes_per_device          / HBM_BW          (819e9)
    collective = ici_link_bytes_per_device     / LINK_BW         (50e9)

``cost_analysis()`` provides per-device FLOPs and bytes. Collective bytes are
NOT in cost_analysis: we parse the post-partitioning HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, applying ring factors (AR 2(n-1)/n, AG/RS/A2A (n-1)/n,
CP 1) with n = replica-group size.

Useful-FLOPs ratio: MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(prefill/decode) vs flops_pd × n_devices — catches remat/dispatch/padding waste.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict

# --- TPU v5e-class hardware constants (per chip) ---------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (spec-prescribed constant)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-gather.5 = bf16[16,128]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


@dataclass
class CollectiveStats:
    ops: Dict[str, int] = field(default_factory=dict)
    raw_bytes: Dict[str, float] = field(default_factory=dict)   # operand bytes
    link_bytes: float = 0.0                                     # ring-adjusted

    def add(self, kind: str, nbytes: float, group_size: int):
        kind = kind.replace("-start", "")
        self.ops[kind] = self.ops.get(kind, 0) + 1
        self.raw_bytes[kind] = self.raw_bytes.get(kind, 0.0) + nbytes
        n = max(group_size, 1)
        ring = (n - 1) / n
        if kind == "all-reduce":
            self.link_bytes += 2 * nbytes * ring
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            self.link_bytes += nbytes * ring
        else:  # collective-permute
            self.link_bytes += nbytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective operand bytes from post-SPMD per-device HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if not any(k in line for k in _COLL_KINDS):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(dtype, dims)
        # result of all-gather is the gathered buffer; use result size for AG,
        # operand (=result here as parsed) for others — both are the transferred
        # volume under the ring model given the factors applied in add().
        g = _GROUPS_RE.search(line)
        if g:
            group_size = g.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group_size = int(gi.group(2)) if gi else 2
        stats.add(kind, nbytes, group_size)
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_pd: float
    bytes_pd: float
    coll_link_bytes_pd: float
    coll_ops: Dict[str, int]
    coll_raw_bytes: Dict[str, float]
    mem: Dict[str, float]              # memory_analysis fields (per device)
    model_flops: float                 # 6·N·D or 2·N·D (total, all devices)
    # derived:
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0
    roofline_fraction: float = 0.0     # model_flops-time / max-term

    def derive(self):
        self.t_compute = self.flops_pd / PEAK_FLOPS
        self.t_memory = self.bytes_pd / HBM_BW
        self.t_collective = self.coll_link_bytes_pd / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo_flops = self.flops_pd * self.n_devices
        self.useful_flops_ratio = (self.model_flops / total_hlo_flops
                                   if total_hlo_flops else 0.0)
        # fraction of the chip's compute roofline that useful FLOPs achieve if
        # the program runs at the dominant term's speed:
        t_star = max(terms.values())
        ideal = self.model_flops / (self.n_devices * PEAK_FLOPS)
        self.roofline_fraction = ideal / t_star if t_star else 0.0
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1, sort_keys=True)


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N_active·D (train) else 2·N_active·D."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _mem_dict(ma) -> Dict[str, float]:
    return {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    }


def analyze(compiled, *, arch: str, shape, mesh_name: str, n_devices: int,
            cfg) -> RooflineReport:
    """Single-compile analysis (exact only for scan-free programs)."""
    ca = compiled.cost_analysis() or {}
    stats = parse_collectives(compiled.as_text())
    rep = RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_pd=float(ca.get("flops", 0.0)),
        bytes_pd=float(ca.get("bytes accessed", 0.0)),
        coll_link_bytes_pd=stats.link_bytes,
        coll_ops=stats.ops, coll_raw_bytes=stats.raw_bytes,
        mem=_mem_dict(compiled.memory_analysis()),
        model_flops=model_flops(cfg, shape))
    return rep.derive()


def analyze_from_parts(*, ma, cost: dict, arch: str, shape, mesh_name: str,
                       n_devices: int, cfg) -> RooflineReport:
    """Memory from the full scanned compile; flops/bytes/collectives from the
    unrolled shallow probes (see launch.dryrun.probe_costs)."""
    rep = RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_pd=cost["flops"], bytes_pd=cost["bytes"],
        coll_link_bytes_pd=cost["link_bytes"],
        coll_ops=cost["ops"], coll_raw_bytes=cost["raw_bytes"],
        mem=_mem_dict(ma), model_flops=model_flops(cfg, shape))
    return rep.derive()

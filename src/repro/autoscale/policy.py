"""Pluggable autoscaler policies (registry mirrors the LB-policy registry).

A policy maps the observed :class:`~repro.autoscale.metrics.MetricsWindow`
to a desired replica count (replica = one LB branch of
``workers_per_replica`` workers). Policies are pure functions of the
window plus their own explicitly-seeded state, so two same-seed simulator
runs produce byte-identical decision streams.

The menu spans the design space the FaaS literature actually compares:

- ``static``             no-op; the paper's provision-for-X replicate recipe
- ``reactive``           queue/utilization threshold scaler (AWS-style)
- ``target_concurrency`` Knative KPA: stable window + panic window
- ``predictive``         Holt linear-trend (EWMA level+trend) rate forecast,
                         built for ``daily_cycle`` envelopes
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List

from repro.autoscale.metrics import MetricsWindow

AUTOSCALERS: Dict[str, Callable[..., "AutoscalePolicy"]] = {}


def register_autoscaler(cls):
    """Class decorator: add an AutoscalePolicy subclass to the registry."""
    AUTOSCALERS[cls.name] = cls
    return cls


def get_autoscaler(name: str, **params) -> "AutoscalePolicy":
    """Construct a registered policy by name: the config/CLI hook."""
    if name not in AUTOSCALERS:
        raise KeyError(f"autoscaler policy {name!r} not registered "
                       f"(have: {sorted(AUTOSCALERS)})")
    return AUTOSCALERS[name](**params)


def list_autoscalers() -> List[str]:
    return sorted(AUTOSCALERS)


class AutoscalePolicy:
    """Base interface: desired replica count given the metrics window."""

    name = "base"

    def desired_replicas(self, window: MetricsWindow, current: int) -> int:
        raise NotImplementedError


@register_autoscaler
@dataclass
class StaticPolicy(AutoscalePolicy):
    """No-op baseline: whatever the tree was built with, it keeps.

    This is the paper's scaling story so far — ``replicate(tree, k)`` at
    deploy time — expressed as a policy so the benchmark cost/latency
    accounting is identical across the whole menu.
    """

    name = "static"

    def desired_replicas(self, window, current):
        return current


@register_autoscaler
@dataclass
class ReactivePolicy(AutoscalePolicy):
    """Threshold scaler on outstanding work per worker.

    Scale up proportionally (straight to the load-implied size, not +1
    steps — flash crowds don't wait) when the *latest* sample exceeds
    ``upper``; scale down toward the load-implied size only when the
    *window average* falls below ``lower``, so one calm tick inside a
    burst never sheds capacity.
    """

    target_load: float = 4.0     # design point: outstanding reqs per worker
    upper: float = 6.0           # latest-sample load that triggers scale-up
    lower: float = 1.0           # window-average load that allows scale-down
    name = "reactive"

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        if last.load_per_worker > self.upper:
            return math.ceil(current * last.load_per_worker / self.target_load)
        if window.avg("load_per_worker") < self.lower:
            down = math.ceil(
                current * window.avg("load_per_worker") / self.target_load)
            return min(current, max(1, down))
        return current


@register_autoscaler
@dataclass
class TargetConcurrencyPolicy(AutoscalePolicy):
    """Knative-KPA-style scaler: size the fleet so observed concurrency
    per worker sits at ``target``; a short panic window overrides the
    stable window when concurrency doubles, and freezes scale-down while
    panicking."""

    target: float = 4.0          # concurrent requests per worker at SLO
    panic_window: int = 2        # samples in the panic (burst) window
    panic_threshold: float = 2.0  # panic when panic-desired >= thr * current
    panic_hold_ticks: int = 8    # ticks scale-down stays frozen after panic
    _panic_left: int = field(default=0, repr=False)
    name = "target_concurrency"

    def _size(self, concurrency: float, workers_per_replica: float) -> int:
        return math.ceil(concurrency / (self.target * workers_per_replica))

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        wpr = last.workers / max(last.replicas, 1)
        stable = self._size(window.avg("concurrency"), wpr)
        panic = self._size(window.avg("concurrency", self.panic_window), wpr)
        if panic >= self.panic_threshold * current:
            self._panic_left = self.panic_hold_ticks
            return max(current, panic)
        if self._panic_left > 0:
            self._panic_left -= 1
            return max(current, stable)     # panicking: never scale down
        return max(1, stable)


@register_autoscaler
@dataclass
class PredictivePolicy(AutoscalePolicy):
    """Holt linear-trend forecast of the arrival rate (EWMA on level and
    trend), sized against a per-worker service rate. Scales *ahead* of a
    ``daily_cycle`` ramp instead of chasing it; falls back to reactive
    sizing whenever observed load already exceeds the forecast."""

    rate_per_worker: float = 120.0   # sustainable requests/s per worker
    alpha: float = 0.5               # level smoothing
    beta: float = 0.3                # trend smoothing
    lead_ticks: float = 4.0          # forecast horizon, in ticks
    interval_s: float = 1.0          # set by the controller on attach
    _level: float = field(default=-1.0, repr=False)
    _trend: float = field(default=0.0, repr=False)
    name = "predictive"

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        rate = last.arrivals / max(self.interval_s, 1e-9)
        if self._level < 0.0:                       # first observation
            self._level = rate
        prev = self._level
        self._level = self.alpha * rate + (1 - self.alpha) * (prev + self._trend)
        self._trend = (self.beta * (self._level - prev)
                       + (1 - self.beta) * self._trend)
        forecast = max(0.0, self._level + self._trend * self.lead_ticks)
        wpr = last.workers / max(last.replicas, 1)
        need = math.ceil(forecast / (self.rate_per_worker * wpr))
        # never size below what the backlog already demands right now
        backlog = math.ceil(last.concurrency / (4.0 * wpr))
        return max(1, need, backlog)

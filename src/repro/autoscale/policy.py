"""Pluggable autoscaler policies (registry mirrors the LB-policy registry).

A policy maps the observed :class:`~repro.autoscale.metrics.MetricsWindow`
to a desired replica count (replica = one LB branch of
``workers_per_replica`` workers). Policies are pure functions of the
window plus their own explicitly-seeded state, so two same-seed simulator
runs produce byte-identical decision streams.

The menu spans the design space the FaaS literature actually compares:

- ``static``             no-op; the paper's provision-for-X replicate recipe
- ``reactive``           queue/utilization threshold scaler (AWS-style)
- ``target_concurrency`` Knative KPA: stable window + panic window
- ``predictive``         Holt linear-trend (EWMA level+trend) rate forecast,
                         built for ``daily_cycle`` envelopes
- ``slo_aware``          per-function p95-vs-SLO pressure scaler; also emits
                         per-function prewarm/reap directives

Besides the fleet size, a policy may steer *per-function* capacity:
:meth:`AutoscalePolicy.fn_actions` returns ``{fn: delta}`` prewarm (+n) /
reap (-n) directives the controller applies through ``sim.prewarm`` /
``sim.reap`` — scaling signals at the granularity FaaS platforms actually
bill at.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping

from repro.autoscale.metrics import MetricsWindow

AUTOSCALERS: Dict[str, Callable[..., "AutoscalePolicy"]] = {}


def register_autoscaler(cls):
    """Class decorator: add an AutoscalePolicy subclass to the registry."""
    AUTOSCALERS[cls.name] = cls
    return cls


def get_autoscaler(name: str, **params) -> "AutoscalePolicy":
    """Construct a registered policy by name: the config/CLI hook."""
    if name not in AUTOSCALERS:
        raise KeyError(f"autoscaler policy {name!r} not registered "
                       f"(have: {sorted(AUTOSCALERS)})")
    return AUTOSCALERS[name](**params)


def list_autoscalers() -> List[str]:
    return sorted(AUTOSCALERS)


class AutoscalePolicy:
    """Base interface: desired replica count given the metrics window."""

    name = "base"

    def desired_replicas(self, window: MetricsWindow, current: int) -> int:
        raise NotImplementedError

    def fn_actions(self, window: MetricsWindow) -> Dict[str, int]:
        """Per-function capacity directives: ``{fn: +n}`` prewarm n
        replicas, ``{fn: -n}`` reap n idle replicas. Default: none."""
        return {}


@register_autoscaler
@dataclass
class StaticPolicy(AutoscalePolicy):
    """No-op baseline: whatever the tree was built with, it keeps.

    This is the paper's scaling story so far — ``replicate(tree, k)`` at
    deploy time — expressed as a policy so the benchmark cost/latency
    accounting is identical across the whole menu.
    """

    name = "static"

    def desired_replicas(self, window, current):
        return current


@register_autoscaler
@dataclass
class ReactivePolicy(AutoscalePolicy):
    """Threshold scaler on outstanding work per worker.

    Scale up proportionally (straight to the load-implied size, not +1
    steps — flash crowds don't wait) when the *latest* sample exceeds
    ``upper``; scale down toward the load-implied size only when the
    *window average* falls below ``lower``, so one calm tick inside a
    burst never sheds capacity.
    """

    target_load: float = 4.0     # design point: outstanding reqs per worker
    upper: float = 6.0           # latest-sample load that triggers scale-up
    lower: float = 1.0           # window-average load that allows scale-down
    name = "reactive"

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        if last.load_per_worker > self.upper:
            return math.ceil(current * last.load_per_worker / self.target_load)
        if window.avg("load_per_worker") < self.lower:
            down = math.ceil(
                current * window.avg("load_per_worker") / self.target_load)
            return min(current, max(1, down))
        return current


@register_autoscaler
@dataclass
class TargetConcurrencyPolicy(AutoscalePolicy):
    """Knative-KPA-style scaler: size the fleet so observed concurrency
    per worker sits at ``target``; a short panic window overrides the
    stable window when concurrency doubles, and freezes scale-down while
    panicking."""

    target: float = 4.0          # concurrent requests per worker at SLO
    panic_window: int = 2        # samples in the panic (burst) window
    panic_threshold: float = 2.0  # panic when panic-desired >= thr * current
    panic_hold_ticks: int = 8    # ticks scale-down stays frozen after panic
    _panic_left: int = field(default=0, repr=False)
    name = "target_concurrency"

    def _size(self, concurrency: float, workers_per_replica: float) -> int:
        return math.ceil(concurrency / (self.target * workers_per_replica))

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        wpr = last.workers / max(last.replicas, 1)
        stable = self._size(window.avg("concurrency"), wpr)
        panic = self._size(window.avg("concurrency", self.panic_window), wpr)
        if panic >= self.panic_threshold * current:
            self._panic_left = self.panic_hold_ticks
            return max(current, panic)
        if self._panic_left > 0:
            self._panic_left -= 1
            return max(current, stable)     # panicking: never scale down
        return max(1, stable)


@register_autoscaler
@dataclass
class PredictivePolicy(AutoscalePolicy):
    """Holt linear-trend forecast of the arrival rate (EWMA on level and
    trend), sized against a per-worker service rate. Scales *ahead* of a
    ``daily_cycle`` ramp instead of chasing it; falls back to reactive
    sizing whenever observed load already exceeds the forecast."""

    rate_per_worker: float = 120.0   # sustainable requests/s per worker
    alpha: float = 0.5               # level smoothing
    beta: float = 0.3                # trend smoothing
    lead_ticks: float = 4.0          # forecast horizon, in ticks
    interval_s: float = 1.0          # set by the controller on attach
    _level: float = field(default=-1.0, repr=False)
    _trend: float = field(default=0.0, repr=False)
    name = "predictive"

    def desired_replicas(self, window, current):
        last = window.last()
        if last is None:
            return current
        rate = last.arrivals / max(self.interval_s, 1e-9)
        if self._level < 0.0:                       # first observation
            self._level = rate
        prev = self._level
        self._level = self.alpha * rate + (1 - self.alpha) * (prev + self._trend)
        self._trend = (self.beta * (self._level - prev)
                       + (1 - self.beta) * self._trend)
        forecast = max(0.0, self._level + self._trend * self.lead_ticks)
        wpr = last.workers / max(last.replicas, 1)
        need = math.ceil(forecast / (self.rate_per_worker * wpr))
        # never size below what the backlog already demands right now
        backlog = math.ceil(last.concurrency / (4.0 * wpr))
        return max(1, need, backlog)


@register_autoscaler
@dataclass
class SloAwarePolicy(AutoscalePolicy):
    """Scale on per-function p95 latency pressure against per-function
    SLO targets — not on raw load.

    Pressure for a function is ``max(observed p95, projected wait) /
    (headroom * slo)``: the observed side is the windowed per-function
    p95 estimate; the projected side is a Little's-law backlog read
    (outstanding work over the completion rate) so a burst registers
    before its inflated latencies ever complete. The fleet is sized
    multiplicatively on the worst function's pressure, and cools down to
    the load-implied size only when *every* function sits comfortably
    inside its SLO. Per-function prewarm/reap directives ride on the same
    signal, so hot functions gain warm replicas ahead of the queue and
    cold ones stop pinning capacity slots.
    """

    slo_p95_s: Mapping[str, float] = field(default_factory=dict)
    default_slo_s: float = 1.0       # target for fns without an explicit SLO
    headroom: float = 0.7            # aim p95 at headroom * SLO
    max_step: float = 3.0            # cap on one tick's multiplicative growth
    down_pressure: float = 0.35      # window-avg pressure allowing scale-down
    prewarm_pressure: float = 1.0    # fn pressure that triggers a prewarm
    reap_pressure: float = 0.15      # fn pressure under which idle warm reaps
    interval_s: float = 1.0          # set by the controller on attach
    name = "slo_aware"

    def _slo(self, fn: str) -> float:
        return self.slo_p95_s.get(fn, self.default_slo_s)

    def _fn_pressure(self, window: MetricsWindow, f) -> float:
        """p95-vs-SLO pressure for one FnSample, backlog-projected."""
        # Little's law projection: outstanding work drains at the observed
        # completion rate; a burst shows up here ticks before its inflated
        # latencies complete and move the measured p95
        rate = window.fn_avg(f.fn, "completions") / max(self.interval_s, 1e-9)
        projected = f.concurrency / rate if rate > 0 else (
            float("inf") if f.concurrency > 0 else 0.0)
        est = max(f.p95_est, min(projected, 1e6))
        return est / max(self.headroom * self._slo(f.fn), 1e-9)

    def _pressures(self, window: MetricsWindow) -> Dict[str, float]:
        last = window.last()
        if last is None:
            return {}
        return {f.fn: self._fn_pressure(window, f) for f in last.fns}

    def desired_replicas(self, window, current):
        pressures = self._pressures(window)
        if not pressures:
            return current
        worst = max(pressures.values())
        if worst > 1.0:
            return math.ceil(current * min(worst, self.max_step))
        if worst < self.down_pressure:
            last = window.last()
            wpr = last.workers / max(last.replicas, 1)
            implied = math.ceil(last.concurrency / max(4.0 * wpr, 1e-9))
            return min(current, max(1, implied))
        return current

    def fn_actions(self, window):
        acts: Dict[str, int] = {}
        for fn, pressure in sorted(self._pressures(window).items()):
            f = window.fn_last(fn)
            if f is None:
                continue
            # prewarm only under *live* demand: the latency reservoir
            # remembers a hot past, and a prewarm with nothing arriving
            # would keep the control loop awake forever (each prewarm
            # schedules a future idle_check event)
            if (pressure > self.prewarm_pressure
                    and (f.concurrency > 0 or f.arrivals > 0)):
                acts[fn] = 1                       # warm capacity ahead of queue
            elif (pressure < self.reap_pressure
                    and f.warm > f.inflight and f.warm > 1):
                acts[fn] = -1                      # stop pinning idle slots
        return acts

"""Sliding metrics window the autoscaler control loop observes.

Each ``autoscale_tick`` the controller snapshots the simulator into a
:class:`MetricsSample` (totals over live workers plus deltas since the
previous tick) and pushes it into a bounded :class:`MetricsWindow`.
Policies only ever read aggregates of this window — they never touch the
simulator directly — which keeps every policy a pure function of
deterministic inputs and makes the scaling-decision log byte-identical
across same-seed runs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional


@dataclass(frozen=True)
class MetricsSample:
    """One control-loop observation (totals at tick time + inter-tick deltas)."""

    t: float
    replicas: int              # branches under the LB root
    workers: int               # live (routable) workers
    queue: int                 # queued requests across workers
    inflight: int              # busy instance slots across workers
    arrivals: int              # requests arrived since the previous tick
    completions: int           # results recorded since the previous tick
    cold_starts: int           # instances cold-started since the previous tick

    @property
    def concurrency(self) -> int:
        """Outstanding work (Knative's 'observed concurrency')."""
        return self.queue + self.inflight

    @property
    def load_per_worker(self) -> float:
        return self.concurrency / max(self.workers, 1)


class MetricsWindow:
    """Bounded deque of samples with the aggregates policies consume."""

    def __init__(self, maxlen: int):
        self.samples: Deque[MetricsSample] = deque(maxlen=max(1, maxlen))

    def push(self, sample: MetricsSample) -> None:
        self.samples.append(sample)

    def __len__(self) -> int:
        return len(self.samples)

    def last(self) -> Optional[MetricsSample]:
        return self.samples[-1] if self.samples else None

    def avg(self, attr: str, tail: Optional[int] = None) -> float:
        """Mean of a sample attribute over the window (or its last ``tail``
        samples — the panic-window read)."""
        if not self.samples:
            return 0.0
        xs = list(self.samples)[-tail:] if tail else list(self.samples)
        return sum(getattr(s, attr) for s in xs) / len(xs)

    def arrival_rate(self, interval_s: float, tail: Optional[int] = None) -> float:
        """Observed arrivals/s averaged over the window."""
        return self.avg("arrivals", tail) / max(interval_s, 1e-9)

"""Sliding metrics window the autoscaler control loop observes.

Each ``autoscale_tick`` the controller snapshots the simulator into a
:class:`MetricsSample` (totals over live workers plus deltas since the
previous tick) and pushes it into a bounded :class:`MetricsWindow`.
Policies only ever read aggregates of this window — they never touch the
simulator directly — which keeps every policy a pure function of
deterministic inputs and makes the scaling-decision log byte-identical
across same-seed runs.

Samples are keyed down to *function* granularity: every sample carries a
sorted tuple of :class:`FnSample` rows (per-function queue depth,
inflight, arrival/completion deltas, warm replica count, and a windowed
p95 latency estimate) — the signals SLO-aware policies and per-function
prewarm/reap decisions run on.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple


@dataclass(frozen=True)
class FnSample:
    """One function's share of a control-loop observation.

    With a front-door gateway attached (``sim.gateway``), ``arrivals``
    is the *post-gateway admitted* delta — the demand that actually
    reached the LB tree — so rate-proportional policies (reactive,
    predictive, slo_aware) scale to the load the platform accepted, not
    to a flood the gateway already refused. ``shed`` carries the refused
    delta and ``goodput`` the successful-completion delta; without a
    gateway, ``arrivals`` is offered load (unchanged semantics) and
    ``shed`` stays 0.
    """

    fn: str
    queue: int                 # queued requests for this fn across workers
    inflight: int              # busy slots serving this fn
    arrivals: int              # fn arrivals since the previous tick
    completions: int           # fn results recorded since the previous tick
    warm: int                  # replicas (ready + warming) across workers
    p95_est: float             # windowed p95 latency estimate (0 => no data)
    shed: int = 0              # gateway refusals since the previous tick
    goodput: int = 0           # ok results recorded since the previous tick

    @property
    def concurrency(self) -> int:
        return self.queue + self.inflight


@dataclass(frozen=True)
class MetricsSample:
    """One control-loop observation (totals at tick time + inter-tick deltas)."""

    t: float
    replicas: int              # branches under the LB root
    workers: int               # live *healthy* (routable) workers
    queue: int                 # queued requests across workers
    inflight: int              # busy instance slots across workers
    arrivals: int              # requests arrived since the previous tick
    completions: int           # results recorded since the previous tick
    cold_starts: int           # instances cold-started since the previous tick
    fns: Tuple[FnSample, ...] = ()     # per-function rows, sorted by name
    unhealthy: int = 0         # workers currently failed/partitioned away

    @property
    def concurrency(self) -> int:
        """Outstanding work (Knative's 'observed concurrency')."""
        return self.queue + self.inflight

    @property
    def load_per_worker(self) -> float:
        return self.concurrency / max(self.workers, 1)

    def fn(self, name: str) -> Optional[FnSample]:
        for f in self.fns:
            if f.fn == name:
                return f
        return None


def merge_fleet_samples(samples) -> MetricsSample:
    """Combine per-partition :class:`MetricsSample` rows into one
    whole-fleet observation (the windowed metrics exchange of
    ``repro.parallel``).

    Counters and deltas sum; per-function rows merge by name with
    ``p95_est`` taken as the max across partitions (a conservative
    fleet-tail estimate — partitions see disjoint tenants, so their
    windows cannot be pooled exactly); ``t`` is the latest partition
    clock. Input order does not matter: rows re-sort by name, so the
    merge is deterministic regardless of summary arrival order.
    """
    samples = [s for s in samples if s is not None]
    if not samples:
        return MetricsSample(t=0.0, replicas=0, workers=0, queue=0,
                             inflight=0, arrivals=0, completions=0,
                             cold_starts=0)
    by_fn: dict = {}
    for s in samples:
        for f in s.fns:
            prev = by_fn.get(f.fn)
            if prev is None:
                by_fn[f.fn] = f
            else:
                by_fn[f.fn] = FnSample(
                    fn=f.fn, queue=prev.queue + f.queue,
                    inflight=prev.inflight + f.inflight,
                    arrivals=prev.arrivals + f.arrivals,
                    completions=prev.completions + f.completions,
                    warm=prev.warm + f.warm,
                    p95_est=max(prev.p95_est, f.p95_est),
                    shed=prev.shed + f.shed,
                    goodput=prev.goodput + f.goodput)
    return MetricsSample(
        t=max(s.t for s in samples),
        replicas=sum(s.replicas for s in samples),
        workers=sum(s.workers for s in samples),
        queue=sum(s.queue for s in samples),
        inflight=sum(s.inflight for s in samples),
        arrivals=sum(s.arrivals for s in samples),
        completions=sum(s.completions for s in samples),
        cold_starts=sum(s.cold_starts for s in samples),
        fns=tuple(by_fn[k] for k in sorted(by_fn)),
        unhealthy=sum(s.unhealthy for s in samples))


class MetricsWindow:
    """Bounded deque of samples with the aggregates policies consume."""

    def __init__(self, maxlen: int):
        self.samples: Deque[MetricsSample] = deque(maxlen=max(1, maxlen))

    def push(self, sample: MetricsSample) -> None:
        self.samples.append(sample)

    def __len__(self) -> int:
        return len(self.samples)

    def last(self) -> Optional[MetricsSample]:
        return self.samples[-1] if self.samples else None

    def avg(self, attr: str, tail: Optional[int] = None) -> float:
        """Mean of a sample attribute over the window (or its last ``tail``
        samples — the panic-window read)."""
        if not self.samples:
            return 0.0
        xs = list(self.samples)[-tail:] if tail else list(self.samples)
        return sum(getattr(s, attr) for s in xs) / len(xs)

    def arrival_rate(self, interval_s: float, tail: Optional[int] = None) -> float:
        """Observed arrivals/s averaged over the window."""
        return self.avg("arrivals", tail) / max(interval_s, 1e-9)

    # ------------------------------------------------- per-function reads
    def fn_names(self) -> Tuple[str, ...]:
        last = self.last()
        return tuple(f.fn for f in last.fns) if last is not None else ()

    def fn_last(self, name: str) -> Optional[FnSample]:
        last = self.last()
        return last.fn(name) if last is not None else None

    def fn_avg(self, name: str, attr: str, tail: Optional[int] = None) -> float:
        """Mean of one function's sample attribute over the window."""
        if not self.samples:
            return 0.0
        xs = list(self.samples)[-tail:] if tail else list(self.samples)
        vals = [getattr(f, attr) for s in xs
                for f in (s.fn(name),) if f is not None]
        return sum(vals) / len(vals) if vals else 0.0


class ServiceEstimator:
    """Windowed per-function mean *service-time* estimate.

    The routing-facing sibling of :class:`LatencyEstimator`: fed one
    observation per completed request (in result order, so it is a pure
    function of the deterministic result stream), read by
    ``deadline_aware`` routing to price a worker's queued backlog. A
    running sum over a bounded deque keeps both ``observe`` and
    ``estimate`` O(1) — this sits on the per-arrival routing hot path.
    """

    def __init__(self, maxlen: int = 128, default_s: float = 0.05):
        self.maxlen = maxlen
        self.default_s = default_s
        self._win: dict = {}       # fn -> deque[float]
        self._sum: dict = {}       # fn -> running sum over the deque

    def observe(self, fn: str, service_s: float) -> None:
        d = self._win.get(fn)
        if d is None:
            d = self._win[fn] = deque(maxlen=self.maxlen)
            self._sum[fn] = 0.0
        if len(d) == self.maxlen:
            self._sum[fn] -= d[0]
        d.append(service_s)
        self._sum[fn] += service_s

    def estimate(self, fn: str) -> float:
        d = self._win.get(fn)
        if not d:
            return self.default_s
        return self._sum[fn] / len(d)


class LatencyEstimator:
    """Bounded per-function latency reservoir feeding ``FnSample.p95_est``.

    Keeps the most recent ``maxlen`` completed-request latencies per
    function (deterministic: fed in result order by the controller) and
    reports an empirical p95. A bounded reservoir keeps each tick
    O(maxlen log maxlen) even under very high completion rates.
    """

    def __init__(self, maxlen: int = 256):
        self.maxlen = maxlen
        self._lat: dict = {}       # fn -> deque[float]

    def observe(self, fn: str, latency: float) -> None:
        d = self._lat.get(fn)
        if d is None:
            d = self._lat[fn] = deque(maxlen=self.maxlen)
        d.append(latency)

    def p95(self, fn: str) -> float:
        d = self._lat.get(fn)
        if not d:
            return 0.0
        xs = sorted(d)
        # nearest-rank p95 (no interpolation: byte-stable across runs).
        # ceil(0.95n) is the nearest-rank definition; the old
        # int(0.95n) index over-shot by one rank — for n ≤ 20 it
        # returned the window *max*, overstating small-sample tails.
        import math
        return xs[math.ceil(0.95 * len(xs)) - 1]

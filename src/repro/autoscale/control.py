"""The control plane: scaling, placement, and decision logging, as a layer.

The paper's testbed swaps *platform architectures*; the control plane is
the piece a FaaS platform actually differentiates on (scaling policy,
replica placement, prewarming). This facade gathers every control-side
hook that used to live inline in the simulator — autoscaler binding and
tick handling, per-function prewarm/reap, placer-ranked placement, and
the byte-stable placement/routing decision logs — behind one object, so
the simulator proper only *wires* workload → router → workers → control
plane and a different control plane can be dropped in without touching
the data path.

The facade operates on the same duck-typed simulator surface the worker
runtime uses (``repro.core.worker``): ``workers``, ``_worker_list``,
``store``, ``now``, ``_push``, the ``runtime`` (poke/dispatch), and the
``engine`` (pending-event accounting for tick re-arming). The simulator
keeps thin delegate methods (``sim.prewarm`` etc.) for API
compatibility — they are one-line calls into this class.

Determinism: decision logs are plain event-ordered line lists; same
seed ⇒ byte-identical logs (pinned in ``tests/test_placement.py`` and
``tests/test_autoscale.py``).
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.placement import Placer, get_placer


class ControlPlane:
    """Autoscaler + placement hooks + decision logs for one simulator."""

    def __init__(self, sim, *, placer="first_fit",
                 record_decisions: bool = False):
        self.sim = sim
        self.placer: Placer = (get_placer(placer) if isinstance(placer, str)
                               else placer)
        # single source of truth for decision recording: the simulator's
        # hot paths read sim._record directly, so write it there and
        # keep no mirror here that could drift
        sim._record = record_decisions
        self.autoscaler = None
        self.placement_records: List[str] = []   # start/reap/idle events
        self.routing_records: List[str] = []     # arrival/reroute choices
        self.gateway_records: List[str] = []     # front-door verdicts

    # ------------------------------------------------------- decision logs
    def log_placement(self, kind: str, w, fn: str) -> None:
        cap = "inf" if w.memory_mb is None else f"{w.memory_mb:.0f}"
        self.placement_records.append(
            f"t={self.sim.now:.6f} {kind} fn={fn} worker={w.name} "
            f"mem={w.memory_used_mb:.0f}/{cap} inst={w.total_instances}")

    def log_routing(self, kind: str, req, wid: str) -> None:
        self.routing_records.append(
            f"t={self.sim.now:.6f} {kind} rid={req.rid} fn={req.fn} "
            f"worker={wid}")

    def placement_log(self) -> str:
        """Byte-stable placement decision log (``record_decisions=True``):
        one line per replica start/reap/idle-stop, in event order."""
        return "\n".join(self.placement_records)

    def log_gateway(self, kind: str, req, verdict) -> None:
        self.gateway_records.append(
            f"t={self.sim.now:.6f} {kind} rid={req.rid} fn={req.fn} "
            f"verdict={verdict or 'admit'}")

    def routing_log(self) -> str:
        """Byte-stable routing decision log (``record_decisions=True``):
        one line per arrival/reroute with the worker the tree chose."""
        return "\n".join(self.routing_records)

    def gateway_log(self) -> str:
        """Byte-stable front-door decision log (``record_decisions=True``):
        one line per gateway consult (arrival or retry) with the
        verdict — ``admit`` or the terminal shed error."""
        return "\n".join(self.gateway_records)

    # -------------------------------------------------- per-fn scale units
    def prewarm(self, worker: str, fn: str) -> bool:
        """Proactively start (cold-start now, serve warm later) one
        instance of ``fn`` on a worker — the autoscaler's scale-up
        companion. Returns False if the worker is gone/unhealthy or at
        instance capacity."""
        sim = self.sim
        w = sim.workers.get(worker)
        if w is None or not w.healthy:
            return False
        cfg = sim.store.get(fn)
        inst = sim._maybe_start_instance(w, cfg)
        if inst is None:
            return False
        # instances normally get idle_checks from the finish path; a
        # prewarmed instance that never serves traffic needs its own reap
        # path or it would pin a capacity slot forever
        sim._push(inst.ready_t + cfg.idle_timeout_s, "idle_check",
                  (worker, inst.iid))
        # a prewarm onto a worker already holding queued work for this fn
        # must wake its dispatch when the replica is ready, or that work
        # only drains on the next unrelated enqueue/finish
        if w.queue.depth(fn) > 0:
            sim._poke(w, inst.ready_t)
        return True

    def reap(self, worker: str, fn: str) -> bool:
        """Stop one idle warm instance of ``fn`` on a worker — the
        autoscaler's per-function scale-down companion to :meth:`prewarm`.
        Returns False if the worker is gone/unhealthy or holds no idle
        ready replica of that function."""
        sim = self.sim
        w = sim.workers.get(worker)
        if w is None or not w.healthy:
            return False
        rs = w.replica_sets.get(fn)
        inst = rs.idle_ready(sim.now) if rs is not None else None
        if inst is None:
            return False
        w.remove_instance(inst)
        if sim._record:
            self.log_placement("reap", w, fn)
        if len(w.queue) > 0:       # freed capacity may unblock other fns
            sim._dispatch(w)
        else:
            sim._refresh_view(w)
        return True

    # ------------------------------------------------------ placement layer
    def place_prewarm(self, fn: str) -> Optional[str]:
        """Start one replica of ``fn`` on the worker the placer picks —
        the autoscaler's scale-up entry into the placement layer.

        Candidates are offered coldest-in-``fn`` first (fewest replicas
        of the function, then fewest instances overall, then name — the
        deterministic preference order the control loop always used);
        the placer bin-packs within that order. Returns the worker name,
        or None when no worker has memory/instance headroom."""
        sim = self.sim
        cfg = sim.store.get(fn)
        cands = sorted(
            (sim.workers[n] for n in sim._worker_list
             if n in sim.workers),
            key=lambda w: (w.fn_replicas(fn), w.total_instances, w.name))
        for w in self.placer.place_order(fn, cfg.memory_mb, cands):
            if self.prewarm(w.name, fn):
                return w.name
        return None

    def place_reap(self, fn: str) -> Optional[str]:
        """Stop one idle replica of ``fn`` off the worker the placer
        picks (warmest-in-``fn`` candidates first) — the scale-down
        mirror of :meth:`place_prewarm`. Returns the worker name, or
        None when no worker holds an idle ready replica."""
        sim = self.sim
        cands = sorted(
            (sim.workers[n] for n in sim._worker_list
             if n in sim.workers),
            key=lambda w: (-w.fn_replicas(fn), w.name))
        for w in self.placer.reap_order(fn, cands):
            if self.reap(w.name, fn):
                return w.name
        return None

    def workflow_prewarm(self, fn: str) -> Optional[str]:
        """Stage-lookahead prewarm: the workflow engine calls this when a
        stage is submitted so its *successors'* functions are warm by the
        time the stage completes. Only acts when the fleet holds no
        replica of the function at all — steady traffic keeps its own
        capacity warm; this hook exists to hide the first cold start on
        each DAG edge (and after idle reaping between workflow bursts).
        Returns the worker the placer picked, or None (already warm
        somewhere / no headroom)."""
        sim = self.sim
        for name in sim._worker_list:
            w = sim.workers.get(name)
            if w is not None and w.healthy and w.fn_replicas(fn) > 0:
                return None
        return self.place_prewarm(fn)

    # ------------------------------------------------------ autoscaler loop
    def attach_autoscaler(self, scaler, *, first_tick_s: float = None):
        """Bind an ``repro.autoscale.Autoscaler`` and schedule its periodic
        ``autoscale_tick`` control-loop event. Ticks re-arm themselves only
        while other events remain, so ``run()`` still terminates."""
        sim = self.sim
        self.autoscaler = scaler
        t0 = sim.now + (scaler.interval_s if first_tick_s is None
                        else first_tick_s)
        sim._push(t0, "autoscale_tick", None)
        return scaler

    def on_tick(self) -> None:
        sim = self.sim
        if self.autoscaler is None:
            return
        self.autoscaler.on_tick(sim)
        if sim.engine.pending_real > 0:  # re-arm only while real work remains
            sim._push(sim.now + self.autoscaler.interval_s,
                      "autoscale_tick", None)

"""Counterfactual replay of recorded scaling decisions.

A run's structured decision log (``Autoscaler.decision_records()``) is a
complete record of *what the control loop did*: the raw policy output per
tick plus the per-function prewarm/reap directives. :func:`replay` turns
that record back into a controller whose "policy" simply re-emits the
recorded outputs tick by tick, so the same decision sequence can be
re-applied —

- on the same seed/workload, which must reproduce the original decision
  log byte-for-byte (the regression contract
  ``tests/test_autoscale.py`` pins), or
- on a *different* seed, workload shape, or service model: the
  counterfactual question "what would last Tuesday's scaling have done
  under today's traffic?".

Records are plain JSON types; :func:`save_decision_log` /
:func:`load_decision_log` round-trip them through a file.
"""
from __future__ import annotations

import json
from typing import List, Sequence

from repro.autoscale.controller import Autoscaler
from repro.autoscale.policy import AutoscalePolicy
from repro.core.gateway import Gateway, GatewayConfig


class ReplayPolicy(AutoscalePolicy):
    """Re-emits a recorded decision sequence instead of deciding.

    Each tick consumes one record: ``desired_replicas`` returns the raw
    recorded policy output (the controller re-applies its own clamp /
    cooldown exactly as the original did) and ``fn_actions`` the recorded
    per-function directives. Past the end of the recording it holds
    steady. The policy reports the *recorded* policy's name so a replayed
    decision log is byte-identical to the original.
    """

    def __init__(self, records: Sequence[dict]):
        self.records: List[dict] = list(records)
        self.name = self.records[0]["policy"] if self.records else "replay"
        self._i = 0
        self._current: dict = {}

    def desired_replicas(self, window, current):
        if self._i >= len(self.records):
            self._current = {}
            return current
        self._current = self.records[self._i]
        self._i += 1
        return self._current["desired"]

    def fn_actions(self, window):
        return {fn: int(n)
                for fn, n in self._current.get("fn_deltas", ())}


def replay(records: Sequence[dict], **autoscaler_kwargs) -> Autoscaler:
    """Build an :class:`Autoscaler` that re-applies ``records``.

    Pass the same controller kwargs (interval, bounds, cooldown,
    workers_per_replica, ...) as the recording run, then attach to a
    simulator with ``sim.attach_autoscaler(...)`` as usual.
    """
    return Autoscaler(ReplayPolicy(records), **autoscaler_kwargs)


class ReplayGateway(Gateway):
    """Re-emits a recorded front-door verdict sequence
    (``Gateway.decision_records()``) instead of deciding.

    Only :meth:`Gateway.decide` is overridden, so the slot accounting,
    per-tenant counters, and release bookkeeping run exactly as live —
    the replayed run's result stream is byte-identical to the recording
    run's on the same seed/workload (consult order is deterministic).
    Past the end of the recording it admits everything. Records are the
    same plain-JSON shape ``save_decision_log``/``load_decision_log``
    round-trip.
    """

    def __init__(self, records: Sequence[dict], config=None, *,
                 record: bool = False):
        super().__init__(config or GatewayConfig(), record=record)
        self._replay: List[tuple] = [(r["rid"], r["verdict"])
                                     for r in records]
        self._ri = 0

    def decide(self, req, now, *, retry):
        if self._ri >= len(self._replay):
            return None
        rid, verdict = self._replay[self._ri]
        if rid != req.rid:
            raise ValueError(
                f"gateway replay diverged: consult #{self._ri} saw "
                f"rid={req.rid}, recording has rid={rid} (replaying "
                "against a different workload/seed?)")
        self._ri += 1
        return None if verdict == "admit" else verdict


def save_decision_log(records: Sequence[dict], path: str) -> None:
    with open(path, "w") as fh:
        json.dump({"decisions": list(records)}, fh, indent=1)


def load_decision_log(path: str) -> List[dict]:
    with open(path) as fh:
        return json.load(fh)["decisions"]

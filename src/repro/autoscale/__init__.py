"""Autoscaler control-loop subsystem: policy registry + controller.

Turns the paper's static ``replicate()`` recipe into a live control loop
driven by the workload-scenario subsystem, with per-function metrics,
SLO-aware scaling, per-function prewarm/reap, and decision-log replay.
See README.md §"Autoscaling" for the extension guide."""
from repro.autoscale.controller import (Autoscaler, ScalingDecision,
                                        build_pool)
from repro.autoscale.metrics import (FnSample, LatencyEstimator,
                                     MetricsSample, MetricsWindow,
                                     ServiceEstimator)
from repro.autoscale.policy import (AUTOSCALERS, AutoscalePolicy,
                                    PredictivePolicy, ReactivePolicy,
                                    SloAwarePolicy, StaticPolicy,
                                    TargetConcurrencyPolicy,
                                    get_autoscaler, list_autoscalers,
                                    register_autoscaler)
from repro.autoscale.replay import (ReplayPolicy, load_decision_log,
                                    replay, save_decision_log)

__all__ = [
    "Autoscaler", "ScalingDecision", "build_pool",
    "FnSample", "LatencyEstimator", "MetricsSample", "MetricsWindow",
    "ServiceEstimator",
    "AUTOSCALERS", "AutoscalePolicy", "StaticPolicy", "ReactivePolicy",
    "TargetConcurrencyPolicy", "PredictivePolicy", "SloAwarePolicy",
    "get_autoscaler", "list_autoscalers", "register_autoscaler",
    "ReplayPolicy", "replay", "save_decision_log", "load_decision_log",
]

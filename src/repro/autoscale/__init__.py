"""Autoscaler control-loop subsystem: policy registry + controller.

Turns the paper's static ``replicate()`` recipe into a live control loop
driven by the workload-scenario subsystem. See README.md §"Autoscaling"
for the extension guide."""
from repro.autoscale.controller import (Autoscaler, ScalingDecision,
                                        build_pool)
from repro.autoscale.metrics import MetricsSample, MetricsWindow
from repro.autoscale.policy import (AUTOSCALERS, AutoscalePolicy,
                                    PredictivePolicy, ReactivePolicy,
                                    StaticPolicy, TargetConcurrencyPolicy,
                                    get_autoscaler, list_autoscalers,
                                    register_autoscaler)

__all__ = [
    "Autoscaler", "ScalingDecision", "build_pool",
    "MetricsSample", "MetricsWindow",
    "AUTOSCALERS", "AutoscalePolicy", "StaticPolicy", "ReactivePolicy",
    "TargetConcurrencyPolicy", "PredictivePolicy",
    "get_autoscaler", "list_autoscalers", "register_autoscaler",
]

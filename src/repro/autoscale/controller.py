"""The autoscaler control loop: observe -> decide -> act, once per tick.

The :class:`Autoscaler` binds to a :class:`~repro.core.simulator.Simulator`
(``sim.attach_autoscaler``) which then fires a periodic ``autoscale_tick``
event. Each tick the controller:

1. snapshots live workers into the sliding :class:`MetricsWindow`
   (queue depth, inflight, arrival/cold-start deltas),
2. asks its policy for a desired replica count,
3. clamps to ``[min_replicas, max_replicas]``, applies scale-down
   cooldown, and acts through ``sim.add_branch`` / ``sim.remove_branch``
   (which drains safely), prewarming instances on scaled-up workers,
4. appends a :class:`ScalingDecision` to the decision log.

Everything is a deterministic function of simulator state, so the same
seed yields a byte-identical ``decision_log()`` — the regression contract
``tests/test_autoscale.py`` pins.

A replica is one LB branch of ``workers_per_replica`` workers directly
under the tree root — the same unit as the paper's ``replicate()`` recipe,
applied live. The controller only ever removes branches it added itself,
so a pre-built static pool is never scaled below its deploy size.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.autoscale.metrics import (FnSample, LatencyEstimator,
                                     MetricsSample, MetricsWindow)
from repro.autoscale.policy import AutoscalePolicy, get_autoscaler
from repro.core.router import LBNode, build_leaf


def build_pool(branches: int, workers_per_branch: int, *,
               leaf_policy: str = "least_loaded",
               inner_policy: str = "random",
               prefix: str = "pool") -> LBNode:
    """Root LB over ``branches`` identical leaf branches — the autoscaler's
    (and the replicate recipe's) unit of scale, built explicitly."""
    leaves = [build_leaf(f"{prefix}-b{i}",
                         [f"{prefix}-b{i}-w{j}"
                          for j in range(workers_per_branch)],
                         leaf_policy)
              for i in range(branches)]
    return LBNode(f"{prefix}-root", inner_policy, children=leaves)


@dataclass(frozen=True)
class ScalingDecision:
    """One control-loop outcome; ``fmt()`` is the byte-stable log line and
    ``to_record()`` the structured (JSON-able) form the replay tool
    re-applies."""

    t: float
    policy: str
    replicas_before: int
    desired: int                # raw policy output, pre-clamp
    applied: int                # replicas after this tick
    action: str     # hold | up | down | cooldown | bound | floor | outage_hold
    queue: int
    inflight: int
    workers: int
    arrival_rate: float
    # per-function prewarm(+)/reap(-) directives the policy emitted this
    # tick, sorted by fn — the control plane below branch granularity
    fn_deltas: Tuple[Tuple[str, int], ...] = ()

    def fmt(self) -> str:
        acts = ",".join(f"{fn}:{n:+d}" for fn, n in self.fn_deltas) or "-"
        return (f"t={self.t:.3f} policy={self.policy} "
                f"replicas={self.replicas_before}->{self.applied} "
                f"desired={self.desired} action={self.action} "
                f"queue={self.queue} inflight={self.inflight} "
                f"workers={self.workers} arr_rate={self.arrival_rate:.3f} "
                f"fn_actions={acts}")

    def to_record(self) -> dict:
        """Structured form (plain JSON types) for logs and replay."""
        rec = asdict(self)
        rec["fn_deltas"] = [list(d) for d in self.fn_deltas]
        return rec

    @classmethod
    def from_record(cls, rec: dict) -> "ScalingDecision":
        rec = dict(rec)
        rec["fn_deltas"] = tuple((fn, int(n)) for fn, n in rec["fn_deltas"])
        return cls(**rec)


class Autoscaler:
    def __init__(self, policy, *, interval_s: float = 0.5,
                 window_s: float = 4.0, min_replicas: int = 1,
                 max_replicas: int = 8, workers_per_replica: int = 2,
                 cooldown_s: float = 5.0, leaf_policy: str = "least_loaded",
                 prewarm_fns: Optional[Sequence[str]] = ("auto",)):
        """``policy`` is an :class:`AutoscalePolicy` or a registry name.
        ``prewarm_fns``: function names to pre-start one instance of on
        every scaled-up worker; ``("auto",)`` prewarms every registered
        function, ``None`` disables prewarming."""
        self.policy: AutoscalePolicy = (get_autoscaler(policy)
                                        if isinstance(policy, str) else policy)
        self.interval_s = interval_s
        self.window = MetricsWindow(max(1, round(window_s / interval_s)))
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.workers_per_replica = workers_per_replica
        self.cooldown_s = cooldown_s
        self.leaf_policy = leaf_policy
        self.prewarm_fns = prewarm_fns
        self.decisions: List[ScalingDecision] = []
        self.worker_seconds = 0.0       # cost proxy: live workers x time
        self.replica_seconds = 0.0
        self._scaled: List[str] = []    # LIFO of branches this loop added
        self._branch_seq = 0
        self._last_scale_t = -1e30
        self._last_tick_t: Optional[float] = None
        self._last_arrivals = 0
        self._last_results = 0
        self._last_cold = 0
        self._last_fn_arrivals: Dict[str, int] = {}
        self._last_fn_admitted: Dict[str, int] = {}
        self._last_fn_shed: Dict[str, int] = {}
        self._lat_est = LatencyEstimator()
        # rate-based policies need the tick period to convert deltas
        if hasattr(self.policy, "interval_s"):
            self.policy.interval_s = interval_s

    # --------------------------------------------------------- observation
    def _fn_samples(self, sim, workers) -> Tuple[FnSample, ...]:
        """Aggregate the per-function layer of every live worker and feed
        the latency estimator from the results delta — O(workers x fns +
        new results) per tick."""
        new_completions: Dict[str, int] = {}
        new_ok: Dict[str, int] = {}
        for r in sim.results[self._last_results:]:
            new_completions[r.fn] = new_completions.get(r.fn, 0) + 1
            if r.ok:
                new_ok[r.fn] = new_ok.get(r.fn, 0) + 1
                self._lat_est.observe(r.fn, r.latency)
        gw = getattr(sim, "gateway", None)
        rows = []
        for fn in sorted(sim.arrivals_by_fn):
            queue = inflight = warm = 0
            for w in workers:
                queue += w.queue.depth(fn)
                rs = w.replica_sets.get(fn)
                if rs is not None:
                    inflight += rs.inflight()
                    warm += len(rs)
            arr = sim.arrivals_by_fn[fn]
            shed = 0
            if gw is not None:
                # post-gateway demand: rate policies should track what
                # the front door admitted, not the offered flood it shed
                adm = gw.admitted_by_fn.get(fn, 0)
                arr = adm - self._last_fn_admitted.get(fn, 0)
                self._last_fn_admitted[fn] = adm
                sh = gw.shed_by_fn.get(fn, 0)
                shed = sh - self._last_fn_shed.get(fn, 0)
                self._last_fn_shed[fn] = sh
            else:
                arr = arr - self._last_fn_arrivals.get(fn, 0)
                self._last_fn_arrivals[fn] = sim.arrivals_by_fn[fn]
            rows.append(FnSample(
                fn=fn, queue=queue, inflight=inflight,
                arrivals=arr,
                completions=new_completions.get(fn, 0), warm=warm,
                p95_est=self._lat_est.p95(fn), shed=shed,
                goodput=new_ok.get(fn, 0)))
        return tuple(rows)

    def _snapshot(self, sim) -> MetricsSample:
        workers = [sim.workers[w] for w in sim._worker_list
                   if w in sim.workers]
        # partition-aware observation: a crashed/partitioned worker is
        # not capacity — counting it dilutes load_per_worker exactly
        # when pressure on the survivors is spiking
        healthy = sum(1 for w in workers if w.healthy)
        cold = sim.cold_starts_total
        sample = MetricsSample(
            t=sim.now,
            replicas=len(sim.tree.children),
            workers=healthy,
            unhealthy=len(workers) - healthy,
            queue=sum(len(w.queue) for w in workers),
            inflight=sum(w.inflight() for w in workers),
            arrivals=sim.arrivals_seen - self._last_arrivals,
            completions=len(sim.results) - self._last_results,
            cold_starts=cold - self._last_cold,
            fns=self._fn_samples(sim, workers))
        self._last_arrivals = sim.arrivals_seen
        self._last_results = len(sim.results)
        self._last_cold = cold
        return sample

    # --------------------------------------------------------------- tick
    def on_tick(self, sim) -> ScalingDecision:
        if self._last_tick_t is not None:
            dt = sim.now - self._last_tick_t
            self.worker_seconds += len(sim._worker_list) * dt
            self.replica_seconds += len(sim.tree.children) * dt
        self._last_tick_t = sim.now

        sample = self._snapshot(sim)
        self.window.push(sample)
        current = sample.replicas
        desired = self.policy.desired_replicas(self.window, current)
        target = max(self.min_replicas, min(self.max_replicas, desired))

        action = "hold"
        if target > current:
            action = "up"
            for _ in range(target - current):
                self._grow(sim)
        elif target < current:
            if sample.unhealthy > 0:
                # partition-aware tick: with part of the fleet dark the
                # window's completion/queue metrics are stale (stalled
                # work on dead workers reads as vanished load) — never
                # scale down on them; scale-up above stays allowed
                action, target = "outage_hold", current
            elif sim.now - self._last_scale_t < self.cooldown_s:
                action, target = "cooldown", current
            elif not self._scaled:
                action, target = "floor", current   # only shrink own branches
            else:
                action = "down"
                shrink = min(current - target, len(self._scaled))
                for _ in range(shrink):
                    sim.remove_branch(self._scaled.pop())
                target = current - shrink
        elif desired != target:
            action = "bound"
        if action in ("up", "down"):
            self._last_scale_t = sim.now

        # per-function prewarm/reap directives act below branch
        # granularity — the control plane FaaS platforms actually bill at.
        # Prewarms are refused for functions with no outstanding work and
        # no arrivals this tick: each prewarm schedules a future
        # idle_check, so an unconditional one would re-arm the tick chain
        # forever on a drained system (run() would never terminate).
        def _admissible(fn, delta):
            f = sample.fn(fn)
            return delta < 0 or (f is not None
                                 and (f.concurrency > 0 or f.arrivals > 0))
        fn_deltas = tuple(sorted(
            (fn, n) for fn, n in self.policy.fn_actions(self.window).items()
            if _admissible(fn, n)))
        self._apply_fn_actions(sim, fn_deltas)

        decision = ScalingDecision(
            t=sim.now, policy=self.policy.name, replicas_before=current,
            desired=desired, applied=len(sim.tree.children), action=action,
            queue=sample.queue, inflight=sample.inflight,
            workers=sample.workers,
            arrival_rate=sample.arrivals / self.interval_s,
            fn_deltas=fn_deltas)
        self.decisions.append(decision)
        return decision

    def _apply_fn_actions(self, sim, fn_deltas) -> None:
        """Prewarm (+n) and reap (-n) through the simulator's placement
        layer (``place_prewarm``/``place_reap``): the placer bin-packs
        replica starts by memory and picks the reap victim over a
        deterministic coldest/warmest-first candidate order, re-ranked
        after every placed unit (so a multi-unit delta re-packs against
        the updated footprints; for the ±1 deltas every built-in policy
        emits this is exactly the pre-placement order)."""
        for fn, delta in fn_deltas:
            for _ in range(abs(delta)):
                placed = (sim.place_prewarm(fn) if delta > 0
                          else sim.place_reap(fn))
                if placed is None:
                    break

    def _grow(self, sim) -> None:
        bid = self._branch_seq
        self._branch_seq += 1
        name = f"as-b{bid}"
        leaf = build_leaf(name, [f"{name}-w{j}"
                                 for j in range(self.workers_per_replica)],
                          self.leaf_policy)
        sim.add_branch(leaf)
        self._scaled.append(name)
        if self.prewarm_fns is None:
            return
        fns = (sim.store.list() if "auto" in self.prewarm_fns
               else self.prewarm_fns)
        for w in leaf.workers:
            for fn in fns:
                sim.prewarm(w, fn)

    # ------------------------------------------------------------ reporting
    def decision_log(self) -> str:
        """Byte-stable scaling-decision log (same seed => identical)."""
        return "\n".join(d.fmt() for d in self.decisions)

    def decision_records(self) -> List[dict]:
        """Structured decision log — feed to ``repro.autoscale.replay``."""
        return [d.to_record() for d in self.decisions]

    def summary(self) -> dict:
        ups = sum(d.action == "up" for d in self.decisions)
        downs = sum(d.action == "down" for d in self.decisions)
        return {"policy": self.policy.name, "ticks": len(self.decisions),
                "scale_ups": ups, "scale_downs": downs,
                "worker_seconds": self.worker_seconds,
                "replica_seconds": self.replica_seconds,
                "max_replicas_seen": max(
                    (d.applied for d in self.decisions), default=0)}

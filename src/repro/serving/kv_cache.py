"""Slot-pool KV cache for continuous batching.

One :class:`SlotCache` backs one function instance: a decode cache of width
``slots`` on the batch dim (the within-instance concurrency), with per-slot
insert (admission after prefill) and a shared decode step over all slots.
Inactive slots decode garbage that is never read — standard continuous
batching semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class SlotCache:
    def __init__(self, model, slots: int, max_len: int):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int32)           # next position per slot
        self.active = np.zeros(slots, bool)
        self.rid = np.full(slots, -1, np.int64)
        self.remaining = np.zeros(slots, np.int32)

    def free_slots(self):
        return [i for i in range(self.slots) if not self.active[i]]

    def admit(self, slot: int, prefill_cache, prompt_len: int, rid: int,
              gen_tokens: int):
        """Insert a prefilled (batch=1) sequence into `slot`."""
        def insert(c, p):
            # c: [K, slots, W, ...] or [K, slots, ...]; p batch dim = 1
            if c.ndim >= 3 and p.shape[2] != c.shape[2] and p.ndim == c.ndim:
                # attn cache: prefill width S0 <= W
                return jax.lax.dynamic_update_slice_in_dim(
                    c, jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros_like(c[:, slot:slot + 1]), p.astype(c.dtype),
                        0, axis=2),
                    slot, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                c, p.astype(c.dtype), slot, axis=1)
        self.cache = jax.tree.map(insert, self.cache, prefill_cache)
        self.pos[slot] = prompt_len
        self.active[slot] = True
        self.rid[slot] = rid
        self.remaining[slot] = gen_tokens

    def release(self, slot: int):
        self.active[slot] = False
        self.rid[slot] = -1

    def positions(self) -> jnp.ndarray:
        return jnp.asarray(self.pos)

    def advance(self):
        self.pos[self.active] += 1
        self.remaining[self.active] -= 1

    def finished_slots(self):
        return [i for i in range(self.slots)
                if self.active[i] and self.remaining[i] <= 0]

"""The REAL worker engine: executes registered functions as actual JAX models
on the local device, with continuous batching, measured cold starts, idle
lifecycle, and full telemetry — paper Fig. 2 step 1's "actual server".

A :class:`Worker` owns function instances; an instance is (params, compiled
prefill/decode, SlotCache). Cold start = param materialization + first-shape
jit, measured with a wall clock and charged to the triggering request — the
HyperFaaS analogue of a container pull + boot.

The :class:`Engine` glues a router tree over N workers in one process. It is
intentionally synchronous and deterministic (single CPU device); massive-load
studies use the simulator with workers emulated from THIS engine's telemetry.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from random import Random
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import LBNode, StateView, WorkerState
from repro.core.types import FunctionConfig, Request, RequestResult, TelemetryRecord
from repro.models import build_model
from repro.serving.kv_cache import SlotCache


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


# "image layer cache": the same function image (arch, slots) yields the same
# weights and compiled programs — first pull pays the full compile cold start,
# replica instances hit the cache (exactly a container image/layer cache).
_IMAGE_CACHE: Dict[tuple, tuple] = {}


class Instance:
    def __init__(self, iid: str, cfg: FunctionConfig, *, rng_seed: int = 0,
                 max_len: int = 256):
        self.iid = iid
        self.cfg = cfg
        t0 = time.monotonic()
        slots = cfg.concurrency if cfg.concurrency > 0 else cfg.max_instances_per_worker
        self.slots = slots
        key = (cfg.arch, slots, max_len)
        if key not in _IMAGE_CACHE:
            mcfg = get_config(cfg.arch)
            model = build_model(mcfg)
            params = model.init_params(jax.random.PRNGKey(hash(cfg.arch) % 2**31))
            prefill = jax.jit(lambda p, b: model.prefill(p, b))
            decode = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
            # shape warmup = the dominant cold-start cost (compile)
            kv0 = SlotCache(model, slots, max_len)
            warm = {"tokens": jnp.zeros((1, 16), jnp.int32)}
            jax.block_until_ready(prefill(params, warm)[0])
            jax.block_until_ready(decode(
                params, kv0.cache,
                {"token": jnp.zeros(slots, jnp.int32),
                 "pos": jnp.zeros(slots, jnp.int32)})[0])
            _IMAGE_CACHE[key] = (model, params, prefill, decode)
        self.model, self.params, self._prefill, self._decode = _IMAGE_CACHE[key]
        self.kv = SlotCache(self.model, slots, max_len)
        self.cold_start_s = time.monotonic() - t0
        self.last_used = time.monotonic()
        self.sampler = Random(rng_seed)
        self._last_tok = np.zeros(slots, np.int32)   # greedy-decode feedback
        self._slot_meta: Dict[int, object] = {}
        self.generated: Dict[int, list] = {}         # rid -> token ids

    def busy(self) -> int:
        return int(self.kv.active.sum())


@dataclass
class _Pending:
    req: Request
    submit_t: float


class Worker:
    def __init__(self, name: str, store: ConfigStore, registry: ImageRegistry,
                 *, max_len: int = 256):
        self.name = name
        self.store = store
        self.registry = registry
        self.max_len = max_len
        self.instances: Dict[str, List[Instance]] = {}
        self.pending: deque = deque()
        self.telemetry: List[TelemetryRecord] = []
        self.cold_starts = 0
        self._iid = 0

    # ------------------------------------------------------------- state
    def state(self) -> WorkerState:
        return WorkerState(
            worker=self.name, queue_len=len(self.pending),
            inflight=sum(i.busy() for il in self.instances.values() for i in il),
            capacity=max(sum(i.slots for il in self.instances.values()
                             for i in il), 1),
            warm_fns=frozenset(fn for fn, il in self.instances.items() if il))

    def submit(self, req: Request):
        self.pending.append(_Pending(req, time.monotonic()))

    # ---------------------------------------------------------- lifecycle
    def _get_instance(self, cfg: FunctionConfig):
        il = self.instances.setdefault(cfg.name, [])
        for inst in il:
            if inst.kv.free_slots():
                return inst, False
        if len(il) < cfg.max_instances_per_worker:
            self._iid += 1
            inst = Instance(f"{self.name}/i{self._iid}", cfg,
                            rng_seed=self._iid, max_len=self.max_len)
            il.append(inst)
            self.cold_starts += 1
            return inst, True
        return None, False

    def reap_idle(self):
        now = time.monotonic()
        for fn, il in self.instances.items():
            cfg = self.store.get(fn)
            for inst in list(il):
                if inst.busy() == 0 and now - inst.last_used > cfg.idle_timeout_s:
                    il.remove(inst)

    # ------------------------------------------------------------- serve
    def step(self) -> List[RequestResult]:
        """Admit pending into slots, run ONE decode step on every instance
        with active slots, and complete finished sequences."""
        results = []
        # admission
        still = deque()
        while self.pending:
            p = self.pending.popleft()
            cfg = self.store.get(p.req.fn)
            inst, cold = self._get_instance(cfg)
            if inst is None:
                still.append(p)
                continue
            slot = inst.kv.free_slots()[0]
            bl = _bucket(p.req.size)
            toks = np.zeros((1, bl), np.int32)
            payload = np.asarray(p.req.payload if p.req.payload is not None
                                 else np.arange(p.req.size) % 97 + 2)
            toks[0, :p.req.size] = payload[:p.req.size]
            logits, pcache = inst._prefill(inst.params,
                                           {"tokens": jnp.asarray(toks)})
            jax.block_until_ready(logits)
            inst.kv.admit(slot, pcache, bl, p.req.rid, cfg.gen_tokens)
            inst._last_tok[slot] = int(jnp.argmax(logits[0]))
            inst.generated[p.req.rid] = [int(inst._last_tok[slot])]
            inst.last_used = time.monotonic()
            self.telemetry.append(TelemetryRecord(
                fn=p.req.fn, t=p.submit_t, queue_len=len(self.pending),
                inflight=inst.busy() - 1, batch_size=inst.busy(),
                cold=cold, prompt_tokens=p.req.size,
                gen_tokens=cfg.gen_tokens,
                fn_cost=get_config(cfg.arch).param_count() / 1e7,
                latency=0.0, ok=True))
            p._telemetry_idx = len(self.telemetry) - 1
            p._instance = inst
            p._slot = slot
            p._cold = cold
            if not hasattr(inst, "_slot_meta"):
                inst._slot_meta = {}
            inst._slot_meta[slot] = p
        self.pending = still
        # decode step per instance
        for fn, il in self.instances.items():
            for inst in il:
                if inst.busy() == 0:
                    continue
                tok = jnp.asarray(inst._last_tok)
                logits, inst.kv.cache = inst._decode(
                    inst.params, inst.kv.cache,
                    {"token": tok, "pos": inst.kv.positions()})
                jax.block_until_ready(logits)
                nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
                for s in range(inst.slots):
                    if inst.kv.active[s]:
                        inst._last_tok[s] = nxt[s]
                        rid = int(inst.kv.rid[s])
                        if rid in inst.generated:
                            inst.generated[rid].append(int(nxt[s]))
                inst.kv.advance()
                inst.last_used = time.monotonic()
                for slot in inst.kv.finished_slots():
                    p = inst._slot_meta.pop(slot)
                    inst.kv.release(slot)
                    now = time.monotonic()
                    rec = self.telemetry[p._telemetry_idx]
                    rec.latency = now - p.submit_t
                    results.append(RequestResult(
                        rid=p.req.rid, fn=p.req.fn, ok=True,
                        arrival_t=p.submit_t, start_t=p.submit_t,
                        finish_t=now, cold_start=p._cold,
                        worker=self.name, instance=inst.iid))
        return results

    def drain(self) -> List[RequestResult]:
        out = []
        while self.pending or any(i.busy() for il in self.instances.values()
                                  for i in il):
            out.extend(self.step())
        return out


class Engine:
    """Router tree over real in-process workers."""

    def __init__(self, tree: LBNode, store: ConfigStore,
                 registry: ImageRegistry, *, seed: int = 0, max_len: int = 256):
        self.tree = tree
        self.store = store
        self.view = StateView()
        self.rng = Random(seed)
        self.workers = {w: Worker(w, store, registry, max_len=max_len)
                        for w in tree.all_workers()}
        for w in self.workers.values():
            self.view.update(w.state())

    def submit(self, req: Request):
        wid, _ = self.tree.route(req, self.view, self.rng, time.monotonic())
        self.workers[wid].submit(req)
        self.view.update(self.workers[wid].state())

    def run(self) -> List[RequestResult]:
        results = []
        while True:
            progressed = False
            for w in self.workers.values():
                r = w.step()
                if r or w.pending:
                    progressed = True
                results.extend(r)
                self.view.update(w.state())
            if not progressed and not any(
                    i.busy() for w in self.workers.values()
                    for il in w.instances.values() for i in il):
                break
        return results

    def telemetry(self) -> List[TelemetryRecord]:
        out = []
        for w in self.workers.values():
            out.extend(w.telemetry)
        return out

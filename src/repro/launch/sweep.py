"""Dry-run sweep driver: every (arch × shape × mesh) cell as a subprocess.

Subprocess isolation keeps one cell's compile failure (or RAM spike) from
killing the sweep, and each process gets a fresh 512-device jax runtime.
Resumable: cells with an existing status=ok/skip artifact are not re-run
(pass --force to redo).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, applicable_shapes, assigned_archs, get_config

ART = "artifacts/dryrun"


def cell_done(arch, shape, mesh):
    path = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("status") in ("ok", "skip")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--archs", default=",".join(assigned_archs()))
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    cells = []
    for mesh in args.meshes.split(","):
        for arch in args.archs.split(","):
            for shape in SHAPES:
                cells.append((arch, shape, mesh))

    t_start = time.time()
    n_ok = n_fail = n_skip = 0
    for i, (arch, shape, mesh) in enumerate(cells):
        if not args.force and cell_done(arch, shape, mesh):
            n_skip += 1
            continue
        reason = applicable_shapes(get_config(arch)).get(shape)
        tag = f"[{i+1}/{len(cells)}] {arch} x {shape} x {mesh}"
        if reason:
            # let dryrun.py write the skip artifact quickly (no jax init cost
            # shortcut: write it here directly)
            os.makedirs(ART, exist_ok=True)
            with open(os.path.join(ART, f"{arch}__{shape}__{mesh}.json"), "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "skip", "reason": reason}, f, indent=1)
            print(f"{tag}: SKIP ({reason})", flush=True)
            n_skip += 1
            continue
        t0 = time.time()
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh, "--out", ART],
            capture_output=True, text=True, timeout=args.timeout,
            env={**os.environ, "PYTHONPATH": "src"})
        ok = p.returncode == 0
        n_ok += ok
        n_fail += (not ok)
        last = [ln for ln in p.stdout.splitlines() if ln.strip()][-1:] or ["?"]
        print(f"{tag}: {'OK' if ok else 'FAIL'} ({time.time()-t0:.0f}s) {last[0][:160]}",
              flush=True)
        if not ok:
            err = (p.stderr or "")[-1500:]
            with open(os.path.join(ART, f"{arch}__{shape}__{mesh}.stderr"), "w") as f:
                f.write(p.stderr or "")
            print("      stderr tail:", err.splitlines()[-1] if err else "?", flush=True)
    print(f"sweep done in {(time.time()-t_start)/60:.1f}min: "
          f"ok={n_ok} fail={n_fail} skip/cached={n_skip}", flush=True)


if __name__ == "__main__":
    main()

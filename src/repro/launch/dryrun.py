import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at first
#   init, and the production meshes below need 512 placeholder CPU devices.
#   This is set ONLY here (never in conftest/pyproject): smoke tests and
#   benchmarks see the single real device.

import argparse
import json
import sys
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, assigned_archs, get_config
from repro.distributed.sharding import (make_resolver,
                                        rules_for_cfg, tree_shardings,
                                        with_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.layers import sharding_context
from repro.models.transformer import LM
from repro.telemetry import roofline
from repro.train.optimizer import make_optimizer
from repro.train.trainer import make_train_step

HBM_PER_CHIP_GIB = 16.0   # TPU v5e

# The CPU backend emulates bf16 in f32; XLA's loop-invariant code motion then
# hoists `convert(residual_stack)` out of the backward while-loop, carrying an
# f32 COPY of the whole [L, B, S, D] stack (+13.6 GiB measured on the 62-layer
# train cell). TPU has native bf16 — the hoist doesn't exist there — so the
# dry-run disables that pass to keep memory_analysis a faithful TPU proxy.
COMPILER_OPTS = {"xla_disable_hlo_passes": "while-loop-invariant-code-motion"}


def build_cell(cfg, shape, mesh, rules, model=None):
    """Returns (fn, example_args(ShapeDtypeStructs w/ shardings), donate, out_shardings)."""
    model = model or LM(cfg)
    repl = NamedSharding(mesh, P())

    params_abs = model.abstract_params()
    params_sh = tree_shardings(mesh, params_abs, model.param_axes(), rules)
    params_in = with_shardings(params_abs, params_sh)

    batch_abs, batch_axes = model.input_specs(shape)
    batch_sh = tree_shardings(mesh, batch_abs, batch_axes, rules)
    batch_in = with_shardings(batch_abs, batch_sh)

    if shape.mode == "train":
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        accum = max(1, min(cfg.grad_accum, shape.global_batch // dp))
        opt = make_optimizer("auto", 1e-4, cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = tree_shardings(mesh, opt_abs,
                                opt.state_axes(model.param_axes()), rules)
        opt_in = with_shardings(opt_abs, opt_sh)
        step = make_train_step(model, opt, accum=accum)
        metrics_sh = {"loss": repl, "grad_norm": repl}
        return (step, (params_in, opt_in, batch_in), (0, 1),
                (params_sh, opt_sh, metrics_sh))

    if shape.mode == "prefill":
        if not cfg.causal:
            # encoder: full-sequence logits, no decode cache
            def enc(params, batch):
                x, _ = model.forward_seq(params, batch, want_cache=False,
                                         remat=False)
                return model.logits(params, x)
            logits_sh = NamedSharding(mesh, roofline_spec(mesh, rules, shape, cfg))
            return enc, (params_in, batch_in), (), logits_sh
        step = lambda params, batch: model.prefill(params, batch)
        cache_abs, cache_axes = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_sh = tree_shardings(mesh, cache_abs, cache_axes, rules)
        logits_sh = tree_shardings(
            mesh, jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size),
                                       jnp.dtype(cfg.dtype)),
            ("act_batch", "act_vocab"), rules)
        return step, (params_in, batch_in), (), (logits_sh, cache_sh)

    # decode / long_decode
    cache_abs, cache_axes = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_sh = tree_shardings(mesh, cache_abs, cache_axes, rules)
    cache_in = with_shardings(cache_abs, cache_sh)
    step = lambda params, cache, batch: model.decode_step(params, cache, batch)
    logits_sh = tree_shardings(
        mesh, jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size),
                                   jnp.dtype(cfg.dtype)),
        ("act_batch", "act_vocab"), rules)
    return step, (params_in, cache_in, batch_in), (1,), (logits_sh, cache_sh)


def roofline_spec(mesh, rules, shape, cfg):
    from repro.distributed.sharding import resolve_spec
    return resolve_spec(mesh, (shape.global_batch, shape.seq_len, cfg.vocab_size),
                        ("act_batch", "act_seq", "act_vocab"), rules)


def _cost_tuple(compiled):
    ca = compiled.cost_analysis() or {}
    stats = roofline.parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "link_bytes": stats.link_bytes,
            "ops": stats.ops, "raw_bytes": stats.raw_bytes}


def _lin(c1, c2, k):
    """c1 + (k-1)*(c2-c1), element-wise over cost dicts."""
    out = {}
    for key in ("flops", "bytes", "link_bytes"):
        out[key] = max(0.0, c1[key] + (k - 1) * (c2[key] - c1[key]))
    out["ops"] = {o: int(c1["ops"].get(o, 0)
                         + (k - 1) * (c2["ops"].get(o, 0) - c1["ops"].get(o, 0)))
                  for o in set(c1["ops"]) | set(c2["ops"])}
    out["raw_bytes"] = {o: c1["raw_bytes"].get(o, 0.0)
                        + (k - 1) * (c2["raw_bytes"].get(o, 0.0)
                                     - c1["raw_bytes"].get(o, 0.0))
                        for o in set(c1["raw_bytes"]) | set(c2["raw_bytes"])}
    return out


def _scale(c, f):
    return {"flops": c["flops"] * f, "bytes": c["bytes"] * f,
            "link_bytes": c["link_bytes"] * f,
            "ops": {o: int(v * f) for o, v in c["ops"].items()},
            "raw_bytes": {o: v * f for o, v in c["raw_bytes"].items()}}


def _add(a, b):
    return {"flops": a["flops"] + b["flops"], "bytes": a["bytes"] + b["bytes"],
            "link_bytes": a["link_bytes"] + b["link_bytes"],
            "ops": {o: a["ops"].get(o, 0) + b["ops"].get(o, 0)
                    for o in set(a["ops"]) | set(b["ops"])},
            "raw_bytes": {o: a["raw_bytes"].get(o, 0.0) + b["raw_bytes"].get(o, 0.0)
                          for o in set(a["raw_bytes"]) | set(b["raw_bytes"])}}


def probe_costs(cfg, shape, mesh, rules) -> dict:
    """Exact per-device cost via shallow UNROLLED probes + linear extrapolation.

    XLA's cost_analysis counts while-loop bodies once, so the production
    (scanned) program under-reports FLOPs/bytes/collectives.  We compile the
    same cell at 1 and 2 periods with every scan unrolled and extrapolate:
    total = probe1 + (K-1)*(probe2 - probe1); train cells scale by the
    grad-accum factor, with the optimizer update probed separately at full
    depth (it is scan-free, so its costs are exact).
    """
    period = LM(cfg).period
    K = cfg.num_layers // period
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    accum = max(1, min(cfg.grad_accum, shape.global_batch // dp)) \
        if shape.mode == "train" else 1
    micro_b = shape.global_batch // accum
    pshape = replace(shape, global_batch=micro_b)

    probes = []
    for kp in (1, 2):
        cfgp = replace(cfg, num_layers=period * kp, grad_accum=1)
        model = LM(cfgp, unroll=True, attn_block=2048, mamba_chunk=2048)
        with mesh, sharding_context(make_resolver(mesh, rules)):
            if shape.mode == "train":
                params_abs = model.abstract_params()
                params_sh = tree_shardings(mesh, params_abs, model.param_axes(), rules)
                batch_abs, batch_axes = model.input_specs(pshape)
                batch_sh = tree_shardings(mesh, batch_abs, batch_axes, rules)

                def gstep(params, batch):
                    (_, _), grads = jax.value_and_grad(
                        model.loss_fn, has_aux=True)(params, batch)
                    return grads
                compiled = jax.jit(gstep, out_shardings=params_sh).lower(
                    with_shardings(params_abs, params_sh),
                    with_shardings(batch_abs, batch_sh)).compile()
            else:
                fn, args, donate, out_sh = build_cell(cfgp, pshape, mesh, rules,
                                                      model=model)
                compiled = jax.jit(fn, donate_argnums=donate,
                                   out_shardings=out_sh).lower(*args).compile()
        probes.append(_cost_tuple(compiled))
    cost = _lin(probes[0], probes[1], K)
    if shape.mode == "train":
        cost = _scale(cost, accum)
        # optimizer update at full depth (scan-free => exact)
        model = LM(cfg)
        opt = make_optimizer("auto", 1e-4, cfg)
        params_abs = model.abstract_params()
        params_sh = tree_shardings(mesh, params_abs, model.param_axes(), rules)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = tree_shardings(mesh, opt_abs,
                                opt.state_axes(model.param_axes()), rules)
        acc_dt = jnp.dtype(cfg.opt_state_dtype)
        grads_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, acc_dt), params_abs)
        with mesh:
            compiled = jax.jit(opt.update, out_shardings=(params_sh, opt_sh)).lower(
                with_shardings(grads_abs, params_sh),
                with_shardings(opt_abs, opt_sh),
                with_shardings(params_abs, params_sh)).compile()
        cost = _add(cost, _cost_tuple(compiled))
        cost["accum"] = accum
    return cost


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = False, rules_override=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicable_shapes(cfg).get(shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "status": "skip", "reason": skip}
    if skip:
        print(f"[dryrun] SKIP {arch} x {shape_name}: {skip}")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rules = rules_override or rules_for_cfg(shape.mode, cfg)

    t0 = time.time()
    fn, args, donate, out_sh = build_cell(cfg, shape, mesh, rules)
    with mesh, sharding_context(make_resolver(mesh, rules)):
        lowered = jax.jit(fn, donate_argnums=donate,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile(compiler_options=COMPILER_OPTS)
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)                           # proves the cell fits per-device HBM
    ca = compiled.cost_analysis()
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})

    t0 = time.time()
    cost = probe_costs(cfg, shape, mesh, rules)
    t_probe = time.time() - t0
    rep = roofline.analyze_from_parts(
        ma=ma, cost=cost, arch=arch, shape=shape,
        mesh_name=mesh_kind, n_devices=n_dev, cfg=cfg)
    fits = rep.mem["peak_gib"] <= HBM_PER_CHIP_GIB
    result.update(status="ok", fits=fits, lower_s=round(t_lower, 2),
                  compile_s=round(t_compile, 2), probe_s=round(t_probe, 2),
                  report=json.loads(rep.to_json()))
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
          f"peak={rep.mem['peak_gib']:.2f}GiB fits={fits} "
          f"compute={rep.t_compute*1e3:.2f}ms memory={rep.t_memory*1e3:.2f}ms "
          f"collective={rep.t_collective*1e3:.2f}ms bottleneck={rep.bottleneck} "
          f"useful={rep.useful_flops_ratio:.3f} roofline_frac={rep.roofline_fraction:.3f}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        if save_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="Multi-pod dry-run harness")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for a in assigned_archs():
            cfg = get_config(a)
            for s, reason in applicable_shapes(cfg).items():
                print(f"{a:22s} {s:12s} {'RUN' if reason is None else 'SKIP: ' + reason}")
        return 0

    assert args.arch and args.shape, "--arch and --shape required (or --list)"
    try:
        res = run_cell(args.arch, args.shape, args.mesh, args.out,
                       save_hlo=args.save_hlo)
        return 0 if res["status"] in ("ok", "skip") else 1
    except Exception:
        traceback.print_exc()
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            path = os.path.join(args.out,
                                f"{args.arch}__{args.shape}__{args.mesh}.json")
            with open(path, "w") as f:
                json.dump({"arch": args.arch, "shape": args.shape,
                           "mesh": args.mesh, "status": "error",
                           "error": traceback.format_exc()[-2000:]}, f, indent=1)
        return 1


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh construction (prescribed shapes; DESIGN.md §5).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked at first jax init, and only
``launch/dryrun.py`` sets the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(n_devices: int | None = None, *, model_axis: int = 1):
    """Small mesh over actually-available devices (tests, examples)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

"""Elasticity controller: drives scale-up/down + fault injection scenarios
against the simulator and reports SLO impact. The training-side analogue
(re-mesh via elastic checkpoint restore) is exercised in
tests/test_distributed_8dev.py::test_checkpoint_elastic_remesh.

  PYTHONPATH=src python -m repro.launch.elastic --scenario scale_out
"""
from __future__ import annotations

import argparse

from repro.core.config_store import ConfigStore
from repro.core.router import build_leaf, build_tree
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  poisson_load, summarize)
from repro.core.types import FunctionConfig

SCENARIOS = ("scale_out", "scale_in", "node_failure", "stragglers")


def run(scenario: str):
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    sim = Simulator(build_tree(8, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    hedge_after_s=0.4 if scenario == "stragglers" else None)
    poisson_load(sim, fn="fn", rps=500, duration_s=12, seed=3)

    if scenario == "scale_out":
        sim.run(until=4.0)
        sim.add_branch(build_leaf("leaf-x", [f"wx{i}" for i in range(8)]))
    elif scenario == "scale_in":
        sim.run(until=4.0)
        sim.remove_branch("lb-leaf1")
    elif scenario == "node_failure":
        sim.inject_failure("w0", at=3.0, recover_after=4.0)
        sim.inject_failure("w1", at=3.5, recover_after=4.0)
    elif scenario == "stragglers":
        sim.set_straggler("w2", 8.0)
        sim.set_straggler("w5", 8.0)
    sim.run()
    s = summarize(sim.results)
    print(f"[elastic:{scenario}] n={s['n']} fail={s['fail_rate']:.3f} "
          f"p50={s['p50']*1e3:.1f}ms p99={s['p99']*1e3:.1f}ms "
          f"workers_end={len(sim.tree.all_workers())}")
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    choices=list(SCENARIOS) + ["all"])
    args = ap.parse_args(argv)
    for sc in (SCENARIOS if args.scenario == "all" else [args.scenario]):
        run(sc)


if __name__ == "__main__":
    main()

"""Production-style training launcher.

Real execution on the local device(s) for reduced configs; the full assigned
configs are exercised via ``repro.launch.dryrun`` (ShapeDtypeStruct only).
Features: sharded step (logical-axis rules), grad accumulation, checkpoint/
restart with keep-k retention, optional cross-pod gradient compression (when
the mesh has a 'pod' axis), throughput logging.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \
      --steps 100 --ckpt artifacts/train_qwen
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import TRAIN_RULES, make_resolver, tree_shardings
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.layers import sharding_context
from repro.train.optimizer import make_optimizer
from repro.train.schedule import warmup_cosine
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="train_100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgdm"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg, attn_block=max(64, args.seq // 4))
    mesh = make_local_mesh(model_axis=args.model_axis)
    resolver = make_resolver(mesh, TRAIN_RULES)

    params = model.init_params(jax.random.PRNGKey(0))
    psh = tree_shardings(mesh, model.abstract_params(), model.param_axes(),
                         TRAIN_RULES)
    params = jax.device_put(params, psh)
    opt = make_optimizer(args.optimizer, warmup_cosine(args.lr, 20, args.steps),
                         cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, accum=args.accum))

    mgr = CheckpointManager(args.ckpt, keep=3) if args.ckpt else None
    start = 0
    if mgr:
        s, restored = mgr.restore_latest({"p": params, "o": opt_state})
        if restored is not None:
            params, opt_state, start = restored["p"], restored["o"], s
            print(f"[train] resumed at step {start}")

    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    pf = Prefetcher(stream, start_step=start)
    t0, tokens = time.time(), 0
    try:
        with mesh, sharding_context(resolver):
            for i in range(start, start + args.steps):
                params, opt_state, m = step_fn(params, opt_state, pf.next())
                tokens += args.batch * args.seq
                if i % 10 == 0 or i == start + args.steps - 1:
                    print(f"[train] step {i:5d} loss={float(m['loss']):.4f} "
                          f"gnorm={float(m['grad_norm']):.3f} "
                          f"tok/s={tokens/(time.time()-t0):.0f}", flush=True)
                if mgr and i and i % args.ckpt_every == 0:
                    mgr.save(i, {"p": params, "o": opt_state})
    finally:
        pf.stop()
    if mgr:
        mgr.save(start + args.steps, {"p": params, "o": opt_state})
        mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()

"""Serving launcher: stand up the platform and drive it with a workload.

  PYTHONPATH=src python -m repro.launch.serve --workers 2 --requests 16 \
      --fn-arch tiny_lm --concurrency 4
"""
from __future__ import annotations

import argparse

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import build_tree
from repro.core.simulator import summarize
from repro.core.types import FunctionConfig, Request
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--fn-arch", default="tiny_lm")
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--gen-tokens", type=int, default=6)
    ap.add_argument("--policy", default="least_loaded")
    args = ap.parse_args(argv)

    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch=args.fn_arch,
                             concurrency=args.concurrency,
                             gen_tokens=args.gen_tokens))
    engine = Engine(build_tree(args.workers, fanout=4,
                               leaf_policy=args.policy),
                    store, ImageRegistry(), max_len=64)
    for i in range(args.requests):
        engine.submit(Request(fn="fn", arrival_t=0.0, size=8 + 8 * (i % 3)))
    res = engine.run()
    s = summarize(res)
    print(f"[serve] ok={s['ok']}/{s['n']} p50={s['p50']*1e3:.0f}ms "
          f"p99={s['p99']*1e3:.0f}ms cold_rate={s['cold_rate']:.2f}")
    return 0


if __name__ == "__main__":
    main()

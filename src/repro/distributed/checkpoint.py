"""Sharded, asynchronous, elastic checkpointing (no orbax in this environment
— and the FaaS platform needs restore-onto-a-different-mesh semantics anyway).

Layout on disk::

    <dir>/step_000420/
        MANIFEST.json          # written LAST via atomic rename => commit point
        <leaf-escaped-name>/
            shard_d0_... .npy  # one file per (host-)addressable shard
            ...

Every array leaf is saved as one or more shard files tagged with the global
index ranges they cover. Restore reads the manifest, reassembles each leaf
from whatever shard tiling it was written with, and device_puts it under the
*target* sharding — so a checkpoint written on a (16,16) mesh restores onto
(2,16,16), (4,8), or a single device (elastic re-meshing / worker-count
changes). Corrupt or uncommitted steps (no MANIFEST) are skipped by
``latest_step``. ``keep`` bounds retention; ``async_save`` moves the
serialization off the training thread (the paper's worker lifecycle needs
non-blocking instance state persistence).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16/f8 dtypes with numpy)
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")

# numpy's .npy format can't represent ml_dtypes (bf16, f8): store them as raw
# unsigned views and view-cast back on load (manifest keeps the true dtype).
_NATIVE_KINDS = set("fiub")


def _to_savable(block: np.ndarray) -> np.ndarray:
    if block.dtype.kind in _NATIVE_KINDS:
        return block
    return block.view(np.dtype(f"u{block.dtype.itemsize}"))


def _from_saved(block: np.ndarray, dtype: str) -> np.ndarray:
    dt = np.dtype(dtype)
    if dt.kind in _NATIVE_KINDS:
        return block
    return block.view(dt)


def _leaf_name(path) -> str:
    return _SAFE.sub("_", jax.tree_util.keystr(path)).strip("_") or "root"


def _shard_ranges(arr: jax.Array):
    """Yield (index-ranges, numpy block) for each addressable unique shard."""
    seen = set()
    for s in arr.addressable_shards:
        idx = tuple((sl.start or 0, sl.stop if sl.stop is not None else dim)
                    for sl, dim in zip(s.index, arr.shape))
        if idx in seen:
            continue
        seen.add(idx)
        yield idx, np.asarray(s.data)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        """Snapshot is taken synchronously (host copies); IO may be async."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        blocks = []
        for path, leaf in flat:
            name = _leaf_name(path)
            shards = list(_shard_ranges(leaf)) if isinstance(leaf, jax.Array) \
                else [(tuple((0, d) for d in np.shape(leaf)), np.asarray(leaf))]
            blocks.append((name, np.shape(leaf), np.dtype(
                leaf.dtype if hasattr(leaf, "dtype") else type(leaf)).name, shards))
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, blocks, extra), daemon=True)
            self._thread.start()
        else:
            self._write(step, blocks, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, blocks, extra):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        manifest: Dict[str, Any] = {"step": step, "extra": extra or {},
                                    "leaves": {}}
        try:
            for name, shape, dtype, shards in blocks:
                leafdir = os.path.join(tmp, name)
                os.makedirs(leafdir, exist_ok=True)
                entries = []
                for i, (idx, block) in enumerate(shards):
                    fname = f"shard_{i:04d}.npy"
                    np.save(os.path.join(leafdir, fname), _to_savable(block))
                    entries.append({"file": fname, "index": idx})
                manifest["leaves"][name] = {"shape": list(shape),
                                            "dtype": dtype, "shards": entries}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)      # commit point
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):          # orphaned tmpdirs
            if d.startswith(".tmp_"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None) -> Any:
        """Reassemble onto `target`'s structure; `shardings` (optional tree)
        re-device_puts each leaf — the elastic re-meshing path."""
        stepdir = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(stepdir, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        sh_flat = None
        if shardings is not None:
            sh_flat = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
        leaves = []
        for i, (path, tgt) in enumerate(flat):
            name = _leaf_name(path)
            meta = manifest["leaves"][name]
            arr = np.zeros(meta["shape"], dtype=np.dtype(meta["dtype"]))
            for e in meta["shards"]:
                block = _from_saved(np.load(os.path.join(stepdir, name,
                                                         e["file"])),
                                    meta["dtype"])
                sl = tuple(slice(a, b) for a, b in e["index"])
                arr[sl] = block
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=meta["dtype"]))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target: Any, *, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings=shardings)

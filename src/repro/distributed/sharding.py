"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Every tensor dim carries a logical name (``w_*`` for weights, ``act_*`` for
activations). A :class:`RuleSet` maps logical names to a priority list of mesh
axis tuples. Resolution is *global-priority* (rules-dict order), not dim order:
e.g. in decode, ``act_kv_heads`` is tried before ``act_kv_seq``, so GQA caches
shard by head when the head count divides the axis (moonshot kv=16, phi3 kv=32)
and fall back to flash-decode-style sequence sharding otherwise (kv=8 archs) —
the per-arch sharding choices in DESIGN.md §5 emerge from divisibility alone.

Mesh-axis candidates absent from the mesh degrade gracefully: ``("pod","data")``
on the single-pod mesh behaves as ``("data",)`` — one rule table serves both
production meshes.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]
RuleTable = Dict[str, Sequence[Tuple[str, ...]]]


# --------------------------------------------------------------------------
# Baseline rule tables (the paper-faithful starting point; §Perf iterates)
# --------------------------------------------------------------------------

TRAIN_RULES: RuleTable = {
    # weights — TP over `model`, FSDP (ZeRO-3) over `data` on the other dim
    "w_vocab": [("model",)],
    "w_qdim": [("model",)],
    "w_kvdim": [("model",)],
    "w_mlp": [("model",)],
    "w_expert": [("model",)],          # EP when E % axis == 0 (moonshot, jamba)
    "w_moe_mlp": [("model",)],         # picks up TP when w_expert fell through (grok)
    "w_dinner": [("model",)],
    "w_embed": [("data",)],            # FSDP dim (pod added per-arch, see
    "w_state": [],                     # rules_for_cfg: grok/jamba only)
    "w_layers": [],
    # activations
    "act_batch": [("pod", "data")],
    "act_heads": [("model",)],
    "act_kv_heads": [("model",)],
    "act_mlp": [("model",)],
    "act_vocab": [("model",)],
    "act_expert": [("model",)],
    "act_seq": [],
    "act_embed": [],
    "act_kv_seq": [],
}

# prefill returns the full KV cache: shard kv_heads (else kv_seq) over model
# like decode, or an 88-layer 32k cache lands 23 GiB/device unsharded.
PREFILL_RULES: RuleTable = {**TRAIN_RULES,
                            "act_kv_heads": [("model",)],
                            "act_kv_seq": [("model",)]}
_prf = PREFILL_RULES.pop("act_kv_seq")  # reinsert AFTER kv_heads for priority
PREFILL_RULES["act_kv_seq"] = _prf

DECODE_RULES: RuleTable = {
    "w_vocab": [("model",)],
    "w_qdim": [("model",)],
    "w_kvdim": [("model",)],
    "w_mlp": [("model",)],
    "w_expert": [("model",)],
    "w_moe_mlp": [("model",)],
    "w_dinner": [("model",)],
    "w_embed": [("data",)],            # weights stay 2D-sharded for HBM fit
    "w_state": [],
    "w_layers": [],
    # WEIGHT-STATIONARY decode (§Perf iteration 2): the residual stream is
    # feature-sharded over `data` — aligned with the weights' FSDP dim — so
    # every matmul contracts locally and only [B, d]-sized partial sums
    # all-reduce. act_embed resolves BEFORE act_batch: on the x stream the
    # data axis goes to features (batch keeps `pod`); cache tensors have no
    # act_embed, so their batch dim still takes (pod, data) for HBM fit.
    "act_embed": [("data",)],
    "act_batch": [("pod", "data")],
    "act_kv_heads": [("model",)],      # tried BEFORE kv_seq (priority order)
    "act_kv_seq": [("model",)],        # flash-decode fallback for kv=8 archs
    "act_heads": [("model",)],
    "act_mlp": [("model",)],
    "act_vocab": [("model",)],
    "act_expert": [("model",)],
    "act_seq": [],
}

LONG_DECODE_RULES: RuleTable = {
    # batch=1: context parallelism — cache sequence over every available axis
    "act_kv_seq": [("pod", "data", "model"), ("data", "model")],
    "w_vocab": [("model",)],
    "w_qdim": [("model",)],
    "w_kvdim": [("model",)],
    "w_mlp": [("model",)],
    "w_expert": [("model",)],
    "w_moe_mlp": [("model",)],
    "w_dinner": [("model",)],
    "w_embed": [("data",)],
    "w_state": [],
    "w_layers": [],
    "act_embed": [("data",)],          # weight-stationary stream (batch=1)
    "act_batch": [("pod", "data")],
    "act_kv_heads": [],
    "act_heads": [("model",)],
    "act_mlp": [("model",)],
    "act_vocab": [("model",)],
    "act_expert": [("model",)],
    "act_seq": [],
}

RULES_BY_MODE: Dict[str, RuleTable] = {
    "train": TRAIN_RULES,
    "prefill": PREFILL_RULES,
    "decode": DECODE_RULES,
    "long_decode": LONG_DECODE_RULES,
}


# --------------------------------------------------------------------------
# Resolver
# --------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(mesh: Mesh, shape: Tuple[int, ...], names: Axes,
                 rules: RuleTable, *, for_constraint: bool = False) -> P:
    """PartitionSpec for one tensor, honoring global rule priority + no-reuse.

    ``for_constraint=True`` (activation ``with_sharding_constraint`` use):
    dims whose rule failed divisibility become ``P.UNCONSTRAINED`` instead of
    replicated — GSPMD may then factor them (e.g. deepseek's 56 heads tile
    8-way on half the 16-way model axis).  jit in/out shardings must stay
    concrete, so the default keeps replication on failure.
    """
    assert len(shape) == len(names), (shape, names)
    assignment: Dict[int, Tuple[str, ...]] = {}
    failed: set = set()
    used: set = set()
    # iterate logical names in RULES order (= priority), then dims in order
    for lname in rules:
        for dim, n in enumerate(names):
            if n != lname or dim in assignment:
                continue
            tried = False
            for cand in rules[lname]:
                eff = tuple(a for a in cand if a in mesh.axis_names and a not in used)
                if not eff:
                    continue
                tried = True
                size = _axis_size(mesh, eff)
                if size > 1 and shape[dim] % size == 0:
                    assignment[dim] = eff
                    used.update(eff)
                    break
            if dim in assignment:
                break  # a logical name is assigned at most once per tensor
            if tried:
                failed.add(dim)
    entries = []
    for d in range(len(shape)):
        e = assignment.get(d)
        if e is not None:
            entries.append(e[0] if len(e) == 1 else e)
        elif for_constraint and d in failed:
            entries.append(P.UNCONSTRAINED)
        else:
            entries.append(None)
    if not for_constraint:
        while entries and entries[-1] is None:
            entries.pop()
    return P(*entries)


def make_resolver(mesh: Mesh, rules: RuleTable):
    """Closure for ``repro.models.layers.sharding_context``."""
    def resolver(shape, names):
        spec = resolve_spec(mesh, tuple(shape), tuple(names), rules,
                            for_constraint=True)
        return NamedSharding(mesh, spec)
    return resolver


def tree_shardings(mesh: Mesh, spec_tree, axes_tree, rules: RuleTable):
    """Map (ShapeDtypeStruct tree, logical-axes tree) -> NamedSharding tree."""
    def one(s, ax):
        return NamedSharding(mesh, resolve_spec(mesh, s.shape, tuple(ax), rules))
    return jax.tree.map(one, spec_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def with_shardings(spec_tree, shardings_tree):
    """Attach shardings to ShapeDtypeStructs (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shardings_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def rules_for_cfg(mode: str, cfg) -> RuleTable:
    """Per-arch rule adjustments: fsdp_pod extends the weight FSDP axis to
    (pod, data) — needed by the >300B archs' optimizer state on the multi-pod
    mesh, a net loss for smaller archs (mistral: memory term +89%)."""
    rules = dict(RULES_BY_MODE[mode])
    if mode == "train" and getattr(cfg, "fsdp_pod", False):
        rules["w_embed"] = [("pod", "data")]
    return rules

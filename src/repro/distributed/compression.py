"""Gradient compression for cross-pod sync (the slow DCI hop at 512+ chips).

Two standard schemes, both with error feedback (the residual re-enters the
next step, so compression error doesn't bias the optimizer long-run):

* int8 quantization — 4x volume cut on bf16/f32 grads; per-tensor absmax scale.
* top-k sparsification — keep the largest |g| fraction, psum dense-ified
  (demonstration scale; production would all-gather indices).

``compressed_psum`` composes with shard_map over the ``pod`` axis; the
8-virtual-device subprocess test checks end-to-end numerics, and the
hypothesis property test checks the error-feedback contraction invariant.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def ef_compress_int8(g: jax.Array, err: jax.Array):
    """Error-feedback int8: returns (quantized payload, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def ef_compress_topk(g: jax.Array, err: jax.Array, frac: float):
    target = g.astype(jnp.float32) + err
    mask = topk_mask(target, frac)
    sent = target * mask
    return sent, target - sent


def make_pod_grad_sync(mesh, scheme: str = "int8", topk_frac: float = 0.05):
    """Returns sync(grads, err) -> (synced_grads, new_err), where the psum
    over the 'pod' axis carries the compressed representation.

    Run INSIDE shard_map over the pod axis (grads replicated per pod).
    """
    npod = mesh.shape.get("pod", 1)

    def sync_leaf(g, err):
        if scheme == "int8":
            q, scale, new_err = ef_compress_int8(g, err)
            # psum int8 payloads would overflow; send dequantized int8 values
            # (volume on the wire is the int8 payload + scalar scale)
            contrib = dequantize_int8(q, scale)
            total = jax.lax.psum(contrib, "pod")
            return (total / npod).astype(g.dtype), new_err
        if scheme == "topk":
            sent, new_err = ef_compress_topk(g, err, topk_frac)
            total = jax.lax.psum(sent, "pod")
            return (total / npod).astype(g.dtype), new_err
        total = jax.lax.psum(g.astype(jnp.float32), "pod")
        return (total / npod).astype(g.dtype), err

    def sync(grads, err_tree):
        out = jax.tree.map(sync_leaf, grads, err_tree)
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        return (jax.tree.unflatten(treedef, [t[0] for t in flat]),
                jax.tree.unflatten(treedef, [t[1] for t in flat]))

    return sync

"""Assigned-architecture configs: exact spec values + published-size sanity."""
import pytest

from repro.configs import (SHAPES, applicable_shapes, assigned_archs,
                           get_config, reduced)

EXPECTED_TOTALS_B = {    # published sizes (phi3 excludes the stubbed CLIP tower;
    "hubert_xlarge": (0.95, 0.10),       # moonshot uses the assigned 48L, see DESIGN.md)
    "deepseek_coder_33b": (33.3, 0.05),
    "mistral_large_123b": (122.6, 0.05),
    "gemma3_12b": (11.8, 0.10),
    "qwen3_32b": (32.8, 0.05),
    "grok1_314b": (314.0, 0.05),
    "jamba15_large": (398.0, 0.05),
    "falcon_mamba_7b": (7.0, 0.10),
    "phi3_vision": (3.8, 0.10),
}


def test_ten_archs_assigned():
    assert len(assigned_archs()) == 10


@pytest.mark.parametrize("arch", assigned_archs())
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0


@pytest.mark.parametrize("arch,exp", EXPECTED_TOTALS_B.items())
def test_param_counts_match_published(arch, exp):
    target, tol = exp
    got = get_config(arch).param_count() / 1e9
    assert abs(got - target) / target < tol, (arch, got, target)


def test_moe_active_counts():
    grok = get_config("grok1_314b")
    assert grok.active_param_count() < 0.3 * grok.param_count()
    jamba = get_config("jamba15_large")
    assert 80e9 < jamba.active_param_count() < 110e9   # ~94B published


def test_exact_assigned_specs():
    q = get_config("qwen3_32b")
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads,
            q.d_ff, q.vocab_size, q.qk_norm) == (64, 5120, 64, 8, 25600, 151936, True)
    g = get_config("gemma3_12b")
    assert (g.sliding_window, g.swa_local, g.swa_period) == (1024, 5, 6)
    j = get_config("jamba15_large")
    assert (j.attn_every, j.moe.num_experts, j.moe.top_k, j.moe.every) == (8, 16, 2, 2)
    m = get_config("moonshot_v1_16b")
    assert (m.moe.num_experts, m.moe.top_k, m.moe.expert_ff) == (64, 6, 1408)
    f = get_config("falcon_mamba_7b")
    assert f.attention_free and f.mamba.d_state == 16


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_cell_skip_rules():
    total_run = total_skip = 0
    for a in assigned_archs():
        app = applicable_shapes(get_config(a))
        total_run += sum(v is None for v in app.values())
        total_skip += sum(v is not None for v in app.values())
    assert total_run == 32 and total_skip == 8
    assert applicable_shapes(get_config("hubert_xlarge"))["decode_32k"]
    assert applicable_shapes(get_config("gemma3_12b"))["long_500k"] is None
    assert applicable_shapes(get_config("qwen3_32b"))["long_500k"] is not None


@pytest.mark.parametrize("arch", assigned_archs())
def test_reduced_preserves_family(arch):
    cfg = get_config(arch)
    r = reduced(cfg)
    assert r.family == cfg.family
    assert (r.moe is None) == (cfg.moe is None)
    assert (r.mamba is None) == (cfg.mamba is None)
    assert r.num_layers % max(r.swa_period if r.sliding_window else 1,
                              r.attn_every if r.mamba and not r.attention_free else 1) == 0
    if cfg.num_kv_heads:
        assert r.num_heads % r.num_kv_heads == 0


def test_config_json_roundtrip():
    from repro.configs.base import ModelConfig
    cfg = get_config("jamba15_large")
    assert ModelConfig.from_json(cfg.to_json()) == cfg

"""Placement layer + deadline-aware routing: the ISSUE-4 regression suite.

The contracts under test:

- the placer registry (`first_fit`, `best_fit_memory`, `spread`) ranks
  workers deterministically and only ever offers workers with memory
  headroom;
- worker memory capacity is *never* exceeded: the per-worker incremental
  footprint equals a flat rescan and stays under the cap at every
  instance add, across randomized scenario sweeps;
- unlimited memory + `first_fit` reproduces the pre-placement (PR 3)
  simulator byte-for-byte — golden digests recorded at the PR 3 tree
  are pinned below, so the new layer is provably a no-op until a memory
  cap is configured;
- placement + routing decision logs are seeded: same seed => byte
  identical logs on `flash_crowd` and `multi_tenant` (digests pinned);
- acceptance: on a memory-skewed `multi_tenant`, `best_fit_memory`
  placement + `deadline_aware` routing meets every tenant's p95 SLO at
  lower worker-seconds than `first_fit` + `least_loaded` (the PR 3
  style baseline), same enforcement style as the slo_aware test.
"""
import hashlib

import pytest

from repro.autoscale import Autoscaler, build_pool, get_autoscaler
from repro.core.config_store import ConfigStore
from repro.core.placement import (PLACERS, Placer, get_placer, list_placers,
                                  register_placer)
from repro.core.router import build_leaf, build_tree
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  summarize)
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario, install_demo_configs


# ----------------------------------------------------------------- registry
def test_registry_complete():
    assert set(list_placers()) >= {"first_fit", "best_fit_memory", "spread"}
    assert sorted(PLACERS) == list_placers()
    assert get_placer("first_fit").name == "first_fit"
    with pytest.raises(KeyError):
        get_placer("nope")


def test_register_custom_placer():
    @register_placer
    class _Tight(Placer):
        name = "_test_tightest"

        def place_order(self, fn, memory_mb, workers):
            return sorted((w for w in workers if w.fits(memory_mb)),
                          key=lambda w: w.mem_free_mb())
    try:
        assert "_test_tightest" in list_placers()
        assert isinstance(get_placer("_test_tightest"), _Tight)
    finally:
        del PLACERS["_test_tightest"]


# ------------------------------------------------------------ placer ranking
class _FakeWorker:
    def __init__(self, name, free_mb, fn_reps=0, total=0):
        self.name = name
        self._free = free_mb
        self._reps = fn_reps
        self.total_instances = total

    def fits(self, mem):
        return self._free >= mem

    def mem_free_mb(self):
        return self._free

    def fn_replicas(self, fn):
        return self._reps


def test_first_fit_keeps_candidate_order_and_filters_fit():
    ws = [_FakeWorker("a", 100), _FakeWorker("b", 600),
          _FakeWorker("c", 512)]
    order = get_placer("first_fit").place_order("fn", 512, ws)
    assert [w.name for w in order] == ["b", "c"]


def test_best_fit_picks_tightest_gap():
    ws = [_FakeWorker("a", 2048), _FakeWorker("b", 600),
          _FakeWorker("c", 512), _FakeWorker("d", 100)]
    order = get_placer("best_fit_memory").place_order("fn", 512, ws)
    assert [w.name for w in order] == ["c", "b", "a"]
    # reap relieves the most memory-pressured worker first
    reap = get_placer("best_fit_memory").reap_order("fn", ws)
    assert [w.name for w in reap] == ["d", "c", "b", "a"]


def test_spread_prefers_fewest_replicas_then_headroom():
    ws = [_FakeWorker("a", 1024, fn_reps=2), _FakeWorker("b", 512, fn_reps=0),
          _FakeWorker("c", 1024, fn_reps=0), _FakeWorker("d", 100, fn_reps=0)]
    order = get_placer("spread").place_order("fn", 256, ws)
    assert [w.name for w in order] == ["c", "b", "a"]


def test_placers_degenerate_to_input_order_when_uncapped():
    """Stable sorts on all-equal (inf) memory keys must preserve the
    simulator's preference order — the property that keeps uncapped runs
    byte-identical across every placer."""
    ws = [_FakeWorker(n, float("inf")) for n in ("w2", "w0", "w1")]
    for name in ("first_fit", "best_fit_memory"):
        order = get_placer(name).place_order("fn", 512, ws)
        assert [w.name for w in order] == ["w2", "w0", "w1"], name


# ------------------------------------------------------- memory admission
@pytest.fixture
def store():
    s = ConfigStore()
    s.put(FunctionConfig(name="small", arch="tiny_lm", concurrency=2,
                         cold_start_s=0.05, idle_timeout_s=5.0,
                         memory_mb=256))
    s.put(FunctionConfig(name="big", arch="tiny_lm", concurrency=1,
                         cold_start_s=0.05, idle_timeout_s=5.0,
                         memory_mb=1536))
    return s


def test_prewarm_respects_memory_capacity(store):
    sim = Simulator(build_tree(1, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    worker_memory_mb=2048)
    w = sim._worker_list[0]
    assert sim.prewarm(w, "big")                 # 1536 of 2048
    assert sim.prewarm(w, "small")               # 1792 of 2048
    assert sim.prewarm(w, "small")               # 2048 of 2048
    assert not sim.prewarm(w, "small"), "no memory left"
    assert not sim.prewarm(w, "big")
    ww = sim.workers[w]
    assert ww.memory_used_mb == 2048
    assert ww.mem_free_mb() == 0
    assert ww.replica_sets["big"].mem_mb == 1536
    assert ww.replica_sets["small"].mem_mb == 512


def test_reap_frees_memory_for_new_placement(store):
    # instant cold start (the ISSUE-3 falsy-zero fix): the prewarmed
    # replica is ready — and hence reapable — immediately
    store.put(FunctionConfig(name="big", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.0, idle_timeout_s=5.0,
                             memory_mb=1536))
    sim = Simulator(build_tree(1, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    worker_memory_mb=2048)
    w = sim._worker_list[0]
    assert sim.prewarm(w, "big")
    assert not sim.workers[w].fits(1536)
    assert sim.reap(w, "big")
    assert sim.workers[w].memory_used_mb == 0
    assert sim.workers[w].fits(1536)


def test_place_prewarm_uses_placer_and_reports_exhaustion(store):
    for name, mem in (("small", 256), ("big", 1536)):
        store.put(FunctionConfig(name=name, arch="tiny_lm", concurrency=1,
                                 cold_start_s=0.0, idle_timeout_s=5.0,
                                 memory_mb=mem))
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    worker_memory_mb=1536, placer="best_fit_memory")
    assert sim.place_prewarm("big") == "w0"      # coldest first
    assert sim.place_prewarm("big") == "w1"
    assert sim.place_prewarm("big") is None      # both workers full
    assert sim.place_prewarm("small") is None    # 1536 used everywhere
    assert sim.place_reap("big") in ("w0", "w1")
    assert sim.place_prewarm("small") is not None


def test_unplaceable_function_fails_not_crashes(store):
    """A function whose footprint exceeds every worker's capacity can
    never start; its requests must time out cleanly."""
    store.put(FunctionConfig(name="huge", arch="tiny_lm", memory_mb=4096,
                             timeout_s=0.5))
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    worker_memory_mb=2048)
    sim.submit(Request(fn="huge", arrival_t=0.0))
    res = sim.run()
    assert len(res) == 1 and not res[0].ok
    assert res[0].error == "queue timeout"


# ----------------------------------------- memory-capacity invariant (prop)
@pytest.mark.parametrize("trial", range(6))
def test_memory_capacity_never_exceeded_under_random_churn(trial):
    """Acceptance invariant: sum of placed replicas' memory_mb stays
    under the worker capacity at every instance add/remove, across
    randomized scenario/placer/capacity draws (seeded, so failures
    reproduce; the hypothesis lane in test_property.py explores the same
    driver over the whole seed space)."""
    from _prop_drivers import run_memory_cap_trial
    run_memory_cap_trial(1000 + trial)


# -------------------------------------- golden: unlimited memory == PR 3
from _prop_drivers import digest_sim as _digest  # noqa: E402  (shared def)


FLASH = dict(duration_s=30.0, seed=3, base_rps=12.0, burst_rps=1000.0,
             mean_burst_s=2.0, mean_calm_s=10.0)


def test_unlimited_memory_first_fit_matches_pr3_plain_run():
    """Digest recorded from the PR 3 simulator (pre-placement) on this
    exact configuration: first_fit with uncapped workers must not move a
    byte of the result/telemetry stream."""
    wl = build_scenario("multi_tenant", rps=400.0, duration_s=8.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(8, fanout=4, leaf_policy="warm_least_loaded"),
                    store, SyntheticServiceModel(seed=2), seed=7,
                    placer="first_fit", worker_memory_mb=None)
    sim.load(wl)
    sim.run()
    assert _digest(sim) == "856e5836b8ce9cd9"


def test_unlimited_memory_first_fit_matches_pr3_autoscaled_run():
    """Same contract through the full control loop: slo_aware per-fn
    prewarm/reap now flows through place_prewarm/place_reap, and with
    uncapped first_fit that path must reproduce the PR 3 decision
    stream and results exactly."""
    wl = build_scenario("flash_crowd", **FLASH)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(1, 2, leaf_policy="warm_least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=1, placer="first_fit")
    pol = get_autoscaler("slo_aware", slo_p95_s=wl.slo_targets())
    scaler = Autoscaler(pol, interval_s=0.25, window_s=2.0, min_replicas=1,
                        max_replicas=8, workers_per_replica=2, cooldown_s=2.0,
                        leaf_policy="warm_least_loaded")
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    sim.run()
    assert _digest(sim) == "9019f07d1f8667aa"
    dec = hashlib.sha256(scaler.decision_log().encode()).hexdigest()[:16]
    assert dec == "c7a8b3d40c5fc522"


# ------------------------------- golden: placement + routing decision logs
def _decision_log_sim(scenario, **over):
    wl = build_scenario(scenario, duration_s=6.0, seed=3, **over)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(
        build_tree(8, fanout=4, leaf_policy="deadline_aware",
                   inner_policy="deadline_aware"),
        store, SyntheticServiceModel(seed=2), seed=7,
        worker_memory_mb=2048, placer="best_fit_memory",
        record_decisions=True)
    sim.load(wl)
    sim.run()
    return sim


DECISION_GOLDEN = {
    # sha256[:16] of (placement_log, routing_log); recorded at ISSUE 4
    "flash_crowd": ("3f7810309f554a8e", "2bd7c3adb429b9fa"),
    "multi_tenant": ("3d641e4f3dce5bd5", "3d947fc9d8aa9a1f"),
}


@pytest.mark.parametrize("scenario", sorted(DECISION_GOLDEN))
def test_same_seed_identical_decision_logs(scenario):
    over = (dict(memory_skew=True, rps=200.0)
            if scenario == "multi_tenant" else dict(burst_rps=800.0))
    a = _decision_log_sim(scenario, **over)
    b = _decision_log_sim(scenario, **over)
    assert a.placement_records, "placement log must not be empty"
    assert a.routing_records, "routing log must not be empty"
    assert a.placement_log() == b.placement_log()
    assert a.routing_log() == b.routing_log()
    place = hashlib.sha256(a.placement_log().encode()).hexdigest()[:16]
    route = hashlib.sha256(a.routing_log().encode()).hexdigest()[:16]
    assert (place, route) == DECISION_GOLDEN[scenario]


def test_decision_logs_off_by_default():
    wl = build_scenario("steady", rps=100.0, duration_s=2.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=7)
    sim.load(wl)
    sim.run()
    assert sim.placement_records == [] and sim.routing_records == []


# --------------------------------------------- O(1) slots_total regression
def test_slots_total_counter_matches_flat_scan_under_churn():
    """ISSUE-4 satellite: slots_total is now an incremental counter; it
    must match the flat recomputation after every event, including
    slots==0 (unlimited-concurrency) instances whose contribution shifts
    with occupancy."""
    from repro.core import simulator as S
    wl = build_scenario("multi_tenant", rps=300.0, duration_s=5.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    # one unlimited-concurrency tenant so max(busy, 1) contributions move
    cfg = store.get("embed")
    store.put(FunctionConfig(**{**cfg.__dict__, "concurrency": 0}))
    sim = Simulator(build_tree(4, fanout=2, leaf_policy="warm_least_loaded"),
                    store, SyntheticServiceModel(seed=2), seed=7,
                    worker_memory_mb=2048)
    sim.load(wl)

    checked = {"n": 0}
    orig = S.Simulator._refresh_view

    def spy(self, w):
        flat = sum((i.slots if i.slots > 0 else max(i.busy, 1))
                   for i in w.iid_index.values()) or 1
        assert w.slots_total() == flat, (w.name, w.slots_total(), flat)
        checked["n"] += 1
        orig(self, w)
    S.Simulator._refresh_view = spy
    try:
        sim.run()
    finally:
        S.Simulator._refresh_view = orig
    assert checked["n"] > 1000


# -------------------------------------------------- deadline-aware routing
def test_deadline_aware_prefers_worker_with_free_warm_slot():
    import random as _random
    from repro.core.router import StateView, WorkerState, deadline_aware_policy
    view = StateView()
    view.update(WorkerState(worker="cold", warm_fns=frozenset()), 0.0)
    view.update(WorkerState(worker="warm", warm_fns=frozenset({"fn"}),
                            fn_free_slots={"fn": 2}), 0.0)
    req = Request(fn="fn", arrival_t=0.0, deadline_t=0.5)
    pick = deadline_aware_policy(req, ["cold", "warm"], view,
                                 _random.Random(0), 0.0)
    assert pick == "warm"


def test_deadline_aware_avoids_memory_blocked_cold_start():
    import random as _random
    from repro.core.router import StateView, WorkerState, deadline_aware_policy
    view = StateView()
    view.fn_memory["fn"] = 1024.0
    # blocked looks idle (low load) but cannot host the replica;
    # roomy carries a deep-ish queue yet can actually start one
    view.update(WorkerState(worker="blocked", mem_free_mb=0.0,
                            queue_len=0, inflight=0, capacity=8), 0.0)
    view.update(WorkerState(worker="roomy", mem_free_mb=2048.0,
                            queue_len=4, inflight=4, capacity=8,
                            fn_queue={"fn": 2}), 0.0)
    req = Request(fn="fn", arrival_t=0.0, deadline_t=1.0)
    pick = deadline_aware_policy(req, ["blocked", "roomy"], view,
                                 _random.Random(0), 0.0)
    assert pick == "roomy"


def test_graded_mem_eta_prefers_nearly_free_blocked_worker():
    """ISSUE-10 satellite A/B at the policy level: the flat penalty
    prices a 24 MB deficit identically to a full worker, so it routes
    to a drowning-but-startable worker; the placer-aware graded ETA
    prices the *unblock wait* and picks the nearly-free idle worker."""
    import random as _random
    from repro.core.placement import get_placer
    from repro.core.router import StateView, WorkerState, deadline_aware_policy

    def make_view():
        view = StateView()
        view.fn_memory["fn"] = 1024.0
        # blocked: idle, 24 MB short of hosting the replica
        view.update(WorkerState(worker="blocked", mem_free_mb=1000.0,
                                queue_len=0, inflight=0, capacity=8), 0.0)
        # drowning: room to start, but 12 queued + 8 inflight ahead
        view.update(WorkerState(worker="drowning", mem_free_mb=2048.0,
                                queue_len=12, inflight=8, capacity=8,
                                fn_queue={"fn": 12}), 0.0)
        return view

    req = Request(fn="fn", arrival_t=0.0, deadline_t=1.0)
    flat = deadline_aware_policy(req, ["blocked", "drowning"], make_view(),
                                 _random.Random(0), 0.0)
    assert flat == "drowning"
    graded = make_view()
    graded.mem_eta = get_placer("first_fit").blocked_cold_eta_s
    pick = deadline_aware_policy(req, ["blocked", "drowning"], graded,
                                 _random.Random(0), 0.0)
    assert pick == "blocked"
    # the graded estimate is capped at the flat penalty: a *hopeless*
    # deficit with a mountain of outstanding work never outranks the
    # flat model's view of an unblocked worker
    from repro.core.router import MEM_BLOCKED_PENALTY_S
    eta = get_placer("first_fit").blocked_cold_eta_s(
        4096.0, 0.0, 1e9, 10**6, 10**6)
    assert eta == MEM_BLOCKED_PENALTY_S


def _mem_eta_ab_sim(mem_eta):
    """Memory-tight fleet for the routing A/B: one worker pinned by a
    soon-to-idle filler replica (blocked for ``big``), the other
    startable but absorbing the whole arrival stream under the flat
    penalty."""
    store = ConfigStore()
    # filler holds half of one worker's memory for ~0.7 s of service,
    # then its replica idles out 0.1 s later — the "unblock" moment,
    # safely after the last big arrival so the flat run stays pinned
    store.put(FunctionConfig(name="filler", arch="tiny_lm", concurrency=1,
                             memory_mb=512, cold_start_s=0.0,
                             idle_timeout_s=0.1, gen_tokens=1500))
    store.put(FunctionConfig(name="big", arch="tiny_lm", concurrency=1,
                             memory_mb=1024, cold_start_s=0.02,
                             idle_timeout_s=5.0, timeout_s=30.0,
                             gen_tokens=55))
    sim = Simulator(build_leaf("b", ["w0", "w1"], "deadline_aware"), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    worker_memory_mb=1024, mem_eta=mem_eta)
    sim.submit(Request(fn="filler", arrival_t=0.0, rid=0))
    # 50 arrivals at 100/s, all inside the blocked window: service is
    # ~50 ms each, so the startable worker drowns at 5x its drain rate
    for i in range(50):
        sim.submit(Request(fn="big", arrival_t=0.05 + 0.01 * i, rid=1 + i))
    sim.run()
    return sim


def test_graded_mem_eta_spreads_blocked_load_and_wins_ab():
    """End-to-end A/B: under the flat penalty every ``big`` request
    piles onto the single startable worker; the graded ETA also queues
    on the blocked worker (which unblocks as soon as the filler replica
    idles out), serving from both and cutting the mean latency."""
    flat = _mem_eta_ab_sim("flat")
    graded = _mem_eta_ab_sim("placer")
    workers = lambda sim: {r.worker for r in sim.results  # noqa: E731
                           if r.fn == "big" and r.ok}
    assert len(workers(flat)) == 1         # flat: one-worker pileup
    assert len(workers(graded)) == 2       # graded: both serve
    mean = lambda sim: (sum(r.latency for r in sim.results  # noqa: E731
                            if r.fn == "big" and r.ok)
                        / sum(r.fn == "big" and r.ok for r in sim.results))
    assert mean(graded) < mean(flat)


def test_mem_eta_placer_is_noop_without_memory_pressure():
    """With uncapped workers the blocked branch never fires, so the
    graded pricing must not move a byte versus the flat default."""
    wl = build_scenario("multi_tenant", rps=200.0, duration_s=4.0, seed=3)

    def run(mode):
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(
            build_tree(8, fanout=4, leaf_policy="deadline_aware",
                       inner_policy="deadline_aware"),
            store, SyntheticServiceModel(seed=2), seed=7,
            worker_memory_mb=None, mem_eta=mode)
        sim.load(wl)
        sim.run()
        return sim
    assert _digest(run("placer")) == _digest(run("flat"))


def test_branch_level_state_rows_published_for_deadline_trees():
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.05, memory_mb=256))
    sim = Simulator(
        build_pool(2, 2, leaf_policy="deadline_aware",
                   inner_policy="deadline_aware"),
        store, SyntheticServiceModel(seed=2), seed=5,
        worker_memory_mb=1024)
    sim.submit(Request(fn="fn", arrival_t=0.0))
    sim.run()
    leaf = sim.tree.children[0].name
    row = sim.view.get(leaf)
    members = [sim.workers[w] for w in sim._leaf_members[leaf]]
    assert row.capacity == sum(w.slots_total() for w in members)
    assert row.mem_free_mb == max(w.mem_free_mb() for w in members)


def test_leaf_rows_are_dirty_lazy_under_member_churn():
    """ISSUE-5 satellite: the eager scheme re-aggregated a leaf's row —
    O(leaf_size × fns) — on *every* member event. Leaf rows are now
    dirty-lazy like inner-node rows: a member event only stamps the
    leaf dirty; aggregation runs on the next routing *read* and is
    cached until the next member event. A drain phase (in-flight work
    finishing after the last arrival: plenty of member events, zero
    routing reads) must therefore trigger zero aggregations, and a read
    afterwards must still see the live aggregate."""
    from repro.core import simulator as S
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.05, memory_mb=256,
                             idle_timeout_s=0.5))
    sim = Simulator(
        build_pool(2, 4, leaf_policy="deadline_aware",
                   inner_policy="deadline_aware"),
        store, SyntheticServiceModel(seed=2), seed=5,
        worker_memory_mb=1024)
    wl = build_scenario("steady", rps=400.0, duration_s=2.0, seed=4)
    sim.load(wl)
    sim.run(until=2.01)           # all arrivals routed; backlog in flight

    calls = {"n": 0}
    refreshes = {"n": 0}
    orig_agg = S.Simulator._aggregate_state
    orig_refresh = S.Simulator._refresh_view

    def agg_spy(self, name, members, now=None):
        calls["n"] += 1
        return orig_agg(self, name, members, now)

    def refresh_spy(self, w):
        refreshes["n"] += 1
        orig_refresh(self, w)
    S.Simulator._aggregate_state = agg_spy
    S.Simulator._refresh_view = refresh_spy
    try:
        sim.run()                 # pure drain: member events, no arrivals
    finally:
        S.Simulator._aggregate_state = orig_agg
        S.Simulator._refresh_view = orig_refresh
    assert refreshes["n"] > 50, "drain phase must churn member state"
    assert calls["n"] == 0, \
        f"{calls['n']} eager leaf aggregations during a read-free drain"
    # a read after the drain still resolves to the live aggregate
    leaf = sim.tree.children[0].name
    row = sim.view.get(leaf)
    members = [sim.workers[w] for w in sim._leaf_members[leaf]]
    assert row.capacity == sum(w.slots_total() for w in members)
    assert row.mem_free_mb == max(w.mem_free_mb() for w in members)
    # ... and is cached: a second read with no member event in between
    # does not re-aggregate
    calls["n"] = 0
    S.Simulator._aggregate_state = agg_spy
    try:
        first = sim.view.get(leaf)
        again = sim.view.get(leaf)
    finally:
        S.Simulator._aggregate_state = orig_agg
    assert first is again and calls["n"] == 0


def test_inner_node_state_resolves_in_deep_trees():
    """Trees deeper than two levels score *inner* nodes at the upper
    LB levels; those names have no eagerly-refreshed row and must
    resolve to a lazily-aggregated subtree state instead of the blind
    empty default."""
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.05, memory_mb=256,
                             idle_timeout_s=30.0))
    sim = Simulator(
        build_tree(16, fanout=2, leaf_policy="deadline_aware",
                   inner_policy="deadline_aware"),
        store, SyntheticServiceModel(seed=2), seed=5,
        worker_memory_mb=1024)
    wl = build_scenario("steady", rps=200.0, duration_s=2.0, seed=4)
    sim.load(wl)
    sim.run(until=1.0)            # mid-run: replicas are live and warm
    inner = sim.tree.children[0]
    assert not inner.is_leaf, "16 workers at fanout 2 must nest inner nodes"
    row = sim.view.get(inner.name)
    # the aggregate is over the members' *view rows* (the same staleness
    # the per-worker rows model), not live worker state
    rows = [sim.view.get(w) for w in inner.all_workers()]
    assert row.capacity == sum(r.capacity for r in rows)
    assert row.mem_free_mb == max(r.mem_free_mb for r in rows if r.healthy)
    assert row.inflight == sum(r.inflight for r in rows)
    assert "fn" in row.warm_fns
    # unknown names still fall back to the empty default
    assert sim.view.get("no-such-node").capacity == 1


# ------------------------------------------------ acceptance: best_fit wins
def _acceptance_run(placer, leaf, inner):
    """Run one matrix cell through the *shared* ISSUE-4 acceptance
    surface (examples/placement_study.py run_cell — the same definition
    the CI bench imports), so the pinned acceptance, the study, and the
    bench can never drift apart."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "examples"))
    from placement_study import CELLS, run_cell
    assert (placer, leaf, inner) in CELLS
    sim, scaler, results, per_fn = run_cell(placer, leaf, inner)
    targets = {fn: slo for fn, (p95, slo) in per_fn.items()}
    p95 = {fn: p for fn, (p, slo) in per_fn.items()}
    return targets, p95, scaler, summarize(results)


def test_best_fit_deadline_aware_meets_slo_cheaper_than_first_fit():
    """The placement-layer headline: on a memory-skewed multi_tenant mix
    (batch replicas monopolise a worker's memory), best_fit_memory
    packing + deadline_aware routing must meet every tenant's p95 SLO
    while spending fewer worker-seconds than the PR 3-style first_fit +
    least_loaded baseline — same enforcement style as the slo_aware
    acceptance test."""
    targets, p95_base, sc_base, s_base = _acceptance_run(
        "first_fit", "least_loaded", "random")
    targets2, p95_new, sc_new, s_new = _acceptance_run(
        "best_fit_memory", "deadline_aware", "deadline_aware")
    assert targets == targets2 and set(targets) == {"chat", "embed", "batch"}
    for fn, slo in targets.items():
        assert p95_new[fn] < slo, (fn, p95_new[fn], slo)
    assert sc_new.worker_seconds < sc_base.worker_seconds
    # and the baseline is genuinely worse: it blows at least one SLO
    assert any(not (p95_base[fn] < slo) for fn, slo in targets.items())
    assert s_new["fail_rate"] <= s_base["fail_rate"]

"""Real serving engine: actual JAX execution, continuous batching, cold starts."""
import numpy as np
import pytest

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import build_tree
from repro.core.types import FunctionConfig, Request
from repro.serving.engine import Engine, Worker


@pytest.fixture(scope="module")
def platform():
    store = ConfigStore()
    store.put(FunctionConfig(name="gen", arch="tiny_lm", concurrency=4,
                             gen_tokens=4, idle_timeout_s=60.0))
    return store, ImageRegistry()


@pytest.fixture(scope="module")
def engine(platform):
    store, registry = platform
    return Engine(build_tree(2, fanout=2), store, registry, max_len=64)


@pytest.mark.slow
def test_batched_requests_complete(engine):
    reqs = [Request(fn="gen", arrival_t=0.0, size=8) for _ in range(6)]
    for r in reqs:
        engine.submit(r)
    results = engine.run()
    assert len(results) == 6
    assert all(r.ok for r in results)
    assert {r.rid for r in results} == {r.rid for r in reqs}


@pytest.mark.slow
def test_cold_then_warm(engine):
    r1 = Request(fn="gen", arrival_t=0.0, size=8)
    engine.submit(r1)
    engine.run()
    r2 = Request(fn="gen", arrival_t=0.0, size=8)
    engine.submit(r2)
    res2 = engine.run()
    tel = engine.telemetry()
    cold_flags = {t.cold for t in tel}
    assert True in cold_flags         # first touch compiled
    assert res2[-1].ok


@pytest.mark.slow
def test_greedy_decode_matches_offline(platform):
    """Engine-generated tokens == offline greedy decode on the same params."""
    import jax
    import jax.numpy as jnp
    store, registry = platform
    w = Worker("w0", store, registry, max_len=64)
    req = Request(fn="gen", arrival_t=0.0, size=8)
    w.submit(req)
    results = w.drain()
    assert results and results[0].ok
    inst = w.instances["gen"][0]
    got = inst.generated[req.rid]

    # offline: same params, same prompt handling (bucket to 16 with zero pad)
    toks = np.zeros((1, 16), np.int32)
    toks[0, :8] = (np.arange(8) % 97 + 2)
    logits, cache = inst.model.prefill(inst.params, {"tokens": jnp.asarray(toks)})
    cache_w = inst.model.init_cache(1, 64)
    cache = jax.tree.map(
        lambda d, s: s if s.shape[1:] == d.shape[1:] and s.shape == d.shape
        else d.at[:, :1, :s.shape[2]].set(s.astype(d.dtype)) if d.ndim >= 3
        else d, cache_w, cache)
    exp = [int(jnp.argmax(logits[0]))]
    tok = exp[0]
    for i in range(3):
        lg, cache = inst.model.decode_step(
            inst.params, cache,
            {"token": jnp.asarray([tok]), "pos": jnp.asarray([16 + i])})
        tok = int(jnp.argmax(lg[0]))
        exp.append(tok)
    assert got[:2] == exp[:2], (got, exp)


@pytest.mark.slow
def test_within_instance_concurrency_real(platform):
    """c=1 spawns more instances than c=4 on the real engine too (RQ-A)."""
    store, registry = platform
    counts = {}
    for c in (1, 4):
        store.put(FunctionConfig(name="gen", arch="tiny_lm", concurrency=c,
                                 gen_tokens=2, idle_timeout_s=60.0))
        w = Worker(f"w-{c}", store, registry, max_len=64)
        for _ in range(4):
            w.submit(Request(fn="gen", arrival_t=0.0, size=8))
        w.drain()
        counts[c] = len(w.instances["gen"])
    store.put(FunctionConfig(name="gen", arch="tiny_lm", concurrency=4,
                             gen_tokens=4, idle_timeout_s=60.0))
    assert counts[1] == 4 and counts[4] == 1


@pytest.mark.slow
def test_telemetry_recorded(engine):
    tel = engine.telemetry()
    assert tel
    t = tel[-1]
    assert t.latency > 0 and t.fn == "gen" and len(t.features()) == 7

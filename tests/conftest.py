import os
import sys
import time

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real device; only launch/dryrun.py (its own process) forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture(autouse=True)
def _fast_lane_budget(request):
    """Fail any non-`slow` test that exceeds the per-test wall budget.

    Enabled by setting FAST_TEST_BUDGET_S (CI runs the smoke lane with
    30): a test too heavy for the fast lane must either get faster or be
    marked `slow`, instead of silently eroding the lane."""
    budget = float(os.environ.get("FAST_TEST_BUDGET_S", "0") or 0)
    t0 = time.perf_counter()
    yield
    if not budget or "slow" in request.keywords:
        return
    took = time.perf_counter() - t0
    if took > budget:
        pytest.fail(f"{request.node.nodeid} took {took:.1f}s — over the "
                    f"{budget:.0f}s fast-lane budget; speed it up or mark "
                    f"it @pytest.mark.slow", pytrace=False)

import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real device; only launch/dryrun.py (its own process) forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

"""Prefill+decode == full forward (f32 exact; the system's key invariant).

MoE runs with no-drop capacity (capacity routing is not length-invariant by
design — Switch semantics); bf16 drift is covered by a loose sanity bound.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model

FAMILIES = ["deepseek_coder_33b", "gemma3_12b", "qwen3_32b", "moonshot_v1_16b",
            "falcon_mamba_7b", "jamba15_large", "phi3_vision"]


def _prep(arch, dtype):
    cfg = reduced(get_config(arch))
    cfg = replace(cfg, dtype=dtype)
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       capacity_factor=float(cfg.moe.num_experts)))
    return cfg


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILIES)
def test_parity_f32_exact(arch, rng):
    cfg = _prep(arch, "float32")
    m = build_model(cfg, attn_block=8)
    params = m.init_params(rng)
    B, S, S0 = 2, 24, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "patches":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model), jnp.float32)

    x, _ = m.forward_seq(params, batch, want_cache=False)
    full = np.asarray(m.logits(params, x), np.float32)

    b0 = {k: (v[:, :S0] if k == "tokens" else v) for k, v in batch.items()}
    lg, cache = jax.jit(m.prefill)(params, b0)
    np.testing.assert_allclose(np.asarray(lg, np.float32), full[:, S0 - 1],
                               rtol=2e-3, atol=2e-3)

    cache_w = m.init_cache(B, S)
    cache = jax.tree.map(
        lambda d, s: s if s.shape == d.shape
        else d.at[:, :, :s.shape[2]].set(s.astype(d.dtype)), cache_w, cache)
    dec = jax.jit(m.decode_step)
    for t in range(S0, S):
        lg, cache = dec(params, cache,
                        {"token": toks[:, t], "pos": jnp.full((B,), t, jnp.int32)})
        np.testing.assert_allclose(np.asarray(lg, np.float32), full[:, t],
                                   rtol=2e-3, atol=2e-3, err_msg=f"{arch} t={t}")


@pytest.mark.slow
def test_parity_bf16_bounded(rng):
    """bf16 drift stays bounded (exactness is the f32 test's job)."""
    cfg = _prep("qwen3_32b", "bfloat16")
    m = build_model(cfg, attn_block=8)
    params = m.init_params(rng)
    B, S, S0 = 2, 20, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    x, _ = m.forward_seq(params, {"tokens": toks}, want_cache=False)
    full = np.asarray(m.logits(params, x), np.float32)
    lg, cache = m.prefill(params, {"tokens": toks[:, :S0]})
    cache_w = m.init_cache(B, S)
    cache = jax.tree.map(
        lambda d, s: s if s.shape == d.shape
        else d.at[:, :, :s.shape[2]].set(s.astype(d.dtype)), cache_w, cache)
    errs = []
    for t in range(S0, S):
        lg, cache = m.decode_step(params, cache,
                                  {"token": toks[:, t],
                                   "pos": jnp.full((B,), t, jnp.int32)})
        errs.append(np.max(np.abs(np.asarray(lg, np.float32) - full[:, t])))
    assert max(errs) < 0.25, errs


@pytest.mark.slow
def test_ring_buffer_local_cache(rng):
    """gemma3 local slots keep a ring cache of width == sliding_window."""
    cfg = _prep("gemma3_12b", "float32")
    m = build_model(cfg, attn_block=8)
    B, S = 1, 48
    W = cfg.sliding_window
    assert W < S
    specs, _ = m.cache_specs(B, S)
    widths = [sl["k"].shape[2] for sl in specs["slots"] if "k" in sl]
    assert sorted(set(widths)) == [W, S]

    params = m.init_params(rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    x, _ = m.forward_seq(params, {"tokens": toks}, want_cache=False)
    full = np.asarray(m.logits(params, x), np.float32)
    S0 = 32
    lg, cache = m.prefill(params, {"tokens": toks[:, :S0]})
    cache_w = m.init_cache(B, S)

    def blend(d, s):
        if s.shape == d.shape:
            return s
        if d.shape[2] == W and s.shape[2] == W:
            return s
        return d.at[:, :, :s.shape[2]].set(s.astype(d.dtype))
    cache = jax.tree.map(blend, cache_w, cache)
    for t in range(S0, S):
        lg, cache = m.decode_step(params, cache,
                                  {"token": toks[:, t],
                                   "pos": jnp.full((B,), t, jnp.int32)})
        np.testing.assert_allclose(np.asarray(lg, np.float32), full[:, t],
                                   rtol=3e-3, atol=3e-3, err_msg=f"t={t}")

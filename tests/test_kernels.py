"""Pallas kernels (interpret=True) vs pure-jnp oracles, swept over
shapes/dtypes as required by the deliverables."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import grouped_matmul

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,H,KV,hd,causal,window", [
    (2, 256, 4, 2, 64, True, 0),
    (1, 256, 4, 4, 128, False, 0),
    (2, 512, 8, 2, 64, True, 100),
    (1, 128, 2, 1, 32, True, 0),
])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=128)
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.slow
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,W,H,KV,hd,ring", [
    (2, 256, 8, 2, 64, False),
    (3, 128, 4, 4, 32, True),
    (1, 512, 16, 2, 128, False),
])
def test_decode_attention_sweep(B, W, H, KV, hd, ring, dtype, rng):
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, W, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, W, KV, hd), dtype)
    pos = jax.random.randint(ks[3], (B,), 5, W * 2 if ring else W)
    out = decode_attention(q, kc, vc, pos, ring=ring, block_w=64)
    ref = R.decode_attention_ref(q, kc, vc, pos, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.slow
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,DI,N,chunk,bd", [
    (2, 128, 64, 8, 32, 32),
    (1, 64, 128, 16, 64, 64),
    (2, 96, 32, 4, 32, 16),
])
def test_mamba_scan_sweep(B, S, DI, N, chunk, bd, dtype, rng):
    ks = jax.random.split(rng, 6)
    dt = (jax.nn.softplus(jax.random.normal(ks[0], (B, S, DI))) * 0.1).astype(dtype)
    x = jax.random.normal(ks[1], (B, S, DI), dtype)
    Bc = jax.random.normal(ks[2], (B, S, N), dtype)
    Cc = jax.random.normal(ks[3], (B, S, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (DI, N)) * 0.2)
    D = jax.random.normal(ks[5], (DI,))
    y = mamba_scan(dt, x, Bc, Cc, A, D, chunk=chunk, block_d=bd)
    ref = R.mamba_scan_ref(dt, x, Bc, Cc, A, D)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **tol)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,D,F,E,bt", [
    (512, 128, 256, 4, 64),
    (256, 64, 128, 8, 32),
])
def test_grouped_matmul_sweep(T, D, F, E, bt, dtype, rng):
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (T, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    bmap = jax.random.randint(ks[2], (T // bt,), 0, E).astype(jnp.int32)
    y = grouped_matmul(x, w, bmap, block_t=bt)
    ref = R.grouped_matmul_ref(x, w, bmap, bt)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_kernel_is_tiled():
    """BlockSpec tiling: odd block sizes halve down to divide S."""
    q = jnp.zeros((1, 96, 2, 32), jnp.float32)
    k = jnp.zeros((1, 96, 1, 32), jnp.float32)
    out = flash_attention(q, k, k, causal=True, block_q=64, block_k=64)
    assert out.shape == (1, 96, 2, 32)

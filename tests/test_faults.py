"""Chaos-layer regression suite (ISSUE 6).

Four contracts:

1. **Failure-path bugfixes** — in-flight requests on a crashed worker
   fail with ``error="worker died"`` (never complete ok), ``idle_check``
   always republishes the routing view, a winning hedge clone resolves
   the primary's telemetry row, and ``LatencyEstimator.p95`` is exact
   nearest-rank.
2. **Faults-off byte identity** — a wired-but-disabled chaos layer
   (``FaultConfig()`` attached, ``zones=`` set) reproduces the PR 3–5
   golden result digests and decision logs byte-for-byte.
3. **Seeded chaos determinism** — same seed ⇒ byte-identical fault
   log, result stream, and decision log under injected faults.
4. **The zone-outage A/B** — ``spread_zones`` placement + a retry
   budget of 2 meets per-function SLO attainment that the zone-blind
   ``spread`` + no-retry configuration misses on the same seeded
   outage.
"""
import math

import pytest

from repro.autoscale import Autoscaler, build_pool
from repro.autoscale.metrics import LatencyEstimator
from repro.core.config_store import ConfigStore
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.placement import get_placer
from repro.core.router import build_leaf, build_tree
from repro.core.simulator import (RETRYABLE_ERRORS, Simulator,
                                  SyntheticServiceModel)
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario, install_demo_configs

from _prop_drivers import digest_sim as _digest

# --------------------------------------------------------------- fixtures


def _store(**over):
    s = ConfigStore()
    s.put(FunctionConfig(**{**dict(name="fn", arch="tiny_lm", concurrency=1,
                                   cold_start_s=0.05, idle_timeout_s=5.0,
                                   timeout_s=8.0), **over}))
    return s


def _one_worker_sim(store=None, **sim_kw):
    return Simulator(build_leaf("b", ["w0"], "least_loaded"),
                     store or _store(), SyntheticServiceModel(seed=2),
                     seed=5, **sim_kw)


# ---------------------------------------- bugfix 1: in-flight crash path
def test_inflight_request_on_crashed_worker_fails():
    """A request in service when its worker dies must fail with
    ``worker died`` — the seed recorded it as a successful completion."""
    sim = _one_worker_sim()
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w0", at=0.02, recover_after=100.0)
    res = sim.run()
    assert len(res) == 1
    assert res[0].ok is False
    assert res[0].error == "worker died"


def test_crash_fails_queued_and_inflight_work_distinctly():
    """Queued work drains at crash time; in-flight work dies when its
    (now orphaned) finish event fires. Both must fail."""
    sim = _one_worker_sim()            # concurrency 1: rid 1 queues
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.submit(Request(fn="fn", arrival_t=0.01, rid=1))
    sim.inject_failure("w0", at=0.03, recover_after=100.0)
    res = sim.run()
    assert sorted(r.rid for r in res) == [0, 1]
    assert all(not r.ok and r.error == "worker died" for r in res)


def test_crash_spares_other_workers_inflight():
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"),
                    _store(), SyntheticServiceModel(seed=2), seed=5)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))     # -> w0
    sim.submit(Request(fn="fn", arrival_t=0.001, rid=1))   # -> w1
    sim.inject_failure("w1", at=0.02, recover_after=100.0)
    res = {r.rid: r for r in sim.run()}
    died = [r for r in res.values() if not r.ok]
    lived = [r for r in res.values() if r.ok]
    assert len(died) == 1 and died[0].error == "worker died"
    assert len(lived) == 1 and lived[0].latency > 0.0


# ------------------------------------------- bugfix 2: idle_check view
def test_idle_check_republishes_routing_view():
    """Reaping an idle replica must refresh the routing view even when
    the worker also has queued work (the dispatch path used to swallow
    the refresh on unhealthy workers)."""
    sim = _one_worker_sim(_store(idle_timeout_s=0.1))
    sim.prewarm("w0", "fn")
    seen = []
    orig = sim._refresh_view

    def spy(w):
        seen.append((sim.now, w.name, w.total_instances))
        return orig(w)
    sim._refresh_view = spy
    sim.run()
    # the reap at t=0.1 republished: last view refresh shows 0 instances
    assert seen and seen[-1][2] == 0
    assert sim.view.get("w0", sim.now).warm_fns == frozenset()


# -------------------------------------- bugfix 3: hedge-win telemetry
def test_hedge_clone_win_resolves_primary_telemetry():
    """When a hedge clone wins the race the primary's telemetry row must
    carry the end-to-end latency/outcome — the seed left it at the
    placeholder ``latency=0.0, ok=True``."""
    store = _store(concurrency=1, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    hedge_after_s=0.02)
    sim.set_straggler("w0", 50.0)      # primary's worker is pathological
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    res = sim.run()
    assert len(res) == 1 and res[0].ok
    # the clone must actually have won (finished on the fast worker)
    assert res[0].worker == "w1"
    prim_rows = [t for t in sim.telemetry if t.fn == "fn"]
    assert len(prim_rows) == 2         # primary + clone
    # primary row (arrival order: index 0) resolved to the *end-to-end*
    # latency from the primary's arrival at t=0 (the result row keeps
    # the winning clone's shorter arrival->finish span)
    assert prim_rows[0].latency == pytest.approx(res[0].finish_t)
    assert prim_rows[0].ok is True
    assert prim_rows[0].latency >= res[0].latency > 0.0


# ----------------------- ISSUE-7 failure-path telemetry / retry bugfixes
def test_failed_request_resolves_telemetry_row():
    """A request that fails (worker died in-flight) must resolve its
    telemetry row to ``ok=False`` with the end-to-end latency — the
    failure path used to leave the placeholder ``latency=0.0, ok=True``,
    poisoning the RQ-B training set with instant successes."""
    sim = _one_worker_sim()
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w0", at=0.02, recover_after=100.0)
    res = sim.run()
    assert len(res) == 1 and not res[0].ok
    rows = [t for t in sim.telemetry if t.fn == "fn"]
    assert len(rows) == 1
    assert rows[0].ok is False
    assert rows[0].latency == pytest.approx(res[0].finish_t
                                            - res[0].arrival_t)
    # the sweep the ISSUE pins: no row anywhere ends at the placeholder
    assert not any(t.latency == 0.0 and t.ok for t in sim.telemetry)


def test_retry_after_dark_fleet_at_arrival_recovers():
    """Arrival while *no* worker is healthy fails before routing, so no
    telemetry row exists; when the retry budget resurrects the request
    after the fleet recovers, the completion used to dereference the
    missing row index and crash. The retry must just succeed."""
    sim = _one_worker_sim(retry_budget=2, retry_backoff_s=0.5)
    sim.inject_failure("w0", at=0.0, recover_after=0.2)
    sim.submit(Request(fn="fn", arrival_t=0.05, rid=0))   # fleet dark
    res = sim.run()
    assert len(res) == 1 and res[0].ok
    assert sim.retries_scheduled == 1
    # recovery (t=0.2) beat the backoff expiry (t=0.55): served warm path
    assert res[0].finish_t > 0.55
    assert not any(t.latency == 0.0 and t.ok for t in sim.telemetry)


def test_hedge_clone_rids_deterministic_across_runs():
    """Hedge clones derive their rid from the primary (``-rid - 1``),
    not the process-global id counter — two same-seed runs in one
    process must produce byte-identical routing logs (the counter kept
    advancing across runs, renaming every clone in the second run)."""
    def run():
        store = _store(concurrency=1, cold_start_s=0.0)
        sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"),
                        store, SyntheticServiceModel(seed=2), seed=5,
                        hedge_after_s=0.02, record_decisions=True)
        sim.set_straggler("w0", 50.0)
        for i in range(4):      # explicit rids, as the workload layer uses
            sim.submit(Request(fn="fn", arrival_t=0.01 * i, rid=i))
        sim.run()
        return sim
    a, b = run(), run()
    assert a.hedges_seen > 0
    log_a, log_b = a.routing_log(), b.routing_log()
    assert "rid=-" in log_a            # clones route under derived ids
    assert log_a == log_b


def test_hedge_clones_not_counted_as_arrivals():
    """Hedge clones are the platform's own speculation, not offered
    load: they must land in ``hedges_seen``, never in ``arrivals_seen``
    / ``arrivals_by_fn`` — counting them fed the autoscaler synthetic
    demand that grew with its own hedging."""
    store = _store(concurrency=1, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    hedge_after_s=0.02)
    sim.set_straggler("w0", 50.0)
    n = 6
    for i in range(n):
        sim.submit(Request(fn="fn", arrival_t=0.01 * i))
    sim.run()
    assert sim.hedges_seen > 0
    assert sim.arrivals_seen == n
    assert sum(sim.arrivals_by_fn.values()) == n


# -------------------------------------------- bugfix 4: p95 nearest-rank
def test_latency_estimator_p95_nearest_rank():
    est = LatencyEstimator(maxlen=200)
    for v in range(1, 101):
        est.observe("fn", float(v))
    # nearest-rank: ceil(0.95 * 100) = 95th order statistic
    assert est.p95("fn") == 95.0
    est2 = LatencyEstimator()
    est2.observe("g", 7.0)
    assert est2.p95("g") == 7.0        # n=1: the only sample, not IndexError
    est3 = LatencyEstimator()
    for v in range(1, 21):
        est3.observe("h", float(v))
    assert est3.p95("h") == float(math.ceil(0.95 * 20))   # 19.0


# ------------------------------------------------- faults-off byte identity
# golden digests from tests/test_scheduling.py: the chaos layer wired in
# but disabled (default FaultConfig + zones assigned) must not move a byte
GOLDEN_OFF = {
    "steady": ("90ac57f36c579d36",
               dict(scenario="steady", rps=300.0, duration_s=8.0, seed=3)),
    "multi_tenant": ("ec5034f85267151c",
                     dict(scenario="multi_tenant", rps=400.0,
                          duration_s=8.0, seed=3)),
}


@pytest.mark.parametrize("case", sorted(GOLDEN_OFF))
def test_faults_off_byte_identity(case):
    digest, kw = GOLDEN_OFF[case]
    kw = dict(kw)
    wl = build_scenario(kw.pop("scenario"), **kw)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(8, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    zones=2, faults=FaultConfig())
    assert sim.faults is not None and not sim.faults.cfg.enabled
    sim.load(wl)
    sim.run()
    assert _digest(sim) == digest
    assert sim.fault_log() == ""


def test_faults_off_decision_log_identical():
    """Wired-but-disabled chaos + zones: the autoscaler decision log is
    byte-identical to a fault-free run (partition-aware metrics see zero
    unhealthy workers and change nothing)."""
    def run(**extra):
        wl = build_scenario("flash_crowd", duration_s=12.0, seed=3,
                            base_rps=12.0, burst_rps=800.0,
                            mean_burst_s=2.0, mean_calm_s=6.0)
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(build_pool(1, 2), store,
                        SyntheticServiceModel(seed=2), seed=7,
                        worker_capacity_slots=1, **extra)
        scaler = Autoscaler("reactive", interval_s=0.25, window_s=2.0,
                            min_replicas=1, max_replicas=8,
                            workers_per_replica=2, cooldown_s=2.0)
        sim.attach_autoscaler(scaler)
        sim.load(wl)
        sim.run()
        return _digest(sim), scaler.decision_log()
    base = run()
    wired = run(zones=2, faults=FaultConfig())
    assert wired == base


# ------------------------------------------------------ zones plumbing
def test_zone_assignment_per_leaf_branch():
    sim = Simulator(build_pool(3, 2), _store(), SyntheticServiceModel(seed=2),
                    seed=5, zones=2)
    zs = {n: w.zone for n, w in sim.workers.items()}
    # leaf branches are failure domains, round-robin across zones
    assert zs["pool-b0-w0"] == zs["pool-b0-w1"] == "z0"
    assert zs["pool-b1-w0"] == zs["pool-b1-w1"] == "z1"
    assert zs["pool-b2-w0"] == "z0"
    assert set(sim.zone_workers) == {"z0", "z1"}
    assert sorted(sim.zone_workers["z1"]) == ["pool-b1-w0", "pool-b1-w1"]


def test_zone_assignment_explicit_mapping():
    sim = Simulator(build_pool(2, 1), _store(), SyntheticServiceModel(seed=2),
                    seed=5, zones={"pool-b0": "east", "pool-b1": "west"})
    assert sim.workers["pool-b0-w0"].zone == "east"
    assert sim.workers["pool-b1-w0"].zone == "west"


# --------------------------------------------------- spread_zones placer
class _FakeWorker:
    def __init__(self, name, zone, reps=0, free=1000.0):
        self.name, self.zone = name, zone
        self._reps, self._free = reps, free
        self.total_instances = reps

    def fits(self, mem):
        return self._free >= mem

    def mem_free_mb(self):
        return self._free

    def fn_replicas(self, fn):
        return self._reps


def test_spread_zones_balances_across_zones():
    p = get_placer("spread_zones")
    ws = [_FakeWorker("a0", "z0", reps=1), _FakeWorker("a1", "z0"),
          _FakeWorker("b0", "z1"), _FakeWorker("b1", "z1")]
    order = p.place_order("fn", 100.0, ws)
    assert order[0].zone == "z1"       # grow the empty zone first
    reap = p.reap_order("fn", ws)
    assert reap[0].zone == "z0"        # shrink the loaded zone first


def test_spread_zones_counts_memory_full_workers():
    """Regression: a memory-full worker's replicas still anchor its
    zone's load — dropping it from the count piled every replica into
    one zone (and made spread_zones behave exactly like spread)."""
    p = get_placer("spread_zones")
    ws = [_FakeWorker("a0", "z0", reps=1, free=0.0),   # full, holds the fn
          _FakeWorker("a1", "z0"),
          _FakeWorker("b0", "z1")]
    order = p.place_order("fn", 100.0, ws)
    assert [w.name for w in order] == ["b0", "a1"]


def test_spread_zones_degenerates_without_zones():
    spread, zoned = get_placer("spread"), get_placer("spread_zones")
    ws = [_FakeWorker(f"w{i}", None, reps=i % 2) for i in range(4)]
    assert ([w.name for w in zoned.place_order("fn", 1.0, ws)]
            == [w.name for w in spread.place_order("fn", 1.0, ws)])


# ------------------------------------------------------ fault processes
def test_scheduled_zone_outage_fails_and_recovers():
    store = _store(concurrency=4, timeout_s=1.0)
    sim = Simulator(build_pool(2, 1), store, SyntheticServiceModel(seed=2),
                    seed=5, zones=2,
                    faults=FaultConfig(scheduled=((0.5, "z0", 1.0),)))
    wl = build_scenario("steady", rps=100.0, duration_s=3.0, seed=1)
    sim.load(wl)
    res = sim.run()
    st = sim.faults.stats
    assert st.zone_outages == 1 and st.zone_recoveries == 1
    assert sim.workers["pool-b0-w0"].healthy       # recovered by end
    # recover-then-dispatch: traffic lands on the healed zone again
    post = [r for r in res if r.ok and r.worker == "pool-b0-w0"
            and r.finish_t > 1.5]
    assert post
    lines = sim.fault_log().splitlines()
    assert lines[0].startswith("t=0.500000 zone_down zone=z0 workers=1")
    assert any(line.endswith("zone_up zone=z0") for line in lines)


def test_worker_crash_restart_chain():
    sim = _one_worker_sim(_store(concurrency=8),
                          faults=FaultConfig(seed=3, worker_mttf_s=0.5,
                                             worker_mttr_s=0.2))
    wl = build_scenario("steady", rps=150.0, duration_s=4.0, seed=1)
    sim.load(wl)
    res = sim.run()                     # must terminate (faults re-arm
    st = sim.faults.stats               # only while real work remains)
    assert st.crashes >= 2
    assert st.restores >= 1
    assert any(not r.ok and r.error == "worker died" for r in res)


def test_straggler_episode_layers_and_restores():
    store = _store(concurrency=8)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    faults=FaultConfig(seed=1, straggler_rate=2.0,
                                       straggler_factor=4.0,
                                       straggler_duration_s=0.2,
                                       horizon_s=2.0))
    sim.workers["w0"].slowdown = 2.0    # configured base straggler
    wl = build_scenario("steady", rps=100.0, duration_s=4.0, seed=1)
    sim.load(wl)
    sim.run()
    assert sim.faults.stats.stragglers >= 1
    # the horizon stops new episodes at t=2 while traffic runs to t=4,
    # so every episode ended mid-run: slowdowns restored to base values
    # (a transient on w0 layered multiplicatively on its base 2.0)
    assert sim.workers["w0"].slowdown == 2.0
    assert sim.workers["w1"].slowdown == 1.0
    log = sim.fault_log()
    assert "straggle" in log and "unstraggle" in log


def test_lost_completion_times_out_then_frees_slot():
    """A dropped finish leaves a zombie slot until ``timeout_s``; the
    request fails as ``lost completion`` and the freed slot then serves
    the backlog."""
    store = _store(concurrency=1, timeout_s=0.5, cold_start_s=0.0)
    sim = _one_worker_sim(store, faults=FaultConfig(seed=1, lost_finish_p=1.0,
                                                    horizon_s=0.05))
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    res = sim.run()
    assert len(res) == 1
    assert not res[0].ok and res[0].error == "lost completion"
    assert res[0].finish_t == pytest.approx(0.5, abs=0.05)
    assert sim.faults.stats.lost_completions == 1
    assert sim.workers["w0"].inflight() == 0       # zombie slot freed


# ------------------------------------------------------- retry budget
def test_retry_rescues_worker_died():
    store = _store(concurrency=1, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5, retry_budget=2)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w0", at=0.01, recover_after=100.0)
    res = sim.run()
    assert len(res) == 1
    assert res[0].ok                   # resurrected on the survivor
    assert res[0].worker == "w1"
    assert sim.retries_scheduled == 1


def test_retry_budget_exhausts():
    sim = _one_worker_sim(retry_budget=2, retry_backoff_s=0.01,
                          retry_backoff_cap_s=0.02)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w0", at=0.01, recover_after=100.0)
    res = sim.run()
    assert len(res) == 1 and not res[0].ok
    # first failure is "worker died"; both retries then find no healthy
    # workers and the budget runs out
    assert res[0].error in RETRYABLE_ERRORS
    assert sim.retries_scheduled == 2


def test_queue_timeout_is_not_retryable():
    assert "queue timeout" not in RETRYABLE_ERRORS
    store = _store(concurrency=1, timeout_s=0.02, cold_start_s=0.5)
    sim = _one_worker_sim(store, retry_budget=3)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.submit(Request(fn="fn", arrival_t=0.001, rid=1))
    res = sim.run()
    timed_out = [r for r in res if not r.ok and r.error == "queue timeout"]
    assert timed_out                   # the per-request deadline fired
    assert sim.retries_scheduled == 0  # and was never double-spent


def test_retry_storm_guard_sheds_excess():
    """2 of 3 zones die under heavy load: concurrently pending retries
    stay capped and the excess is shed, not re-offered."""
    wl = build_scenario("retry_storm", seed=3, rps=1500.0)
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(name=p.fn, arch="tiny_lm", concurrency=4,
                                 cold_start_s=1.0, timeout_s=8.0))
    sim = Simulator(build_pool(3, 2, leaf_policy="warm_least_loaded",
                               inner_policy="deadline_aware"),
                    store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                    seed=7, zones=3, placer="spread_zones",
                    worker_memory_mb=600, cold_start_default_s=1.0,
                    retry_budget=3, retry_storm_cap=32)
    for p in wl.profiles:
        for _ in range(3):
            sim.place_prewarm(p.fn)
    sim.load(wl)
    sim.run()
    assert sim.faults.stats.zone_outages == 2
    assert sim.retries_shed > 0
    assert sim.retries_scheduled <= 32 * 3   # cap x budget bounds total


def test_hedge_clones_do_not_retry():
    store = _store(concurrency=1, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5, retry_budget=3,
                    hedge_after_s=0.01)
    sim.set_straggler("w0", 50.0)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w1", at=0.02, recover_after=100.0)  # kill the clone
    res = sim.run()
    assert len(res) == 1
    # the primary's own (slow) path still completes; the dead clone must
    # not have consumed retry budget
    assert sim.retries_scheduled == 0


# -------------------------------------------- partition-aware autoscaler
class _ShrinkPolicy:
    name = "shrink"
    interval_s = 0.5

    def desired_replicas(self, window, current):
        return 1

    def fn_actions(self, window):
        return {}


def test_autoscaler_holds_scale_down_during_outage():
    sim = Simulator(build_pool(3, 1), _store(), SyntheticServiceModel(seed=2),
                    seed=5)
    scaler = Autoscaler(_ShrinkPolicy(), min_replicas=1, max_replicas=8,
                        cooldown_s=0.0)
    sim.control.autoscaler = scaler
    sim._on_fail("pool-b1-w0")
    d = scaler.on_tick(sim)
    assert d.action == "outage_hold"
    assert d.applied == 3              # fleet untouched
    assert d.workers == 2              # healthy only
    sim._on_recover("pool-b1-w0")
    d2 = scaler.on_tick(sim)
    # hold releases once the fleet heals (floor: this loop never added
    # the branches it would shrink)
    assert d2.action == "floor"
    assert d2.workers == 3


# ------------------------------------------------- seeded determinism
def _outage_ab_sim(placer, retry_budget):
    """The locked acceptance shape: memory-capped one-replica workers,
    deadline-aware root, two zones, pre-warmed steady state, scripted
    z0 outage (mirrors benchmarks/run.py bench_fault_scenarios)."""
    wl = build_scenario("zone_outage", seed=3)
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(name=p.fn, arch="tiny_lm", concurrency=4,
                                 cold_start_s=1.0, timeout_s=8.0))
    sim = Simulator(build_pool(2, 4, leaf_policy="warm_least_loaded",
                               inner_policy="deadline_aware"),
                    store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                    seed=7, zones=2, placer=placer, worker_memory_mb=600,
                    cold_start_default_s=1.0, retry_budget=retry_budget)
    for p in wl.profiles:
        for _ in range(4):
            sim.place_prewarm(p.fn)
    sim.load(wl)
    sim.run()
    return sim, wl


def _attainment(sim, wl):
    out = {}
    for fn, slo in sorted(wl.slo_targets().items()):
        rows = [r for r in sim.results if r.fn == fn]
        out[fn] = sum(1 for r in rows
                      if r.ok and r.latency <= slo) / len(rows)
    return out


def test_same_seed_byte_identical_fault_and_decision_logs():
    a, _ = _outage_ab_sim("spread_zones", 2)
    b, _ = _outage_ab_sim("spread_zones", 2)
    assert a.fault_log() == b.fault_log()
    assert a.fault_log()                       # non-empty: faults fired
    assert _digest(a) == _digest(b)
    assert a.retries_scheduled == b.retries_scheduled


# --------------------------------------------- acceptance: the chaos A/B
def test_zone_outage_ab_spread_zones_with_retries_meets_slo():
    """The PR's headline experiment: on the same seeded z0 outage,
    failure-domain-aware placement + a retry budget of 2 keeps every
    function's SLO attainment >= 95%, while zone-blind ``spread`` with
    no retries strands one function's entire warm capacity in the dead
    zone and misses by a wide margin."""
    good, wl = _outage_ab_sim("spread_zones", 2)
    blind, _ = _outage_ab_sim("spread", 0)
    att_good, att_blind = _attainment(good, wl), _attainment(blind, wl)

    assert all(v >= 0.95 for v in att_good.values()), att_good
    assert min(att_blind.values()) < 0.80, att_blind
    # the retry budget actually fired and reduced hard failures
    assert good.retries_scheduled > 0
    n_fail = lambda s: sum(1 for r in s.results if not r.ok)  # noqa: E731
    assert n_fail(good) < n_fail(blind)


def test_zone_outage_retry_budget_cuts_failures():
    with_retry, _ = _outage_ab_sim("spread_zones", 2)
    no_retry, _ = _outage_ab_sim("spread_zones", 0)
    fails = lambda s: sum(1 for r in s.results if not r.ok)  # noqa: E731
    assert fails(with_retry) < fails(no_retry)
    assert with_retry.retries_scheduled > 0


# --------------------------------------------------- workload plumbing
def test_scenarios_carry_fault_plans():
    wl = build_scenario("zone_outage", seed=9, outage_at=1.0,
                        outage_zone="z1", outage_duration_s=2.0)
    assert isinstance(wl.faults, FaultConfig)
    assert wl.faults.scheduled == ((1.0, "z1", 2.0),)
    storm = build_scenario("retry_storm", seed=9)
    assert len(storm.faults.scheduled) == 2


def test_load_attaches_workload_fault_plan_once():
    sim = Simulator(build_pool(2, 1), _store(), SyntheticServiceModel(seed=2),
                    seed=5, zones=2)
    wl = build_scenario("zone_outage", seed=1)
    sim.load(wl)
    assert isinstance(sim.faults, FaultInjector)
    assert sim.faults.cfg is wl.faults
    # an explicitly attached injector is not overwritten by load()
    sim2 = Simulator(build_pool(2, 1), _store(), SyntheticServiceModel(seed=2),
                     seed=5, zones=2, faults=FaultConfig(seed=42))
    inj = sim2.faults
    sim2.load(build_scenario("zone_outage", seed=1))
    assert sim2.faults is inj


def test_default_fault_config_is_disabled():
    assert not FaultConfig().enabled
    assert FaultConfig(scheduled=((1.0, "z0", 1.0),)).enabled
    assert FaultConfig(worker_mttf_s=10.0).enabled
    assert FaultConfig(lost_finish_p=0.1).enabled


# ----------------------- ISSUE-9 satellite: stale-route re-roll + retries
def test_stale_route_reroll_scores_with_leaf_policy():
    """Satellite regression: a request routed to a worker that turned
    unhealthy must be re-scored by the owning leaf's *policy* (and the
    hop logged as ``arrival_reroll``) — the old path re-rolled with a
    uniform ``rng.choice`` that bypassed both. Round-robin distinguishes
    the two: the policy walks the healthy list deterministically."""
    store = _store(concurrency=4, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1", "w2"], "round_robin"),
                    store, SyntheticServiceModel(seed=2), seed=5,
                    record_decisions=True)
    sim.inject_failure("w1", at=0.0, recover_after=100.0)
    sim.tree.route = lambda req, view, rng, t: ("w1", 1)   # stale pick
    for i in range(4):
        sim.submit(Request(fn="fn", arrival_t=0.01 + 0.001 * i, rid=i))
    res = sim.run()
    assert all(r.ok for r in res)
    # the leaf's round-robin cycles w0, w2, w0, w2 over the healthy
    # list; a uniform re-roll would not alternate strictly
    assert [r.worker for r in sorted(res, key=lambda r: r.rid)] == \
        ["w0", "w2", "w0", "w2"]
    rerolls = [ln for ln in sim.routing_log().splitlines()
               if "arrival_reroll" in ln]
    assert len(rerolls) == 4           # the decision log saw every hop
    assert "worker=w0" in rerolls[0] and "worker=w2" in rerolls[1]


def test_retry_reroll_goes_through_policy_and_log():
    store = _store(concurrency=1, cold_start_s=0.0)
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5, retry_budget=2,
                    retry_backoff_s=0.05, record_decisions=True)
    sim.tree.route = lambda req, view, rng, t: ("w0", 1)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    sim.inject_failure("w0", at=0.01, recover_after=100.0)
    res = sim.run()
    assert len(res) == 1 and res[0].ok and res[0].worker == "w1"
    assert sim.retries_scheduled == 1
    assert "retry_reroll rid=0" in sim.routing_log()


def test_retry_storm_accounting_reconciles():
    """Satellite invariants: every retry-eligible failure is either
    scheduled or shed (never silently lost), and the pending counter
    drains to zero by end of run."""
    wl = build_scenario("retry_storm", seed=3, rps=1500.0)
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(name=p.fn, arch="tiny_lm", concurrency=4,
                                 cold_start_s=1.0, timeout_s=8.0))
    sim = Simulator(build_pool(3, 2, leaf_policy="warm_least_loaded",
                               inner_policy="deadline_aware"),
                    store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                    seed=7, zones=3, placer="spread_zones",
                    worker_memory_mb=600, cold_start_default_s=1.0,
                    retry_budget=3, retry_storm_cap=32)
    offers = {"n": 0}
    orig = sim._record_fail

    def counting(req, err):
        if (err in RETRYABLE_ERRORS and req.hedged_from is None
                and getattr(req, "_retries", 0) < sim.retry_budget):
            offers["n"] += 1
        return orig(req, err)
    sim._record_fail = counting
    for p in wl.profiles:
        for _ in range(3):
            sim.place_prewarm(p.fn)
    sim.load(wl)
    sim.run()
    assert sim._retries_pending == 0
    assert sim.retries_shed > 0
    assert sim.retries_scheduled + sim.retries_shed == offers["n"]


def test_retry_dropped_when_hedge_settles_first():
    """A pending retry whose primary meanwhile finished via a hedge
    clone is dropped (not re-offered) and now counted in
    ``retries_dropped`` — the drop used to be invisible, making
    scheduled/settled reconciliation impossible."""
    store = _store(concurrency=1, cold_start_s=0.0,
                   max_instances_per_worker=1)    # rid 0 must *queue*
    sim = Simulator(build_leaf("b", ["w0", "w1"], "round_robin"), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    hedge_after_s=0.03, retry_budget=2,
                    retry_backoff_s=5.0)
    sim.set_straggler("w0", 1000.0)
    # primaries pin to the straggler; hedge clones (rid < 0) to w1
    sim.tree.route = lambda req, view, rng, t: \
        (("w1", 1) if req.rid < 0 else ("w0", 1))
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=99))    # occupies w0
    sim.submit(Request(fn="fn", arrival_t=0.001, rid=0))   # queued on w0
    sim.inject_failure("w0", at=0.032, recover_after=100.0)
    res = sim.run()
    # the crash drains rid 0 from w0's queue -> "worker died" -> a retry
    # is scheduled; its hedge clone then wins on w1, so the backoff
    # expiry must drop the retry (and count it), not re-offer it
    assert sorted(r.rid for r in res) == [0, 99]
    assert all(r.ok and r.worker == "w1" for r in res)
    assert sim.retries_scheduled == 1
    assert sim.retries_dropped == 1
    assert sim._retries_pending == 0

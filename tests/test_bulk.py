"""ISSUE-8 bulk generation fast path: the RequestBatch contracts.

``MixedWorkload.generate_bulk`` draws from numpy ``Generator`` streams,
so it cannot reproduce the scalar Mersenne stream byte for byte — it
carries its *own* determinism contract instead, pinned here:

- goldens: same seed ⇒ byte-identical ``RequestBatch`` (sha256 column
  digests, one per arrival-process kind — these change only if the bulk
  sampling algorithms change, which is a contract break to be made
  deliberately);
- distribution equivalence: bulk matches the scalar path on arrival
  counts, mix shares, size-distribution means, and deadline mapping
  (trace replay is verbatim, so there it matches *exactly*);
- structure: ascending in-range arrivals, contiguous rids, NaN⇔None
  deadline mapping through ``to_requests``, lossless chunk iteration.

The scalar path's goldens live in test_workloads.py and are untouched.
"""
import math

import numpy as np
import pytest

from repro.workloads import (ArrivalProcess, BurstyArrivals, DiurnalArrivals,
                             FunctionProfile, MixedWorkload, PoissonArrivals,
                             RequestBatch, SizeDist, TraceArrivals)

PROFILES = [
    FunctionProfile("interactive", weight=3.0,
                    size=SizeDist.lognormal(24, 0.6), slo_p95_s=0.5),
    FunctionProfile("batch", weight=1.0, size=SizeDist.uniform(64, 512)),
    FunctionProfile("ping", weight=1.0, size=SizeDist.const(4)),
]

ARRIVAL_CASES = {
    "poisson": PoissonArrivals(120.0),
    "bursty": BurstyArrivals(rate_on=300.0, rate_off=40.0,
                             mean_on_s=1.0, mean_off_s=3.0),
    "diurnal": DiurnalArrivals(120.0, amplitude=0.8, period_s=60.0),
    "trace": TraceArrivals([0.008] * 997, loop=True),
}

DUR = 60.0

# same seed => byte-identical batch; changing these is a deliberate
# break of the bulk determinism contract (record it in CHANGES.md)
GOLDEN_DIGESTS = {
    "poisson": "910cc244c7b3ee1a",
    "bursty": "88a4a79b67e6bd68",
    "diurnal": "7c4490d74cf6a874",
    "trace": "5cd86db882492a20",
}


def _wl(kind, seed=11, profiles=PROFILES):
    return MixedWorkload(ARRIVAL_CASES[kind], profiles,
                         duration_s=DUR, seed=seed)


# ------------------------------------------------------------------ goldens
@pytest.mark.parametrize("kind", sorted(ARRIVAL_CASES))
def test_generate_bulk_matches_golden_digest(kind):
    assert _wl(kind).generate_bulk().digest() == GOLDEN_DIGESTS[kind]


@pytest.mark.parametrize("kind", sorted(ARRIVAL_CASES))
def test_generate_bulk_run_twice_byte_identical(kind):
    a, b = _wl(kind).generate_bulk(), _wl(kind).generate_bulk()
    assert a.digest() == b.digest()
    for col in ("arrival_t", "fn_idx", "size", "rid", "deadline_t"):
        np.testing.assert_array_equal(getattr(a, col), getattr(b, col))
    assert _wl(kind, seed=12).generate_bulk().digest() != a.digest()


# ---------------------------------------------------------------- structure
@pytest.mark.parametrize("kind", sorted(ARRIVAL_CASES))
def test_generate_bulk_batch_structure(kind):
    batch = _wl(kind).generate_bulk()
    t = batch.arrival_t
    assert len(batch) == len(t) > 0
    assert np.all(t[:-1] <= t[1:])
    assert t[0] >= 0.0 and t[-1] < DUR
    np.testing.assert_array_equal(
        batch.rid, np.arange(len(batch), dtype=np.int64))
    assert batch.fns == ("interactive", "batch", "ping")
    assert batch.fn_idx.min() >= 0 and batch.fn_idx.max() <= 2
    # deadlines: slo-bearing fns get arrival + slo, others NaN
    has_slo = batch.fn_idx == 0
    np.testing.assert_allclose(batch.deadline_t[has_slo], t[has_slo] + 0.5)
    assert np.isnan(batch.deadline_t[~has_slo]).all()


def test_generate_bulk_rid_base_offsets_and_none_raises():
    wl = MixedWorkload(PoissonArrivals(50.0), PROFILES, duration_s=10.0,
                       seed=3, rid_base=1000)
    batch = wl.generate_bulk()
    assert batch.rid[0] == 1000
    np.testing.assert_array_equal(
        batch.rid, np.arange(1000, 1000 + len(batch)))
    wl_none = MixedWorkload(PoissonArrivals(50.0), PROFILES, duration_s=10.0,
                            seed=3, rid_base=None)
    with pytest.raises(ValueError):
        wl_none.generate_bulk()


def test_to_requests_round_trips_columns():
    batch = _wl("poisson").generate_bulk()
    reqs = batch.to_requests()
    assert len(reqs) == len(batch)
    for i in (0, len(reqs) // 2, -1):
        r = reqs[i]
        assert r.fn == batch.fns[batch.fn_idx[i]]
        assert r.arrival_t == batch.arrival_t[i]
        assert r.size == batch.size[i]
        assert r.rid == batch.rid[i]
        dl = batch.deadline_t[i]
        assert r.deadline_t == (None if math.isnan(dl) else dl)
    # NaN deadline really maps to None somewhere in the stream
    assert any(r.deadline_t is None for r in reqs)
    assert any(r.deadline_t is not None for r in reqs)


def test_iter_chunks_covers_batch_losslessly():
    batch = _wl("poisson").generate_bulk()
    chunks = list(batch.iter_chunks(257))
    assert sum(len(c) for c in chunks) == len(batch)
    assert all(len(c) <= 257 for c in chunks)
    np.testing.assert_array_equal(
        np.concatenate([c.arrival_t for c in chunks]), batch.arrival_t)
    np.testing.assert_array_equal(
        np.concatenate([c.rid for c in chunks]), batch.rid)
    # chunk boundaries preserve request identity end to end
    tail = chunks[-1].to_requests()[-1]
    assert tail.rid == batch.rid[-1]


def test_base_times_array_raises_with_guidance():
    with pytest.raises(NotImplementedError):
        ArrivalProcess().times_array(1.0, np.random.default_rng(0))


# --------------------------------------------------- scalar <-> bulk shape
@pytest.mark.parametrize("kind", sorted(ARRIVAL_CASES))
def test_bulk_matches_scalar_distribution(kind):
    """The bulk path must match the scalar path *in distribution*: same
    arrival volume (looser for bursty: dwell realizations differ between
    the two RNG streams), same mix shares, same size means, same
    deadline mapping. Trace replay consumes no RNG, so counts match
    exactly there."""
    wl = _wl(kind)
    scalar = list(wl.requests())
    batch = wl.generate_bulk()
    n_s, n_b = len(scalar), len(batch)
    if kind == "trace":
        assert n_b == n_s
        np.testing.assert_allclose(
            batch.arrival_t, [r.arrival_t for r in scalar], atol=1e-9)
    else:
        tol = 0.35 if kind == "bursty" else 0.10
        assert abs(n_b - n_s) <= tol * n_s, (kind, n_s, n_b)
    # mix shares within 5 points of the declared weights
    w = np.asarray([p.weight for p in PROFILES])
    want = w / w.sum()
    got = np.bincount(batch.fn_idx, minlength=3) / n_b
    assert np.abs(got - want).max() < 0.05, (kind, got)
    # per-fn size means within 15% of the scalar sample's
    for i, p in enumerate(PROFILES):
        bulk_sizes = batch.size[batch.fn_idx == i]
        scal_sizes = [r.size for r in scalar if r.fn == p.fn]
        assert len(bulk_sizes) and len(scal_sizes)
        mb, ms = float(np.mean(bulk_sizes)), float(np.mean(scal_sizes))
        assert abs(mb - ms) <= 0.15 * ms, (kind, p.fn, mb, ms)


def test_poisson_bulk_iat_mean_matches_rate():
    t = PoissonArrivals(200.0).times_array(50.0, np.random.default_rng(5))
    iats = np.diff(t)
    assert abs(float(iats.mean()) - 1.0 / 200.0) < 0.10 * (1.0 / 200.0)


def test_trace_times_array_replays_verbatim_and_tiles():
    import random
    tr = TraceArrivals([0.5, 0.25, 0.25])
    # non-loop: verbatim cumsum, horizon-filtered
    np.testing.assert_allclose(tr.times_array(None), [0.5, 0.75, 1.0])
    np.testing.assert_allclose(tr.times_array(0.8), [0.5, 0.75])
    # loop + period: idle tail restored, exactly like the scalar path
    lp = TraceArrivals([0.5, 0.25, 0.25], loop=True, period_s=2.0)
    scalar = list(lp.times(7.0, random.Random(0)))
    np.testing.assert_allclose(lp.times_array(7.0), scalar, atol=1e-9)
    with pytest.raises(ValueError):
        lp.times_array(None)
    with pytest.raises(ValueError):
        TraceArrivals([0.0], loop=True).times_array(5.0)


# ------------------------------------------------------------ size sampling
def test_sample_array_matches_scalar_distributions():
    rng = np.random.default_rng(9)
    assert (SizeDist.const(16).sample_array(50, rng) == 16).all()
    u = SizeDist.uniform(8, 64).sample_array(2000, rng)
    assert u.min() >= 8 and u.max() <= 64
    assert {8, 64} <= set(u.tolist())      # bounds inclusive, like randint
    ln = SizeDist.lognormal(24, 0.6).sample_array(4000, rng)
    assert ln.min() >= 1
    assert abs(float(np.median(ln)) - 24) <= 4
    ch = SizeDist.choice([4, 8, 32], weights=[1, 1, 6]).sample_array(
        2000, rng)
    assert set(ch.tolist()) == {4, 8, 32}
    assert (ch == 32).mean() > 0.6
    with pytest.raises(ValueError):
        SizeDist("nope").sample_array(3, rng)


def test_request_batch_digest_covers_every_column():
    base = _wl("poisson").generate_bulk()

    def mutated(**over):
        cols = dict(fns=base.fns, arrival_t=base.arrival_t,
                    fn_idx=base.fn_idx, size=base.size, rid=base.rid,
                    deadline_t=base.deadline_t)
        cols.update(over)
        return RequestBatch(**cols)

    assert mutated().digest() == base.digest()
    assert mutated(fns=("a", "b", "c")).digest() != base.digest()
    for col in ("arrival_t", "fn_idx", "size", "rid", "deadline_t"):
        arr = getattr(base, col).copy()
        arr[0] = -1                        # NaN-proof: NaN + 1 == NaN
        assert mutated(**{col: arr}).digest() != base.digest(), col

"""Multi-device behaviour on 8 virtual CPU devices (subprocess: the flag must
be set before jax initializes, and the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_sharded_train_step_runs():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.configs import get_config, reduced, TRAIN_4K
        from repro.models import build_model
        from repro.distributed.sharding import TRAIN_RULES, make_resolver, tree_shardings
        from repro.models.layers import sharding_context
        from repro.train.optimizer import AdamW
        from repro.train.trainer import make_train_step
        from repro.data.pipeline import DataConfig, TokenStream

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = replace(reduced(get_config("qwen3_32b")), dtype="float32")
        model = build_model(cfg, attn_block=16)
        params = model.init_params(jax.random.PRNGKey(0))
        psh = tree_shardings(mesh, model.abstract_params(), model.param_axes(),
                             TRAIN_RULES)
        params = jax.device_put(params, psh)
        opt = AdamW(lr=1e-3)
        state = jax.device_put(opt.init(params), {"step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), "m": psh, "v": psh})
        stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                        global_batch=8, seed=0))
        step = jax.jit(make_train_step(model, opt, accum=2))
        losses = []
        with mesh, sharding_context(make_resolver(mesh, TRAIN_RULES)):
            for i in range(6):
                params, state, m = step(params, state, stream.batch(i))
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("SHARDED_TRAIN_OK", round(losses[0], 3), "->", round(losses[-1], 3))
    """)
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_compressed_pod_psum_numerics():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import make_pod_grad_sync

        mesh = jax.make_mesh((8,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sync = make_pod_grad_sync(mesh, "int8")
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))   # per-pod grads
        err = jnp.zeros((8, 64))

        @partial(jax.shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")))
        def run(g, e):
            s, ne = sync({"w": g[0]}, {"w": e[0]})
            return s["w"][None], ne["w"][None]

        synced, new_err = run(g, err)
        exact = jnp.mean(g, axis=0)
        err1 = float(jnp.max(jnp.abs(synced[0] - exact)))
        # error feedback: after a second identical round, residual shrinks
        synced2, _ = run(g + new_err * 0, new_err)  # reuse err
        assert err1 < 0.05, err1
        print("COMPRESSED_PSUM_OK", err1)
    """)
    assert "COMPRESSED_PSUM_OK" in out


@pytest.mark.slow
def test_checkpoint_elastic_remesh():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.checkpoint import CheckpointManager

        m1 = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,)*2)
        m2 = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,)*2)
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(m1, P("data", "model")))}
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, tree)
        target_sh = {"w": NamedSharding(m2, P("model", "data"))}
        restored = mgr.restore(1, tree, shardings=target_sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
        assert restored["w"].sharding.is_equivalent_to(target_sh["w"], 2)
        print("ELASTIC_RESTORE_OK")
    """)
    assert "ELASTIC_RESTORE_OK" in out


@pytest.mark.slow
def test_mini_dryrun_probe_consistency():
    """Probe extrapolation == direct unrolled compile on a small mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from dataclasses import replace
        from repro.configs import get_config, reduced, TRAIN_4K
        from repro.models.transformer import LM
        from repro.distributed.sharding import TRAIN_RULES, make_resolver, tree_shardings, with_shardings
        from repro.models.layers import sharding_context

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = replace(reduced(get_config("deepseek_coder_33b")), num_layers=4,
                      grad_accum=1)
        shape = replace(TRAIN_4K, global_batch=8, seq_len=64)

        def flops_at(nl):
            c = replace(cfg, num_layers=nl)
            model = LM(c, unroll=True, attn_block=64)
            pa = model.abstract_params()
            psh = tree_shardings(mesh, pa, model.param_axes(), TRAIN_RULES)
            ba, bax = model.input_specs(shape)
            bsh = tree_shardings(mesh, ba, bax, TRAIN_RULES)
            def g(p, b):
                return jax.grad(lambda pp: model.loss_fn(pp, b)[0])(p)
            with mesh, sharding_context(make_resolver(mesh, TRAIN_RULES)):
                comp = jax.jit(g, out_shardings=psh).lower(
                    with_shardings(pa, psh), with_shardings(ba, bsh)).compile()
            return comp.cost_analysis()["flops"]

        f1, f2, f4 = flops_at(1), flops_at(2), flops_at(4)
        pred4 = f1 + 3 * (f2 - f1)
        rel = abs(pred4 - f4) / f4
        assert rel < 0.05, (f1, f2, f4, pred4, rel)
        print("PROBE_LINEARITY_OK", round(rel, 4))
    """)
    assert "PROBE_LINEARITY_OK" in out

"""HyperFaaS core: router tree, simulator lifecycle, RQ-A policies, faults."""
import random

import pytest

from repro.core.config_store import ConfigStore, ImageRegistry
from repro.core.router import (StateView, WorkerState, build_tree,
                               replicate)
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  poisson_load, summarize)
from repro.core.types import FunctionConfig, Request


@pytest.fixture
def store():
    s = ConfigStore()
    s.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                         cold_start_s=0.2, idle_timeout_s=5.0))
    return s


def _sim(store, workers=8, **kw):
    tree = build_tree(workers, fanout=4)
    return Simulator(tree, store, SyntheticServiceModel(seed=2), seed=5, **kw)


# ----------------------------------------------------------------- router
def test_tree_shape_and_routing():
    tree = build_tree(64, fanout=8)
    assert len(tree.all_workers()) == 64
    view, rng = StateView(), random.Random(0)
    for i in range(200):
        w, hops = tree.route(Request(fn="fn", arrival_t=0.0), view, rng)
        assert w in tree.all_workers()
        assert hops == 2            # 64 workers/fanout 8 => leaf + root


def test_replicate_recipe():
    base = build_tree(16, fanout=4)
    doubled = replicate(base, times=2)
    assert len(doubled.all_workers()) == 32
    assert doubled.policy_name == "random"      # stateless front LB (paper)
    quad = replicate(base, times=4)
    assert len(quad.all_workers()) == 64
    assert len(set(quad.all_workers())) == 64   # fresh worker ids


def test_round_robin_visits_all_workers_in_order():
    """Post-increment: the very first call must land on worker 0, then
    cycle w0,w1,w2,w0,... (the seed bug skipped w0 on the first pass)."""
    from repro.core.router import round_robin_policy
    policy = round_robin_policy()
    workers = ["w0", "w1", "w2"]
    view, rng = StateView(), random.Random(0)
    req = Request(fn="fn", arrival_t=0.0)
    picks = [policy(req, workers, view, rng, 0.0) for _ in range(7)]
    assert picks == ["w0", "w1", "w2", "w0", "w1", "w2", "w0"]


def test_warm_affinity_prefers_warm():
    from repro.core.router import warm_affinity_policy
    view = StateView()
    view.update(WorkerState("w0", warm_fns=frozenset({"fn"}), inflight=3,
                            capacity=4))
    view.update(WorkerState("w1", warm_fns=frozenset(), inflight=0, capacity=4))
    rng = random.Random(0)
    req = Request(fn="fn", arrival_t=0.0)
    picks = {warm_affinity_policy(req, ["w0", "w1"], view, rng, 0.0)
             for _ in range(20)}
    assert picks == {"w0"}


def test_state_view_staleness():
    view = StateView(staleness_s=10.0)
    view.update(WorkerState("w0", queue_len=0), t=0.0)
    view.update(WorkerState("w0", queue_len=99), t=1.0)   # within staleness
    assert view.get("w0", t=1.0).queue_len == 0           # stale snapshot


def test_elastic_add_remove_branch(store):
    sim = _sim(store, workers=4)
    from repro.core.router import build_leaf
    sim.add_branch(build_leaf("leaf-new", ["wx0", "wx1"]))
    assert "wx0" in sim.tree.all_workers()
    sim.remove_branch("leaf-new")
    assert "wx0" not in sim.tree.all_workers()


def test_add_branch_preserves_worker_capacity(store):
    """Live-added workers must inherit the simulator's configured
    capacity, not the dataclass default of 16 (seed regression)."""
    from repro.core.router import build_leaf
    from repro.workloads import build_scenario
    sim = _sim(store, workers=4, worker_capacity_slots=2)
    sim.add_branch(build_leaf("leaf-new", ["wx0", "wx1"]))
    assert sim.workers["wx0"].capacity_slots == 2
    assert sim.workers["wx0"].capacity_slots == sim.workers["w0"].capacity_slots
    # elastic-scaling scenario: a flash crowd on the grown tree must never
    # push any worker (original or added) past its instance capacity
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.05, timeout_s=0.5,
                             max_instances_per_worker=8))
    wl = build_scenario("flash_crowd", duration_s=5.0, seed=3)
    sim.load(wl)
    peak = {}
    orig = Simulator._maybe_start_instance

    def spy(self, w, cfg):
        inst = orig(self, w, cfg)
        if inst is not None:
            cur = sum(len(il) for il in w.instances.values())
            peak[w.name] = max(peak.get(w.name, 0), cur)
        return inst
    Simulator._maybe_start_instance = spy
    try:
        sim.run()
    finally:
        Simulator._maybe_start_instance = orig
    served = {r.worker for r in sim.results if r.ok}
    assert served & {"wx0", "wx1"}, "added branch must serve traffic"
    assert peak and max(peak.values()) <= 2, peak


# -------------------------------------------------------------- simulator
def test_sim_deterministic(store):
    r1 = summarize(_run_load(_sim(store)))
    r2 = summarize(_run_load(_sim(store)))
    assert r1 == r2


def _run_load(sim, rps=100, dur=10):
    poisson_load(sim, fn="fn", rps=rps, duration_s=dur, seed=4)
    return sim.run()


def test_all_requests_resolve(store):
    sim = _sim(store)
    n = poisson_load(sim, fn="fn", rps=200, duration_s=10, seed=4)
    res = sim.run()
    assert len(res) == n
    assert len({r.rid for r in res}) == n


def test_within_instance_concurrency_rq_a(store):
    """c=1 must start far more instances than c=8 under the same load."""
    out = {}
    for c in (1, 8):
        store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=c,
                                 cold_start_s=0.2, max_instances_per_worker=16))
        sim = _sim(store, workers=8)
        poisson_load(sim, fn="fn", rps=150, duration_s=10, seed=4)
        sim.run()
        out[c] = sum(w.instances_started for w in sim.workers.values())
    assert out[1] > 2 * out[8], out


def test_queue_timeout_fails_requests(store):
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             timeout_s=0.05, cold_start_s=1.0,
                             max_instances_per_worker=1))
    sim = _sim(store, workers=1)
    poisson_load(sim, fn="fn", rps=300, duration_s=3, seed=4)
    s = summarize(sim.run())
    assert s["fail_rate"] > 0.2


def test_failure_injection_and_recovery(store):
    sim = _sim(store, workers=4)
    sim.inject_failure("w0", at=2.0, recover_after=3.0)
    poisson_load(sim, fn="fn", rps=50, duration_s=10, seed=4)
    res = sim.run()
    late_ok = [r for r in res if r.ok and r.worker == "w0" and r.arrival_t > 6.0]
    assert late_ok, "w0 must serve again after recovery"
    assert summarize(res)["fail_rate"] < 0.2


def test_run_until_resume_loses_no_events(store):
    """run(until) must re-queue the event it peeked past so a later
    run() resumes losslessly (the elastic-scaling driver pattern)."""
    sim = _sim(store)
    n = poisson_load(sim, fn="fn", rps=200, duration_s=5, seed=4)
    sim.run(until=2.0)
    res = sim.run()
    assert len(res) == n


def test_hedging_with_rid_zero_keeps_results_straight(store):
    """A hedge clone of request 0 must resolve to primary rid 0 (rid 0 is
    falsy — `hedged_from or rid` misattributed it), and clone rids from
    the global counter must never displace workload-assigned rids."""
    from repro.workloads import build_scenario
    sim = _sim(store, workers=4, hedge_after_s=0.05)
    wl = build_scenario("steady", rps=100.0, duration_s=5.0, seed=4)
    by_rid = {r.rid: r for r in wl.generate()}
    for req in by_rid.values():
        sim.submit(req)
    res = sim.run()
    assert len(res) == len(by_rid)
    assert {r.rid for r in res} == set(by_rid)
    for r in res:
        # a winning hedge clone legitimately reports arrival + hedge delay;
        # anything else means a clone displaced an unrelated request
        orig = by_rid[r.rid].arrival_t
        assert (r.arrival_t == orig
                or abs(r.arrival_t - (orig + 0.05)) < 1e-9), r.rid


def test_hedging_cuts_straggler_tail(store):
    def tail(hedge):
        sim = _sim(store, workers=4, hedge_after_s=0.08 if hedge else None)
        sim.set_straggler("w1", 30.0)
        poisson_load(sim, fn="fn", rps=40, duration_s=20, seed=4)
        return summarize(sim.run())["p99"]
    assert tail(True) < 0.6 * tail(False)


def test_explicit_zero_cold_start_is_instant(store):
    """ISSUE-3 regression: `cold_start_s=0.0` was falsy, so the seed's
    `cfg.cold_start_s or default` silently replaced an explicitly
    configured instant start with the 0.25 s default. Only an *unset*
    (None) cold start may fall back to the platform default."""
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.0))
    sim = _sim(store, workers=1)
    sim.submit(Request(fn="fn", arrival_t=1.0))
    res = sim.run()
    assert res[0].ok
    # instant start: service began at arrival (no cold-start delay) even
    # though the instance was created cold for this very request
    assert res[0].start_t == pytest.approx(1.0 + sim.hop_s * 2)
    # unset cold start still pays the default
    store.put(FunctionConfig(name="fn2", arch="tiny_lm", concurrency=1))
    sim2 = _sim(store, workers=1)
    sim2.submit(Request(fn="fn2", arrival_t=1.0))
    res2 = sim2.run()
    assert res2[0].start_t >= 1.0 + sim2.cold_default


def test_idle_check_on_draining_worker_is_noop(store):
    """A worker parked in `_draining` (branch removed with work in
    flight) must never reap instances through a queued idle_check — it
    only exists to finish its in-flight requests (pinned behaviour)."""
    from repro.autoscale import build_pool
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.05, idle_timeout_s=0.5))
    sim = Simulator(build_pool(2, 2), store, SyntheticServiceModel(seed=2),
                    seed=5)
    n = poisson_load(sim, fn="fn", rps=200, duration_s=2.0, seed=4)
    sim.run(until=1.0)
    gone = sim.tree.children[0].name
    gone_workers = sim.tree.children[0].all_workers()
    sim.remove_branch(gone)
    drained = {w: sim._draining[w] for w in gone_workers
               if w in sim._draining}
    assert drained, "test must catch a worker mid-drain"
    counts = {w: dw.total_instances for w, dw in drained.items()}
    res = sim.run()
    assert len(res) == n
    for w, dw in drained.items():
        # queued idle_checks fired while draining: silently no-op'ed —
        # instance sets unchanged, only busy counts went to zero
        assert dw.total_instances == counts[w]
        assert dw.inflight() == 0
    assert not sim._draining                # retired once in-flight drained


def test_idle_instances_reaped(store):
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.1, idle_timeout_s=1.0))
    sim = _sim(store, workers=2)
    sim.submit(Request(fn="fn", arrival_t=0.0))
    sim.submit(Request(fn="fn", arrival_t=30.0))   # long gap => reap between
    res = sim.run()
    assert all(r.cold_start for r in res), "second request must cold start again"


def test_summarize_throughput_uses_makespan():
    """Seed bug: throughput divided by max(finish_t), undercounting any
    run whose first arrival is at t0 > 0 (daily_cycle offsets, resumed
    run(until) segments)."""
    from repro.core.types import RequestResult

    def res(arrival, finish, ok=True):
        return RequestResult(rid=0, fn="fn", ok=ok, arrival_t=arrival,
                             start_t=arrival, finish_t=finish,
                             cold_start=False, worker="w0", instance="i")
    shifted = [res(100.0 + i, 100.5 + i) for i in range(10)]
    s = summarize(shifted)
    # 10 ok requests over a 9.5s makespan — NOT over 109.5s absolute time
    assert s["throughput"] == pytest.approx(10 / 9.5)
    assert s["goodput"] == s["throughput"]
    # re-pinned in ISSUE-9 (was 1/3.0): the makespan now ends at the last
    # *successful* finish (t=2.0), so the failed row's later finish_t
    # (t=3.0 — think a queue-timeout tail firing at arrival+timeout_s)
    # no longer stretches the window and dilutes the rate
    assert summarize([res(0.0, 2.0), res(1.0, 3.0, ok=False)])[
        "throughput"] == pytest.approx(1 / 2.0)


def test_summarize_denominators_exclude_unserved_failures():
    """ISSUE-9 bugfix: cold_rate divided by *all* rows, so failures that
    never reached an instance (gateway sheds, no-healthy-workers — the
    `instance == "-"` rows) diluted the cold-start rate; and a run with
    zero successes reported throughput over a meaningless window."""
    from repro.core.types import RequestResult

    def res(arrival, finish, ok=True, cold=False, instance="i"):
        return RequestResult(rid=0, fn="fn", ok=ok, arrival_t=arrival,
                             start_t=arrival, finish_t=finish,
                             cold_start=cold, worker="w0",
                             instance=instance)
    rows = [res(0.0, 1.0, cold=True),          # served, cold
            res(0.0, 2.0, cold=False),         # served, warm
            res(0.5, 0.5, ok=False, instance="-")]   # shed: never served
    s = summarize(rows)
    assert s["cold_rate"] == pytest.approx(0.5)   # 1 cold / 2 *served*
    assert s["goodput"] == pytest.approx(2 / 2.0)
    all_failed = summarize([res(0.0, 9.0, ok=False, instance="-")])
    assert all_failed["goodput"] == 0.0
    assert all_failed["cold_rate"] == 0.0


# ------------------------------------------------------------ config store
def test_config_store_versioning(store):
    assert store.version("fn") == 1
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2))
    assert store.version("fn") == 2
    assert store.get("fn").concurrency == 2
    with pytest.raises(KeyError):
        store.get("nope")


def test_image_registry():
    reg = ImageRegistry()
    reg.register("tiny_lm", lambda: "built")
    assert reg.pull("tiny_lm")() == "built"
    with pytest.raises(KeyError):
        reg.pull("missing")

"""Blocked-flash (fwd + custom VJP) vs plain-attention AD oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

CASES = [
    dict(B=2, S=32, H=4, KV=2, hd=8, causal=True, window=0, blk=8),
    dict(B=1, S=48, H=6, KV=3, hd=16, causal=True, window=0, blk=16),
    dict(B=2, S=32, H=4, KV=4, hd=8, causal=False, window=0, blk=8),
    dict(B=2, S=64, H=4, KV=2, hd=8, causal=True, window=12, blk=16),
    dict(B=1, S=64, H=8, KV=1, hd=8, causal=True, window=0, blk=32),  # MQA
]


def _mk(c, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (c["B"], c["S"], c["H"], c["hd"]), jnp.float32)
    k = jax.random.normal(ks[1], (c["B"], c["S"], c["KV"], c["hd"]), jnp.float32)
    v = jax.random.normal(ks[2], (c["B"], c["S"], c["KV"], c["hd"]), jnp.float32)
    return q, k, v


@pytest.mark.slow
@pytest.mark.parametrize("case", CASES)
def test_blocked_matches_plain_fwd_and_grad(case, rng):
    q, k, v = _mk(case, rng)
    f1 = lambda q, k, v: (A.attend_blocked(
        q, k, v, causal=case["causal"], window=case["window"],
        block=case["blk"]) ** 2).sum()
    f2 = lambda q, k, v: (A.attend_plain(
        q, k, v, causal=case["causal"], window=case["window"]) ** 2).sum()
    np.testing.assert_allclose(f1(q, k, v), f2(q, k, v), rtol=2e-4)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_blocked_unroll_matches_scan(rng):
    c = CASES[0]
    q, k, v = _mk(c, rng)
    o1 = A.attend_blocked(q, k, v, causal=True, block=8)
    o2 = A.attend_blocked(q, k, v, causal=True, block=8, unroll=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)


def test_decode_matches_plain_last_token(rng):
    B, S, H, KV, hd = 2, 33, 4, 2, 8
    ks = jax.random.split(rng, 3)
    q_all = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k_all = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v_all = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    full = A.attend_plain(q_all, k_all, v_all, causal=True)
    pos = jnp.full((B,), S - 1, jnp.int32)
    out = A.attend_decode(q_all[:, -1], k_all, v_all, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_masks_future(rng):
    """Entries past `pos` must not affect decode output."""
    B, W, H, KV, hd = 1, 16, 2, 2, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
    pos = jnp.array([7], jnp.int32)
    o1 = A.attend_decode(q, k, v, pos)
    k2 = k.at[:, 9:].set(99.0)
    v2 = v.at[:, 9:].set(-99.0)
    o2 = A.attend_decode(q, k2, v2, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))


def test_pair_list_exact_triangle():
    pairs = A._block_pairs(8, 8, causal=True, window_blocks=0)
    assert len(pairs) == 8 * 9 // 2
    pairs_w = A._block_pairs(8, 8, causal=True, window_blocks=2)
    assert all(i - j <= 2 for i, j in pairs_w)
    pairs_full = A._block_pairs(4, 4, causal=False, window_blocks=0)
    assert len(pairs_full) == 16

"""Event-engine layer: backend registry, ordering, and resume contracts.

The ISSUE-5 split moved the simulator's hot loop behind
``repro.core.events.EventEngine`` with pluggable queue backends. The
contracts pinned here:

- registry: ``single_heap`` and ``sharded`` are registered and
  constructible; unknown names raise.
- ordering: both backends drain arbitrary interleaved push/pop streams
  in identical ``(t, seq)`` order (shared driver in ``_prop_drivers``;
  the hypothesis lane in ``test_property.py`` explores the seed space).
- equivalence: a full simulator run on ``sharded`` is *byte-identical*
  to ``single_heap`` — results, telemetry, and decision logs — across
  scenario shapes, timeouts, hedging, and an autoscaled control loop.
- resume: ``run(until); run()`` is byte-identical to one straight
  ``run()`` including ``events_processed`` (the engine peeks instead of
  pop-and-requeueing, so there is no path left that could double-count).
"""

import pytest

from repro.core.config_store import ConfigStore
from repro.core.events import (EVENT_BACKENDS, EventEngine, ShardedQueue,
                               get_event_backend, list_event_backends)
from repro.core.router import build_tree
from repro.core.simulator import Simulator, SyntheticServiceModel
from repro.workloads import build_scenario, install_demo_configs


# ----------------------------------------------------------------- registry
def test_registry_complete():
    assert set(list_event_backends()) >= {"single_heap", "sharded"}
    assert sorted(EVENT_BACKENDS) == list_event_backends()
    assert get_event_backend("single_heap").kind == "single_heap"
    assert get_event_backend("sharded", bucket_s=0.5).bucket_s == 0.5
    with pytest.raises(KeyError):
        get_event_backend("nope")


def test_engine_accepts_backend_instance():
    eng = EventEngine(ShardedQueue(bucket_s=0.01))
    eng.push(1.0, "ev", "x")
    assert eng.backend == "sharded"
    assert eng.pop() == (1.0, 0, "ev", "x")


def test_engine_pending_real_excludes_background():
    eng = EventEngine("single_heap", background=("autoscale_tick",))
    eng.push(1.0, "arrival", None)
    eng.push(2.0, "autoscale_tick", None)
    assert len(eng) == 2 and eng.pending_real == 1
    eng.pop()
    assert eng.pending_real == 0 and len(eng) == 1


def test_engine_pop_until_leaves_event_in_place():
    eng = EventEngine("single_heap")
    eng.push(5.0, "ev", "late")
    assert eng.pop(until=1.0) is None
    assert len(eng) == 1 and eng.pending_real == 1
    assert eng.pop(until=5.0) == (5.0, 0, "ev", "late")


# --------------------------------------------------- bulk ingest (ISSUE-8)
def test_push_bulk_matches_per_push_scalar_reference():
    """One deterministic spot check (the property driver fuzzes the rest):
    a sealed sharded queue takes a bulk run spanning the draining bucket
    and several future buckets, and drains identically to single_heap."""
    eng = EventEngine("sharded")
    ref = EventEngine("single_heap")
    pre = [i * 0.1 for i in range(200)]    # stage + seal via first pops
    eng.push_bulk(pre, "arrival", None)
    ref.push_bulk(pre, "arrival", None)
    for _ in range(50):
        assert eng.pop() == ref.pop()
    run = [4.90001 + i * 0.07 for i in range(100)]   # big: vectorized path
    eng.push_bulk(run, "fu", list(range(100)))
    ref.push_bulk(run, "fu", list(range(100)))
    eng.push_bulk([5.0001, 5.0002], "fu", None)      # small: per-entry path
    ref.push_bulk([5.0001, 5.0002], "fu", None)
    out = [eng.pop() for _ in range(len(eng))]
    assert out == [ref.pop() for _ in range(len(ref))]
    assert out == sorted(out)
    assert eng.pop() is None and ref.pop() is None


def test_pop_batch_is_greedy_and_horizon_bounded():
    for backend in ("single_heap", "sharded"):
        eng = EventEngine(backend)
        eng.push_bulk([float(i) for i in range(10)], "ev", None)
        assert [e[0] for e in eng.pop_batch(3)] == [0.0, 1.0, 2.0]
        # horizon cuts inside the batch; until is inclusive
        assert [e[0] for e in eng.pop_batch(100, until=5.0)] == [3.0, 4.0,
                                                                 5.0]
        assert eng.pop_batch(100, until=5.5) == []
        assert [e[0] for e in eng.pop_batch(100)] == [6.0, 7.0, 8.0, 9.0]
        assert eng.pop_batch(4) == [] and len(eng) == 0


def test_push_bulk_stamps_contiguous_seqs_and_counts_background():
    eng = EventEngine("single_heap", background=("tick",))
    assert eng.push_bulk([1.0, 2.0], "ev", None) == 2
    assert eng.push_bulk([1.5], "tick", None) == 1
    assert eng.push_bulk([], "ev", None) == 0
    assert len(eng) == 3 and eng.pending_real == 2
    assert [e[1] for e in eng.pop_batch(3)] == [0, 2, 1]  # seq stamp order
    assert eng.pending_real == 0


@pytest.mark.parametrize("seed", range(5))
def test_push_bulk_matches_per_push_across_backends(seed):
    from _prop_drivers import run_push_bulk_ops
    assert run_push_bulk_ops(seed) > 0


# ------------------------------------------------------- sharded internals
def test_sharded_seals_bulk_load_then_takes_dynamic_pushes():
    q = ShardedQueue(target_per_bucket=4)
    for i in range(32):                    # staged bulk load, ascending t
        q.push((i * 0.1, i, "ev", i))
    assert q.peek() == (0.0, 0, "ev", 0)   # first peek seals the stage
    q.push((0.05, 100, "ev", "dyn"))       # dynamic push into a past-ish slot
    out = []
    while len(q):
        out.append(q.pop())
    assert out == sorted(out)
    assert len(out) == 33


def test_sharded_restages_after_full_drain():
    q = ShardedQueue()
    q.push((1.0, 0, "ev", None))
    assert q.pop() == (1.0, 0, "ev", None)
    # drained: backend returns to staging so a second bulk load re-tunes
    for i in range(8):
        q.push((100.0 + i, 1 + i, "ev", None))
    assert q.peek() == (100.0, 1, "ev", None)
    assert [q.pop()[0] for _ in range(8)] == [100.0 + i for i in range(8)]


def test_sharded_same_time_orders_by_seq():
    q = ShardedQueue()
    for seq in (3, 1, 2, 0):
        q.push((7.5, seq, "ev", None))
    assert [q.pop()[1] for _ in range(4)] == [0, 1, 2, 3]


# -------------------------------------- shared op-sequence property driver
@pytest.mark.parametrize("seed", range(5))
def test_backends_drain_interleaved_streams_identically(seed):
    from _prop_drivers import run_event_backend_ops
    assert run_event_backend_ops(seed) > 0


# ------------------------------------------- full-simulator byte identity
from _prop_drivers import digest_sim as _digest  # noqa: E402  (shared def)


def _scenario_sim(backend, scenario, *, sim_kw=None, **over):
    wl = build_scenario(scenario, **over)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(8, fanout=4), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    event_backend=backend, **(sim_kw or {}))
    sim.load(wl)
    sim.run()
    return sim


BACKEND_CASES = {
    "steady": dict(rps=300.0, duration_s=6.0, seed=3),
    "multi_tenant": dict(rps=400.0, duration_s=6.0, seed=3),
    "flash_crowd": dict(duration_s=6.0, seed=3, burst_rps=1500.0),
}


@pytest.mark.parametrize("scenario", sorted(BACKEND_CASES))
def test_sharded_byte_identical_to_single_heap(scenario):
    a = _scenario_sim("single_heap", scenario, **BACKEND_CASES[scenario])
    b = _scenario_sim("sharded", scenario, **BACKEND_CASES[scenario])
    assert _digest(a) == _digest(b)
    assert a.events_processed == b.events_processed


def test_sharded_byte_identical_with_hedging():
    kw = dict(sim_kw=dict(hedge_after_s=0.05))
    a = _scenario_sim("single_heap", "steady", rps=150.0, duration_s=6.0,
                      seed=3, **kw)
    b = _scenario_sim("sharded", "steady", rps=150.0, duration_s=6.0,
                      seed=3, **kw)
    assert _digest(a) == _digest(b)


def test_sharded_byte_identical_decision_logs():
    # no hedging here: hedge clones draw rids from the process-global
    # counter, which makes the *absolute* rids in the routing log depend
    # on how many clones earlier sims in the process spawned (results
    # and telemetry are immune — they resolve to the primary rid)
    kw = dict(sim_kw=dict(record_decisions=True, worker_memory_mb=2048,
                          placer="best_fit_memory"))
    a = _scenario_sim("single_heap", "multi_tenant", memory_skew=True,
                      rps=250.0, duration_s=6.0, seed=3, **kw)
    b = _scenario_sim("sharded", "multi_tenant", memory_skew=True,
                      rps=250.0, duration_s=6.0, seed=3, **kw)
    assert _digest(a) == _digest(b)
    assert a.placement_log() == b.placement_log()
    assert a.routing_log() == b.routing_log()


def test_sharded_byte_identical_through_autoscaled_control_loop():
    from repro.autoscale import Autoscaler, build_pool

    def run(backend):
        wl = build_scenario("flash_crowd", duration_s=12.0, seed=3,
                            base_rps=12.0, burst_rps=800.0,
                            mean_burst_s=2.0, mean_calm_s=8.0)
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(build_pool(1, 2), store,
                        SyntheticServiceModel(seed=2), seed=7,
                        worker_capacity_slots=1, event_backend=backend)
        scaler = Autoscaler("reactive", interval_s=0.25, window_s=2.0,
                            min_replicas=1, max_replicas=8,
                            workers_per_replica=2, cooldown_s=2.0)
        sim.attach_autoscaler(scaler)
        sim.load(wl)
        sim.run()
        return sim, scaler

    (a, sa), (b, sb) = run("single_heap"), run("sharded")
    assert _digest(a) == _digest(b)
    assert sa.decision_log() == sb.decision_log()


# ------------------------------------------------- bulk-ingest equivalence
def _bulk_sim(backend):
    from repro.core.types import FunctionConfig
    store = ConfigStore()
    for fn in ("a", "b"):
        store.put(FunctionConfig(name=fn, arch="tiny_lm", concurrency=4,
                                 cold_start_s=0.05))
    return Simulator(build_tree(4, fanout=2), store,
                     SyntheticServiceModel(seed=2), seed=7,
                     event_backend=backend)


def _bulk_workload():
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)
    return MixedWorkload(
        PoissonArrivals(150.0),
        [FunctionProfile("a", weight=2.0, size=SizeDist.lognormal(24, 0.5),
                         slo_p95_s=0.8),
         FunctionProfile("b", size=SizeDist.uniform(8, 64))],
        duration_s=8.0, seed=5)


@pytest.mark.parametrize("backend", ["single_heap", "sharded"])
def test_load_bulk_byte_identical_to_per_request_submit(backend):
    """sim.load_bulk(wl) must be byte-identical (results + telemetry) to
    submitting the same RequestBatch request by request — including with
    a chunk size that forces many bulk runs per load."""
    wl = _bulk_workload()
    a = _bulk_sim(backend)
    for req in wl.generate_bulk().to_requests():
        a.submit(req)
    a.run()
    b = _bulk_sim(backend)
    assert b.load_bulk(wl, chunk=257) == len(a.results)
    b.run()
    assert _digest(a) == _digest(b)


def test_load_bulk_byte_identical_across_backends():
    a = _bulk_sim("single_heap")
    a.load_bulk(_bulk_workload())
    a.run()
    b = _bulk_sim("sharded")
    b.load_bulk(_bulk_workload())
    b.run()
    assert _digest(a) == _digest(b)
    assert a.events_processed == b.events_processed


# ------------------------------------------------------ resume equivalence
@pytest.mark.parametrize("backend", ["single_heap", "sharded"])
def test_segmented_run_until_equals_straight_run(backend):
    """ISSUE-5 satellite: resuming run(until=...) must not double-count
    ``events_processed`` or perturb a single byte of the result stream.
    The engine peeks instead of popping-and-requeueing, so the horizon
    check never touches the queue."""

    def mk():
        wl = build_scenario("multi_tenant", rps=300.0, duration_s=5.0,
                            seed=3)
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(build_tree(8, fanout=4), store,
                        SyntheticServiceModel(seed=2), seed=7,
                        hedge_after_s=0.05, event_backend=backend)
        sim.load(wl)
        return sim

    straight = mk()
    straight.run()
    seg = mk()
    t = 0.0
    while len(seg.engine):
        t += 0.37
        seg.run(until=t)
    seg.run()
    assert seg.events_processed == straight.events_processed
    assert _digest(seg) == _digest(straight)


def test_segmented_autoscaled_run_until_equals_straight_run():
    from repro.autoscale import Autoscaler, build_pool

    def mk():
        wl = build_scenario("flash_crowd", duration_s=8.0, seed=3,
                            base_rps=12.0, burst_rps=600.0,
                            mean_burst_s=2.0, mean_calm_s=6.0)
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(build_pool(1, 2), store,
                        SyntheticServiceModel(seed=2), seed=7,
                        worker_capacity_slots=1)
        scaler = Autoscaler("reactive", interval_s=0.25, window_s=2.0,
                            min_replicas=1, max_replicas=8,
                            workers_per_replica=2, cooldown_s=2.0)
        sim.attach_autoscaler(scaler)
        sim.load(wl)
        return sim, scaler

    a, sa = mk()
    a.run()
    b, sb = mk()
    t = 0.0
    while len(b.engine):
        t += 0.4
        b.run(until=t)
    b.run()
    assert b.events_processed == a.events_processed
    assert _digest(a) == _digest(b)
    assert sa.decision_log() == sb.decision_log()

"""Autoscaler control loop: deterministic scaling-regression suite.

The contract under test (ISSUE 2): same seed => byte-identical
scaling-decision log per policy; `reactive` beats the static replicate
baseline under `flash_crowd` on p95/fail at equal-or-lower
replica-seconds; cooldown prevents flapping; min/max replica bounds are
never violated; and `remove_branch` drains safely (no dangling queued or
in-flight requests, no stale worker entries).
"""
import pytest

from repro.autoscale import (AUTOSCALERS, Autoscaler, build_pool,
                             get_autoscaler, list_autoscalers)
from repro.core.config_store import ConfigStore
from repro.core.router import build_leaf
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  summarize)
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario, install_demo_configs

ALL_POLICIES = ("static", "reactive", "target_concurrency", "predictive")

# the benchmark configuration (mirrors bench_autoscaler_scenarios): a
# calm-dominated flash crowd whose bursts saturate the 3-branch static
# fleet; scalers start at 1 branch. Workers are deliberately small
# (1 instance slot) so the operating point stays cheap to simulate.
FLASH = dict(duration_s=30.0, seed=3, base_rps=12.0, burst_rps=1000.0,
             mean_burst_s=2.0, mean_calm_s=10.0)
SCALER = dict(interval_s=0.25, window_s=2.0, min_replicas=1, max_replicas=8,
              workers_per_replica=2, cooldown_s=2.0)


def _run_policy(policy, *, branches=1, scenario="flash_crowd",
                overrides=FLASH, **scaler_kw):
    wl = build_scenario(scenario, **overrides)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(branches, SCALER["workers_per_replica"]),
                    store, SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=1)
    scaler = Autoscaler(policy, **{**SCALER, **scaler_kw})
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    results = sim.run()
    return sim, scaler, summarize(results)


# ---------------------------------------------------------------- registry
def test_registry_complete():
    assert set(list_autoscalers()) >= set(ALL_POLICIES)
    assert sorted(AUTOSCALERS) == list_autoscalers()
    pol = get_autoscaler("reactive", target_load=2.0)
    assert pol.name == "reactive" and pol.target_load == 2.0
    with pytest.raises(KeyError):
        get_autoscaler("nope")


# ----------------------------------------------------------- determinism
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_same_seed_identical_decision_log(policy):
    """Same seed => byte-identical scaling-decision log (the regression
    contract that makes this archetype possible)."""
    branches = 3 if policy == "static" else 1
    _, a, sa = _run_policy(policy, branches=branches)
    _, b, sb = _run_policy(policy, branches=branches)
    assert len(a.decisions) > 10
    assert a.decision_log() == b.decision_log()
    assert sa == sb


# -------------------------------------------- acceptance: reactive wins
def test_reactive_beats_static_replicate_baseline_under_flash_crowd():
    """`reactive` must beat the paper's static replicate recipe on p95 or
    fail_rate at equal-or-lower replica-seconds (worker-seconds here:
    branches are uniform, so the two are proportional)."""
    _, st, s_static = _run_policy("static", branches=3)
    _, re_, s_react = _run_policy("reactive", branches=1)
    assert (s_react["p95"] < s_static["p95"]
            or s_react["fail_rate"] < s_static["fail_rate"])
    assert re_.worker_seconds <= st.worker_seconds
    assert re_.summary()["scale_ups"] > 0      # it actually scaled


@pytest.mark.parametrize("policy", ("target_concurrency", "predictive"))
def test_other_scalers_also_beat_static_tail(policy):
    _, _, s_static = _run_policy("static", branches=3)
    _, _, s = _run_policy(policy, branches=1)
    assert s["p95"] < s_static["p95"]


# ------------------------------------------------------------- cooldown
def test_cooldown_prevents_flapping():
    """No two applied scale-downs may land inside the cooldown window,
    and disabling cooldown must produce at least as many scale events."""
    _, cooled, _ = _run_policy("reactive", cooldown_s=2.0)
    downs = [d.t for d in cooled.decisions if d.action == "down"]
    changes = [d.t for d in cooled.decisions if d.action in ("up", "down")]
    for t in downs:
        prior = [c for c in changes if c < t]
        if prior:
            assert t - max(prior) >= 2.0 - 1e-9, (t, max(prior))
    _, hot, _ = _run_policy("reactive", cooldown_s=0.0)
    n_cooled = sum(d.action in ("up", "down") for d in cooled.decisions)
    n_hot = sum(d.action in ("up", "down") for d in hot.decisions)
    assert n_hot >= n_cooled
    assert any(d.action == "cooldown" for d in cooled.decisions)


# --------------------------------------------------------------- bounds
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_replica_bounds_never_violated(policy):
    sim, scaler, _ = _run_policy(policy, min_replicas=1, max_replicas=4)
    assert scaler.decisions
    for d in scaler.decisions:
        assert 1 <= d.applied <= 4, d.fmt()
    assert 1 <= len(sim.tree.children) <= 4
    # desired is the raw policy output and may exceed the cap; the clamp
    # must be visible in the log rather than silently rewriting desired
    if any(d.desired > 4 for d in scaler.decisions):
        assert any(d.applied < d.desired for d in scaler.decisions)


def test_min_replicas_floor_holds_when_idle():
    """An idle tail (policy wants 0) must clamp at min_replicas."""
    sim, scaler, _ = _run_policy(
        "reactive", min_replicas=2, max_replicas=6,
        overrides=dict(duration_s=10.0, seed=3, base_rps=10.0,
                       burst_rps=800.0))
    assert all(d.applied >= 2 for d in scaler.decisions)
    assert len(sim.tree.children) >= 2


# --------------------------------------------- remove_branch regression
def _drain_sim(store):
    sim = Simulator(build_pool(2, 2), store, SyntheticServiceModel(seed=2),
                    seed=5)
    return sim


@pytest.fixture
def store():
    s = ConfigStore()
    s.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                         cold_start_s=0.05, idle_timeout_s=2.0))
    return s


def test_remove_branch_drains_queued_and_inflight(store):
    """Seed bug: remove_branch left queued/in-flight requests dangling and
    stale self.workers entries. Every request must now resolve."""
    sim = _drain_sim(store)
    wl = build_scenario("steady", rps=300.0, duration_s=6.0, seed=4)
    n = sim.load(wl)
    sim.run(until=3.0)
    removed_workers = [w for w in sim.tree.children[0].all_workers()]
    sim.remove_branch(sim.tree.children[0].name)
    assert all(w not in sim.workers for w in removed_workers)
    res = sim.run()
    assert len(res) == n                       # nothing dangles
    assert len({r.rid for r in res}) == n
    assert not sim._draining                   # drained workers retired
    late = [r for r in res if r.arrival_t > 3.0]
    assert late and all(r.worker not in removed_workers for r in late)


def test_remove_branch_rerouted_requests_can_still_succeed(store):
    """Queued work on a removed branch re-routes instead of failing when
    the tree still has capacity."""
    sim = _drain_sim(store)
    wl = build_scenario("steady", rps=200.0, duration_s=4.0, seed=6)
    n = sim.load(wl)
    sim.run(until=2.0)
    sim.remove_branch(sim.tree.children[0].name)
    s = summarize(sim.run())
    assert s["n"] == n
    assert s["fail_rate"] < 0.05


def test_remove_then_add_branch_does_not_resurrect_stale_workers(store):
    """Seed bug: add_branch rebuilt its cache from self.workers, which
    still held removed names — routing traffic to dead workers."""
    sim = _drain_sim(store)
    gone = sim.tree.children[0].name
    gone_workers = sim.tree.children[0].all_workers()
    sim.remove_branch(gone)
    sim.add_branch(build_leaf("fresh", ["fx0", "fx1"]))
    assert set(sim._worker_list) == set(sim.tree.all_workers())
    assert all(w not in sim._worker_list for w in gone_workers)
    n = sim.load(build_scenario("steady", rps=100.0, duration_s=3.0, seed=4))
    res = sim.run()
    assert len(res) == n
    assert all(r.worker not in gone_workers for r in res)


def test_remove_missing_branch_is_a_noop(store):
    sim = _drain_sim(store)
    before = set(sim.workers)
    sim.remove_branch("no-such-branch")
    assert set(sim.workers) == before
    assert set(sim._worker_list) == set(sim.tree.all_workers())


# ------------------------------------------------------------- prewarm
def test_prewarm_starts_instance_ahead_of_traffic(store):
    sim = _drain_sim(store)
    for w in list(sim._worker_list):    # routing may pick any worker
        assert sim.prewarm(w, "fn")
        assert sim.workers[w].instances["fn"], "prewarmed instance must exist"
    sim.submit(Request(fn="fn", arrival_t=1.0))   # after 0.05s cold start
    res = sim.run()
    assert len(res) == 1 and res[0].ok
    assert not res[0].cold_start, "request after prewarm must be warm"
    assert sim.prewarm("no-such-worker", "fn") is False


def test_scaleup_prewarm_reduces_cold_starts():
    _, warm, s_warm = _run_policy("reactive", prewarm_fns=("auto",))
    _, cold, s_cold = _run_policy("reactive", prewarm_fns=None)
    assert warm.summary()["scale_ups"] > 0
    assert s_warm["cold_rate"] <= s_cold["cold_rate"]


# ------------------------------------------------------- control loop
def test_tick_chain_terminates_and_run_returns(store):
    """Ticks re-arm only while real events remain — run() must not spin
    forever on an empty system and must cover the whole workload."""
    sim = _drain_sim(store)
    scaler = Autoscaler("reactive", interval_s=0.5, max_replicas=3)
    sim.attach_autoscaler(scaler)
    n = sim.load(build_scenario("steady", rps=50.0, duration_s=3.0, seed=4))
    res = sim.run()
    assert len(res) == n
    assert scaler.decisions
    assert scaler.decisions[-1].t <= sim.now
    # fresh sim with zero load: the first tick fires, finds nothing, stops
    sim2 = _drain_sim(store)
    sim2.attach_autoscaler(Autoscaler("reactive"))
    assert sim2.run() == []


def test_decision_log_format_stable():
    _, scaler, _ = _run_policy(
        "reactive", overrides=dict(duration_s=5.0, seed=3, base_rps=10.0,
                                   burst_rps=800.0))
    line = scaler.decisions[0].fmt()
    for key in ("t=", "policy=reactive", "replicas=", "desired=", "action=",
                "queue=", "inflight=", "workers=", "arr_rate="):
        assert key in line, line
    assert scaler.decision_log().count("\n") == len(scaler.decisions) - 1

"""Autoscaler control loop: deterministic scaling-regression suite.

The contract under test (ISSUE 2): same seed => byte-identical
scaling-decision log per policy; `reactive` beats the static replicate
baseline under `flash_crowd` on p95/fail at equal-or-lower
replica-seconds; cooldown prevents flapping; min/max replica bounds are
never violated; and `remove_branch` drains safely (no dangling queued or
in-flight requests, no stale worker entries).
"""
import numpy as np
import pytest

from repro.autoscale import (AUTOSCALERS, Autoscaler, build_pool,
                             get_autoscaler, list_autoscalers, replay,
                             load_decision_log, save_decision_log)
from repro.core.config_store import ConfigStore
from repro.core.router import build_leaf
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  summarize)
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario, install_demo_configs

ALL_POLICIES = ("static", "reactive", "target_concurrency", "predictive",
                "slo_aware")

# the benchmark configuration (mirrors bench_autoscaler_scenarios): a
# calm-dominated flash crowd whose bursts saturate the 3-branch static
# fleet; scalers start at 1 branch. Workers are deliberately small
# (1 instance slot) so the operating point stays cheap to simulate.
FLASH = dict(duration_s=30.0, seed=3, base_rps=12.0, burst_rps=1000.0,
             mean_burst_s=2.0, mean_calm_s=10.0)
SCALER = dict(interval_s=0.25, window_s=2.0, min_replicas=1, max_replicas=8,
              workers_per_replica=2, cooldown_s=2.0)


def _run_policy(policy, *, branches=1, scenario="flash_crowd",
                overrides=FLASH, **scaler_kw):
    wl = build_scenario(scenario, **overrides)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(branches, SCALER["workers_per_replica"]),
                    store, SyntheticServiceModel(seed=2), seed=7,
                    worker_capacity_slots=1)
    scaler = Autoscaler(policy, **{**SCALER, **scaler_kw})
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    results = sim.run()
    return sim, scaler, summarize(results)


# ---------------------------------------------------------------- registry
def test_registry_complete():
    assert set(list_autoscalers()) >= set(ALL_POLICIES)
    assert sorted(AUTOSCALERS) == list_autoscalers()
    pol = get_autoscaler("reactive", target_load=2.0)
    assert pol.name == "reactive" and pol.target_load == 2.0
    with pytest.raises(KeyError):
        get_autoscaler("nope")


# ----------------------------------------------------------- determinism
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_same_seed_identical_decision_log(policy):
    """Same seed => byte-identical scaling-decision log (the regression
    contract that makes this archetype possible)."""
    branches = 3 if policy == "static" else 1
    _, a, sa = _run_policy(policy, branches=branches)
    _, b, sb = _run_policy(policy, branches=branches)
    assert len(a.decisions) > 10
    assert a.decision_log() == b.decision_log()
    assert sa == sb


# -------------------------------------------- acceptance: reactive wins
def test_reactive_beats_static_replicate_baseline_under_flash_crowd():
    """`reactive` must beat the paper's static replicate recipe on p95 or
    fail_rate at equal-or-lower replica-seconds (worker-seconds here:
    branches are uniform, so the two are proportional)."""
    _, st, s_static = _run_policy("static", branches=3)
    _, re_, s_react = _run_policy("reactive", branches=1)
    assert (s_react["p95"] < s_static["p95"]
            or s_react["fail_rate"] < s_static["fail_rate"])
    assert re_.worker_seconds <= st.worker_seconds
    assert re_.summary()["scale_ups"] > 0      # it actually scaled


@pytest.mark.parametrize("policy", ("target_concurrency", "predictive"))
def test_other_scalers_also_beat_static_tail(policy):
    _, _, s_static = _run_policy("static", branches=3)
    _, _, s = _run_policy(policy, branches=1)
    assert s["p95"] < s_static["p95"]


# ------------------------------------------------------------- cooldown
def test_cooldown_prevents_flapping():
    """No two applied scale-downs may land inside the cooldown window,
    and disabling cooldown must produce at least as many scale events."""
    _, cooled, _ = _run_policy("reactive", cooldown_s=2.0)
    downs = [d.t for d in cooled.decisions if d.action == "down"]
    changes = [d.t for d in cooled.decisions if d.action in ("up", "down")]
    for t in downs:
        prior = [c for c in changes if c < t]
        if prior:
            assert t - max(prior) >= 2.0 - 1e-9, (t, max(prior))
    _, hot, _ = _run_policy("reactive", cooldown_s=0.0)
    n_cooled = sum(d.action in ("up", "down") for d in cooled.decisions)
    n_hot = sum(d.action in ("up", "down") for d in hot.decisions)
    assert n_hot >= n_cooled
    assert any(d.action == "cooldown" for d in cooled.decisions)


# --------------------------------------------------------------- bounds
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_replica_bounds_never_violated(policy):
    sim, scaler, _ = _run_policy(policy, min_replicas=1, max_replicas=4)
    assert scaler.decisions
    for d in scaler.decisions:
        assert 1 <= d.applied <= 4, d.fmt()
    assert 1 <= len(sim.tree.children) <= 4
    # desired is the raw policy output and may exceed the cap; the clamp
    # must be visible in the log rather than silently rewriting desired
    if any(d.desired > 4 for d in scaler.decisions):
        assert any(d.applied < d.desired for d in scaler.decisions)


def test_min_replicas_floor_holds_when_idle():
    """An idle tail (policy wants 0) must clamp at min_replicas."""
    sim, scaler, _ = _run_policy(
        "reactive", min_replicas=2, max_replicas=6,
        overrides=dict(duration_s=10.0, seed=3, base_rps=10.0,
                       burst_rps=800.0))
    assert all(d.applied >= 2 for d in scaler.decisions)
    assert len(sim.tree.children) >= 2


# --------------------------------------------- remove_branch regression
def _drain_sim(store):
    sim = Simulator(build_pool(2, 2), store, SyntheticServiceModel(seed=2),
                    seed=5)
    return sim


@pytest.fixture
def store():
    s = ConfigStore()
    s.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                         cold_start_s=0.05, idle_timeout_s=2.0))
    return s


def test_remove_branch_drains_queued_and_inflight(store):
    """Seed bug: remove_branch left queued/in-flight requests dangling and
    stale self.workers entries. Every request must now resolve."""
    sim = _drain_sim(store)
    wl = build_scenario("steady", rps=300.0, duration_s=6.0, seed=4)
    n = sim.load(wl)
    sim.run(until=3.0)
    removed_workers = [w for w in sim.tree.children[0].all_workers()]
    sim.remove_branch(sim.tree.children[0].name)
    assert all(w not in sim.workers for w in removed_workers)
    res = sim.run()
    assert len(res) == n                       # nothing dangles
    assert len({r.rid for r in res}) == n
    assert not sim._draining                   # drained workers retired
    late = [r for r in res if r.arrival_t > 3.0]
    assert late and all(r.worker not in removed_workers for r in late)


def test_remove_branch_rerouted_requests_can_still_succeed(store):
    """Queued work on a removed branch re-routes instead of failing when
    the tree still has capacity."""
    sim = _drain_sim(store)
    wl = build_scenario("steady", rps=200.0, duration_s=4.0, seed=6)
    n = sim.load(wl)
    sim.run(until=2.0)
    sim.remove_branch(sim.tree.children[0].name)
    s = summarize(sim.run())
    assert s["n"] == n
    assert s["fail_rate"] < 0.05


def test_remove_then_add_branch_does_not_resurrect_stale_workers(store):
    """Seed bug: add_branch rebuilt its cache from self.workers, which
    still held removed names — routing traffic to dead workers."""
    sim = _drain_sim(store)
    gone = sim.tree.children[0].name
    gone_workers = sim.tree.children[0].all_workers()
    sim.remove_branch(gone)
    sim.add_branch(build_leaf("fresh", ["fx0", "fx1"]))
    assert set(sim._worker_list) == set(sim.tree.all_workers())
    assert all(w not in sim._worker_list for w in gone_workers)
    n = sim.load(build_scenario("steady", rps=100.0, duration_s=3.0, seed=4))
    res = sim.run()
    assert len(res) == n
    assert all(r.worker not in gone_workers for r in res)


def test_remove_missing_branch_is_a_noop(store):
    sim = _drain_sim(store)
    before = set(sim.workers)
    sim.remove_branch("no-such-branch")
    assert set(sim.workers) == before
    assert set(sim._worker_list) == set(sim.tree.all_workers())


# ------------------------------------------------------------- prewarm
def test_prewarm_starts_instance_ahead_of_traffic(store):
    sim = _drain_sim(store)
    for w in list(sim._worker_list):    # routing may pick any worker
        assert sim.prewarm(w, "fn")
        assert sim.workers[w].instances["fn"], "prewarmed instance must exist"
    sim.submit(Request(fn="fn", arrival_t=1.0))   # after 0.05s cold start
    res = sim.run()
    assert len(res) == 1 and res[0].ok
    assert not res[0].cold_start, "request after prewarm must be warm"
    assert sim.prewarm("no-such-worker", "fn") is False


def test_scaleup_prewarm_reduces_cold_starts():
    _, warm, s_warm = _run_policy("reactive", prewarm_fns=("auto",))
    _, cold, s_cold = _run_policy("reactive", prewarm_fns=None)
    assert warm.summary()["scale_ups"] > 0
    assert s_warm["cold_rate"] <= s_cold["cold_rate"]


# ------------------------------------------------------- control loop
def test_tick_chain_terminates_and_run_returns(store):
    """Ticks re-arm only while real events remain — run() must not spin
    forever on an empty system and must cover the whole workload."""
    sim = _drain_sim(store)
    scaler = Autoscaler("reactive", interval_s=0.5, max_replicas=3)
    sim.attach_autoscaler(scaler)
    n = sim.load(build_scenario("steady", rps=50.0, duration_s=3.0, seed=4))
    res = sim.run()
    assert len(res) == n
    assert scaler.decisions
    assert scaler.decisions[-1].t <= sim.now
    # fresh sim with zero load: the first tick fires, finds nothing, stops
    sim2 = _drain_sim(store)
    sim2.attach_autoscaler(Autoscaler("reactive"))
    assert sim2.run() == []


def test_decision_log_format_stable():
    _, scaler, _ = _run_policy(
        "reactive", overrides=dict(duration_s=5.0, seed=3, base_rps=10.0,
                                   burst_rps=800.0))
    line = scaler.decisions[0].fmt()
    for key in ("t=", "policy=reactive", "replicas=", "desired=", "action=",
                "queue=", "inflight=", "workers=", "arr_rate=", "fn_actions="):
        assert key in line, line
    assert scaler.decision_log().count("\n") == len(scaler.decisions) - 1


# ---------------------------------------------------- per-function metrics
def test_metrics_window_carries_per_fn_samples():
    """Samples are keyed down to function granularity: queue/inflight/
    arrival/completion deltas, warm replica count, p95 estimate."""
    # window wide enough to retain the active phase, not just the drain
    _, scaler, _ = _run_policy("reactive", scenario="multi_tenant",
                               overrides=dict(rps=200.0, duration_s=6.0,
                                              seed=3), window_s=30.0)
    names = scaler.window.fn_names()
    assert set(names) == {"chat", "embed", "batch"}
    assert list(names) == sorted(names)          # deterministic order
    total_arr = sum(s.arrivals for s in scaler.window.samples)
    fn_arr = sum(f.arrivals for s in scaler.window.samples for f in s.fns)
    # windows are bounded; compare within the retained samples only
    assert fn_arr == total_arr
    chat = scaler.window.fn_last("chat")
    assert chat is not None and chat.p95_est > 0.0
    assert scaler.window.fn_avg("chat", "completions") > 0.0


def test_fn_sample_p95_estimator_is_deterministic():
    _, a, _ = _run_policy("slo_aware", scenario="multi_tenant",
                          overrides=dict(rps=200.0, duration_s=6.0, seed=3))
    _, b, _ = _run_policy("slo_aware", scenario="multi_tenant",
                          overrides=dict(rps=200.0, duration_s=6.0, seed=3))
    sa, sb = a.window.last(), b.window.last()
    assert sa.fns == sb.fns


# --------------------------------------------- acceptance: slo_aware wins
def _p95_per_fn(results):
    out = {}
    for fn in {r.fn for r in results}:
        lat = np.array([r.latency for r in results if r.ok and r.fn == fn])
        out[fn] = float(np.percentile(lat, 95)) if len(lat) else float("nan")
    return out


def test_slo_aware_meets_slo_cheaper_than_static_on_flash_crowd():
    """The headline SLO contract: on `flash_crowd` the slo_aware policy
    must keep every function's p95 below the scenario's `slo_p95_s` while
    spending fewer worker-seconds than the static replicate recipe."""
    wl = build_scenario("flash_crowd", **FLASH)
    targets = wl.slo_targets()
    assert targets == {"fn": 1.0}            # scenario carries its SLO

    sim_s, st, _ = _run_policy("static", branches=3)
    pol = get_autoscaler("slo_aware", slo_p95_s=targets)
    sim_a, sc, _ = _run_policy(pol, branches=1)

    p95 = _p95_per_fn(sim_a.results)
    for fn, slo in targets.items():
        assert p95[fn] < slo, (fn, p95[fn], slo)
    assert sc.worker_seconds < st.worker_seconds
    assert sc.summary()["scale_ups"] > 0     # it actually scaled
    # and it used the per-function control plane, not just branches
    assert any(d.fn_deltas for d in sc.decisions)


def test_slo_aware_prewarms_hot_fn_and_reaps_idle_fn():
    pol = get_autoscaler("slo_aware", slo_p95_s={"fn": 1.0})
    _, sc, _ = _run_policy(pol, branches=1)
    deltas = [dict(d.fn_deltas) for d in sc.decisions if d.fn_deltas]
    assert any(v > 0 for d in deltas for v in d.values()), "no prewarm"
    assert any(v < 0 for d in deltas for v in d.values()), "no reap"


# ----------------------------------------------------- decision-log replay
def test_replay_reproduces_decision_sequence_exactly(tmp_path):
    """Structured decision records, re-applied on a same-seed run, must
    reproduce the original decision log byte-for-byte (and the same
    request results) — the counterfactual-replay regression contract."""
    pol = get_autoscaler("slo_aware", slo_p95_s={"fn": 1.0})
    sim1, sc1, s1 = _run_policy(pol, branches=1)

    path = tmp_path / "decisions.json"
    save_decision_log(sc1.decision_records(), str(path))
    records = load_decision_log(str(path))
    assert records == sc1.decision_records()     # JSON round-trip is exact

    wl = build_scenario("flash_crowd", **FLASH)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim2 = Simulator(build_pool(1, SCALER["workers_per_replica"]), store,
                     SyntheticServiceModel(seed=2), seed=7,
                     worker_capacity_slots=1)
    sc2 = replay(records, **SCALER)
    sim2.attach_autoscaler(sc2)
    sim2.load(wl)
    sim2.run()
    assert sc2.decision_log() == sc1.decision_log()
    assert summarize(sim2.results) == s1


def test_replay_holds_steady_past_end_of_recording(store):
    sim = _drain_sim(store)
    sc = replay([], interval_s=0.5)
    sim.attach_autoscaler(sc)
    n = sim.load(build_scenario("steady", rps=50.0, duration_s=3.0, seed=4))
    res = sim.run()
    assert len(res) == n
    assert all(d.action in ("hold", "bound") for d in sc.decisions)


# -------------------------------------------- per-function prewarm / reap
def test_reap_removes_one_idle_instance(store):
    # explicit cold_start_s=0.0 (the ISSUE-3 falsy-zero fix): replicas
    # are ready the instant they are prewarmed
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.0, idle_timeout_s=2.0))
    sim = _drain_sim(store)
    w = sim._worker_list[0]
    assert sim.prewarm(w, "fn") and sim.prewarm(w, "fn")
    assert len(sim.workers[w].replica_sets["fn"].instances) == 2
    assert sim.reap(w, "fn")
    assert len(sim.workers[w].replica_sets["fn"].instances) == 1
    assert sim.reap("no-such-worker", "fn") is False
    assert sim.reap(w, "no-instances-fn") is False


# ------------------------------------- scale-down interplay (ISSUE 3 sat.)
def test_remove_branch_while_instances_still_warming(store):
    """Branch removal racing a cold start: queued work re-routes, and the
    still-queued idle_check/poke events for the vanished instances must
    no-op instead of resurrecting or crashing the drained worker."""
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             cold_start_s=1.5, idle_timeout_s=2.0))
    sim = _drain_sim(store)
    wl = build_scenario("steady", rps=120.0, duration_s=4.0, seed=6)
    n = sim.load(wl)
    gone = sim.tree.children[0].name
    gone_workers = sim.tree.children[0].all_workers()
    sim.run(until=0.5)                  # mid cold start: instances warming
    warming = [i for w in gone_workers
               for i in sim.workers[w].iid_index.values()
               if i.ready_t > sim.now]
    assert warming, "test must race an in-flight cold start"
    sim.remove_branch(gone)
    res = sim.run()
    assert len(res) == n                # every request resolves exactly once
    assert len({r.rid for r in res}) == n
    assert not sim._draining
    late = [r for r in res if r.arrival_t > 0.5]
    assert late and all(r.worker not in gone_workers for r in late)


def test_queued_idle_check_for_removed_branch_is_noop(store):
    sim = _drain_sim(store)
    w = sim._worker_list[0]
    branch = sim.tree.children[0].name
    assert sim.prewarm(w, "fn")         # schedules idle_check for inst
    sim.remove_branch(branch)           # worker gone before check fires
    sim.run()                           # must not raise
    assert w not in sim.workers


def test_summarize_all_failed_results():
    """summarize() on an all-failed set must not die on the empty latency
    array (p50/p95/p99/mean are NaN, throughput 0, fail_rate 1)."""
    from repro.core.types import RequestResult
    res = [RequestResult(rid=i, fn="fn", ok=False, arrival_t=float(i),
                         start_t=float(i), finish_t=float(i) + 0.5,
                         cold_start=False, worker="w", instance="-",
                         error="queue timeout") for i in range(3)]
    s = summarize(res)
    assert s["n"] == 3 and s["ok"] == 0 and s["fail_rate"] == 1.0
    assert np.isnan(s["p50"]) and np.isnan(s["p95"]) and np.isnan(s["p99"])
    assert np.isnan(s["mean"])
    assert s["throughput"] == 0.0

"""Azure Functions public-trace converter (ISSUE-5 satellite).

Contracts: the CSV parser round-trips the checked-in fixture (header,
comments, zero bins); minute counts expand to deterministic evenly
spaced arrivals whose per-bin totals equal the trace; `time_scale`
compresses wall time without changing counts; the `trace_replay`
scenario ingests the format end-to-end through a seeded simulator with
byte-identical request streams.
"""
import os

import pytest

from repro.workloads import build_scenario
from repro.workloads.azure import (BIN_S, azure_trace_arrivals,
                                   azure_trace_iats, azure_trace_streams,
                                   load_azure_trace, minute_counts_to_iats,
                                   select_function, trace_functions)

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "azure_sample.csv")


# ------------------------------------------------------------------ parsing
def test_load_fixture_rows():
    rows = load_azure_trace(FIXTURE)
    assert len(rows) == 3                  # header + comments skipped
    by_owner = {r.owner for r in rows}
    assert by_owner == {"ownerA", "ownerB"}
    f = rows[0]
    assert f.func.startswith("f3e2a1b4")
    assert f.counts == (3, 0, 2, 0, 0, 5, 1, 0)
    assert f.total == 11
    assert f.key() == "f3e2a1b4"


def test_load_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.csv"
    bad.write_text("onlythree,cols,here\n")
    with pytest.raises(ValueError):
        load_azure_trace(str(bad))
    empty = tmp_path / "empty.csv"
    empty.write_text("# nothing but comments\n")
    with pytest.raises(ValueError):
        load_azure_trace(str(empty))


def test_select_function_by_prefix_and_busiest():
    rows = load_azure_trace(FIXTURE)
    assert select_function(rows, "a1b2").trigger == "timer"
    with pytest.raises(KeyError):
        select_function(rows, "zzzz")
    # busiest: queue fn has 12, chat-like http fn has 11
    assert select_function(rows).func.startswith("9f8e7d6c")


def test_trace_functions_index():
    idx = trace_functions(FIXTURE)
    assert idx == {"f3e2a1b4": 11, "a1b2c3d4": 4, "9f8e7d6c": 12}


# -------------------------------------------------------------- expansion
def test_minute_counts_expand_evenly_and_deterministically():
    iats = minute_counts_to_iats([2, 0, 1])
    # 2 arrivals centred in minute 0 -> 15s, 45s; 1 centred in minute 2
    # -> 150s; IATs are the successive differences
    assert iats == [15.0, 30.0, 105.0]
    assert minute_counts_to_iats([2, 0, 1]) == iats     # pure function


def test_per_bin_totals_match_trace():
    rows = load_azure_trace(FIXTURE)
    row = select_function(rows, "f3e2")
    iats = azure_trace_iats(FIXTURE, function="f3e2")
    assert len(iats) == row.total
    t, seen = 0.0, [0] * len(row.counts)
    for iat in iats:
        t += iat
        seen[int(t // BIN_S)] += 1
    assert tuple(seen) == row.counts


def test_time_scale_compresses_without_changing_counts():
    full = azure_trace_iats(FIXTURE, function="f3e2")
    fast = azure_trace_iats(FIXTURE, function="f3e2", time_scale=0.01)
    assert len(full) == len(fast)
    assert all(abs(a - b * 0.01) < 1e-9 for a, b in zip(fast, full))
    with pytest.raises(ValueError):
        azure_trace_iats(FIXTURE, time_scale=0.0)


def test_aggregate_sums_all_functions():
    iats = azure_trace_iats(FIXTURE, aggregate=True)
    assert len(iats) == 11 + 4 + 12


# ------------------------------------------------------------- end-to-end
def test_trace_replay_scenario_ingests_azure_format():
    wl = build_scenario("trace_replay", path=FIXTURE, fmt="azure",
                        function="f3e2", time_scale=0.01, seed=5)
    reqs = wl.generate()
    assert len(reqs) == 11
    assert reqs == wl.generate()           # seeded: byte-identical stream
    assert [r.rid for r in reqs] == list(range(11))
    # arrival times live inside the compressed 8-bin horizon
    assert 0.0 < reqs[0].arrival_t < reqs[-1].arrival_t <= 8 * 60 * 0.01


def test_trace_replay_scenario_runs_through_simulator():
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.workloads import install_demo_configs

    wl = build_scenario("trace_replay", path=FIXTURE, fmt="azure",
                        aggregate=True, time_scale=0.01, seed=5)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=7)
    n = sim.load(wl)
    res = sim.run()
    assert n == 27 and len(res) == n


def test_trace_replay_rejects_unknown_format():
    with pytest.raises(ValueError):
        build_scenario("trace_replay", path=FIXTURE, fmt="parquet")


def test_arrivals_loop_tiles_the_trace():
    arr = azure_trace_arrivals(FIXTURE, function="a1b2", time_scale=0.01,
                               loop=True)
    import random
    times = []
    for t in arr.times(10.0, random.Random(0)):
        times.append(t)
        if len(times) > 40:
            break
    assert len(times) > 4                  # looped past one trace pass
    assert times == sorted(times)


def test_loop_preserves_day_shape_and_rate(tmp_path):
    """Code-review regression: a trace with traffic only early in the
    day must NOT replay at a multiple of its traced rate when looped —
    the cycle period is the full bin horizon, idle tail included."""
    import random
    csv = tmp_path / "sparse.csv"
    # 60 invocations in minute 0 of a 10-minute trace: traced rate is
    # 6/min averaged over the day, not 60/min
    csv.write_text("o,a,f1f1f1f1,http,60,0,0,0,0,0,0,0,0,0\n")
    arr = azure_trace_arrivals(str(csv), loop=True)
    horizon = 3600.0                       # six 10-minute cycles
    times = list(arr.times(horizon, random.Random(0)))
    assert len(times) == 6 * 60            # not 3630 (prefix-only tiling)
    # every arrival sits in the first minute of its own 600 s cycle
    assert all((t % 600.0) < 60.0 for t in times)
    assert abs(arr.mean_rate() - 60 / 600.0) < 1e-9


# ------------------------------------------- per-row streams (ISSUE 10)
def test_azure_trace_streams_one_stream_per_row():
    """One trace file ⇒ one self-contained tenant stream per function
    row: busiest-first order, trigger-derived priority/SLO, disjoint
    rid ranges, per-bin-exact replay counts."""
    streams = azure_trace_streams(FIXTURE, time_scale=0.01)
    assert [s.profiles[0].fn for s in streams] == \
        ["9f8e7d6c", "f3e2a1b4", "a1b2c3d4"]       # by -total, then hash
    assert [s.profiles[0].weight for s in streams] == [12.0, 11.0, 4.0]
    # trigger classes: queue -> batch/5s, http -> interactive/0.5s,
    # timer -> batch/no SLO (all SLOs in scaled time)
    assert [p.priority for p in (s.profiles[0] for s in streams)] == \
        ["batch", "interactive", "batch"]
    assert streams[0].profiles[0].slo_p95_s == pytest.approx(5.0 * 0.01)
    assert streams[1].profiles[0].slo_p95_s == pytest.approx(0.5 * 0.01)
    assert streams[2].profiles[0].slo_p95_s is None
    # rid stride: next power of ten above the busiest total (12) is 100
    reqs = [s.generate() for s in streams]
    assert [len(r) for r in reqs] == [12, 11, 4]
    assert [r[0].rid for r in reqs] == [0, 100, 200]
    rids = [r.rid for rs in reqs for r in rs]
    assert len(set(rids)) == len(rids)             # globally disjoint
    # deterministic: regeneration is byte-identical
    again = azure_trace_streams(FIXTURE, time_scale=0.01)
    assert [s.generate() for s in again] == reqs
    # arrivals live inside the compressed 8-bin horizon
    horizon = 8 * BIN_S * 0.01
    assert all(0.0 < r.arrival_t <= horizon for rs in reqs for r in rs)


def test_azure_trace_streams_filtering_and_stride():
    assert [s.profiles[0].fn
            for s in azure_trace_streams(FIXTURE, min_total=5)] == \
        ["9f8e7d6c", "f3e2a1b4"]
    only = azure_trace_streams(FIXTURE, max_functions=1)
    assert [s.profiles[0].fn for s in only] == ["9f8e7d6c"]
    custom = azure_trace_streams(FIXTURE, rid_stride=10**6)
    assert [s.generate()[0].rid for s in custom] == [0, 10**6, 2 * 10**6]
    with pytest.raises(ValueError):
        azure_trace_streams(FIXTURE, min_total=100)


def test_azure_trace_streams_run_and_partition():
    """The per-row streams drive a multi-function simulator end to end,
    and bucket by the same tenant hash the parallel runner partitions
    on — every stream lands in exactly one bucket."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree, tenant_index
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.core.types import FunctionConfig
    from repro.parallel import partition_streams

    streams = azure_trace_streams(FIXTURE, time_scale=0.01)
    store = ConfigStore()
    for s in streams:
        store.put(FunctionConfig(name=s.profiles[0].fn, arch="tiny_lm",
                                 concurrency=2, cold_start_s=0.05))
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=7)
    assert sum(sim.load(s) for s in streams) == 27
    res = sim.run()
    assert len(res) == 27
    assert sim.arrivals_by_fn == {"9f8e7d6c": 12, "f3e2a1b4": 11,
                                  "a1b2c3d4": 4}
    buckets = partition_streams(streams, 2)
    assert sum(len(b) for b in buckets) == 3
    for k, bucket in enumerate(buckets):
        assert all(tenant_index(s.profiles[0].fn, 2) == k for s in bucket)


def test_trace_replay_rejects_azure_kwargs_on_iat_format(tmp_path):
    """Code-review regression: azure-only kwargs with the default
    fmt='iat' must raise, not silently replay the wrong stream."""
    iat = tmp_path / "t.iat"
    iat.write_text("0.5\n0.5\n")
    for kw in (dict(function="f3e2"), dict(aggregate=True),
               dict(time_scale=0.01)):
        with pytest.raises(ValueError):
            build_scenario("trace_replay", path=str(iat), **kw)
    # plain IAT replay still works
    wl = build_scenario("trace_replay", path=str(iat), seed=1)
    assert len(wl.generate()) == 2

"""Workflow layer (ISSUE 7): DAG composition, critical-path math,
deterministic stage triggering, stage-lookahead prewarm, and the
workflow_aware routing acceptance A/B.

The byte-identity contract for workflow-*free* runs stays pinned by the
golden suites (test_scheduling / test_placement / test_faults) — the
shared ``digest_sim`` projection now also covers ``workflow_results``,
which is empty there, so those digests did not move.
"""

import pytest

from repro.core.config_store import ConfigStore
from repro.core.router import build_tree
from repro.core.simulator import Simulator, SyntheticServiceModel
from repro.core.types import FunctionConfig
from repro.workloads import (PoissonArrivals, SizeDist, StageSpec,
                             WorkflowSpec, WorkflowWorkload, build_scenario,
                             install_demo_configs, summarize_workflows)

from _prop_drivers import digest_sim as _digest  # noqa: E402  (shared def)


def _stage(name, fn="f", deps=(), **kw):
    return StageSpec(name=name, fn=fn, deps=tuple(deps), **kw)


# ------------------------------------------------------- spec validation
def test_spec_rejects_empty_and_bad_fields():
    with pytest.raises(ValueError, match="at least one stage"):
        WorkflowSpec(name="w", stages=())
    with pytest.raises(ValueError, match="slo_s"):
        WorkflowSpec(name="w", stages=(_stage("a"),), slo_s=0.0)
    with pytest.raises(ValueError, match="duplicate stage"):
        WorkflowSpec(name="w", stages=(_stage("a"), _stage("a")))
    with pytest.raises(ValueError, match="fanout"):
        WorkflowSpec(name="w", stages=(_stage("a", fanout=0),))
    with pytest.raises(ValueError, match="weight"):
        WorkflowSpec(name="w", stages=(_stage("a", weight=0.0),))
    with pytest.raises(ValueError, match="prob"):
        WorkflowSpec(name="w", stages=(_stage("a", prob=1.5),))


def test_spec_requires_declaration_after_dependencies():
    """Declaration order is the topological order: forward (or unknown)
    deps are rejected up front, which also makes cycles unrepresentable."""
    with pytest.raises(ValueError, match="not .*declared before"):
        WorkflowSpec(name="w", stages=(
            _stage("a", deps=("b",)), _stage("b")))
    with pytest.raises(ValueError, match="not .*declared before"):
        WorkflowSpec(name="w", stages=(_stage("a", deps=("ghost",)),))


# ------------------------------------------- critical-path decomposition
def test_critical_path_and_deadline_fractions_on_diamond():
    #      a(1) -> b(3) -> d(1)     critical: a,b,d (weight 5)
    #        \--> c(1) ---/         c has float 2
    spec = WorkflowSpec(name="w", slo_s=10.0, stages=(
        _stage("a", weight=1.0),
        _stage("b", deps=("a",), weight=3.0),
        _stage("c", deps=("a",), weight=1.0),
        _stage("d", deps=("b", "c"), weight=1.0)))
    assert spec.path_weight == 5.0
    assert spec.critical == {"a", "b", "d"}
    assert spec.deadline_frac == pytest.approx(
        {"a": 0.2, "b": 0.8, "c": 0.4, "d": 1.0})
    assert spec.roots == ("a",)
    assert spec.successors == {"a": ("b", "c"), "b": ("d",),
                               "c": ("d",), "d": ()}


def test_fanout_counts_stage_weight_once():
    """Parallel fan-out tasks run concurrently: a stage contributes its
    weight once to the path regardless of width."""
    spec = WorkflowSpec(name="w", stages=(
        _stage("split", weight=1.0),
        _stage("map", deps=("split",), fanout=16, weight=2.0),
        _stage("reduce", deps=("map",), weight=1.0)))
    assert spec.path_weight == 4.0
    assert spec.critical == {"split", "map", "reduce"}
    assert spec.tasks_per_instance == 18
    assert spec.rid_offset == {"split": 0, "map": 1, "reduce": 17}


# ------------------------------------------------------- execution semantics
def _run_spec(spec, *, seed=1, rate=4.0, duration_s=2.0, policy="workflow_aware",
              prewarm_next=True, sim_kw=None):
    wl = WorkflowWorkload(PoissonArrivals(rate=rate), spec,
                          duration_s=duration_s, seed=seed,
                          prewarm_next=prewarm_next)
    store = ConfigStore()
    for fn in wl.fns():
        store.put(FunctionConfig(name=fn, arch="tiny_lm", concurrency=2,
                                 cold_start_s=0.1))
    sim = Simulator(build_tree(4, fanout=2, leaf_policy=policy,
                               inner_policy=policy),
                    store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                    seed=7, **(sim_kw or {}))
    n = sim.load(wl)
    sim.run()
    return sim, n


CHAIN = WorkflowSpec(name="chain", slo_s=4.0, stages=(
    _stage("pre", fn="f"),
    _stage("mid", fn="g", deps=("pre",), weight=2.0),
    _stage("post", fn="f", deps=("mid",))))

FANOUT = WorkflowSpec(name="mr", slo_s=4.0, stages=(
    _stage("split", fn="f"),
    _stage("map", fn="g", deps=("split",), fanout=4, weight=2.0),
    _stage("reduce", fn="f", deps=("map",))))


def test_chain_stages_execute_in_dependency_order():
    sim, n = _run_spec(CHAIN)
    assert n > 0 and len(sim.workflow_results) == n
    assert all(w.ok for w in sim.workflow_results)
    by_wf = {}
    for r in sim.results:
        by_wf.setdefault(r.wf, {})[r.stage] = r
    for wf, stages in by_wf.items():
        assert set(stages) == {"pre", "mid", "post"}
        assert stages["pre"].finish_t <= stages["mid"].arrival_t
        assert stages["mid"].finish_t <= stages["post"].arrival_t
    # per-stage deadlines decompose the end-to-end SLO along the path
    inst = next(iter(sim.workflows.instances.values()))
    assert inst.spec.deadline_frac == pytest.approx(
        {"pre": 0.25, "mid": 0.75, "post": 1.0})


def test_fanout_join_waits_for_all_tasks():
    sim, n = _run_spec(FANOUT)
    assert all(w.ok for w in sim.workflow_results)
    by_wf = {}
    for r in sim.results:
        by_wf.setdefault(r.wf, {}).setdefault(r.stage, []).append(r)
    for wf, stages in by_wf.items():
        assert len(stages["map"]) == 4
        gate = max(r.finish_t for r in stages["map"])
        assert stages["reduce"][0].arrival_t >= gate - 1e-9
    # every map task carries its sibling index for waterfill placement
    tasks = sorted(r.rid - min(x.rid for x in stages["map"])
                   for r in stages["map"])
    assert tasks == [0, 1, 2, 3]


def test_conditional_branch_skips_without_running():
    spec = WorkflowSpec(name="cond", slo_s=4.0, stages=(
        _stage("a", fn="f"),
        _stage("maybe", fn="g", deps=("a",), prob=0.5),
        _stage("end", fn="f", deps=("maybe",))))
    sim, n = _run_spec(spec, duration_s=4.0)
    ran = {(r.wf, r.stage) for r in sim.results}
    skipped = taken = 0
    for wf, inst in sim.workflows.instances.items():
        if "maybe" in inst.active:
            taken += 1
            assert (wf, "maybe") in ran
        else:
            skipped += 1
            assert (wf, "maybe") not in ran
        assert (wf, "end") in ran        # joins resolve through the skip
    assert skipped > 0 and taken > 0     # both outcomes exercised
    assert all(w.ok for w in sim.workflow_results)


def test_same_seed_runs_are_byte_identical():
    a, _ = _run_spec(FANOUT, seed=3)
    b, _ = _run_spec(FANOUT, seed=3)
    assert _digest(a) == _digest(b)
    assert a.workflows.stage_log == b.workflows.stage_log
    c, _ = _run_spec(FANOUT, seed=4)     # and the digest is sensitive
    assert _digest(c) != _digest(a)


def test_workflow_free_run_has_empty_workflow_results():
    """A plain (non-workflow) run carries no workflow state at all, so
    the digest extension covering ``workflow_results`` is a no-op there
    — which is what keeps the PR 3-6 golden digests byte-identical."""
    wl = build_scenario("steady", rps=50.0, duration_s=2.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(4, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=7)
    sim.load(wl)
    sim.run()
    assert sim.workflows is None
    assert sim.workflow_results == []


# ------------------------------------------------- stage-lookahead prewarm
def test_prewarm_next_warms_successor_functions():
    sim, _ = _run_spec(CHAIN, prewarm_next=True)
    off, _ = _run_spec(CHAIN, prewarm_next=False)
    assert sim.workflows.prewarms > 0
    assert off.workflows.prewarms == 0


def test_workflow_prewarm_skips_already_warm_functions():
    """The control-plane hook only places a prewarm when no healthy
    worker has a replica of the stage's function."""
    store = ConfigStore()
    store.put(FunctionConfig(name="f", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.1))
    sim = Simulator(build_tree(4, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=7)
    placed = sim.control.workflow_prewarm("f")
    assert placed is not None            # nothing warm: places one
    assert sim.control.workflow_prewarm("f") is None  # now warm: no-op


# --------------------------------------- fixed-seed property-driver lane
@pytest.mark.parametrize("seed", range(4))
def test_workflow_dag_invariants_fixed_seeds(seed):
    from _prop_drivers import run_workflow_dag_ops
    assert run_workflow_dag_ops(seed) > 0


# --------------------------------------------- scenarios + summarization
def test_workflow_scenarios_registered():
    for name in ("ml_pipeline", "etl_fanout"):
        wl = build_scenario(name, duration_s=2.0, seed=1)
        assert isinstance(wl, WorkflowWorkload)
        insts = wl.generate()
        assert insts
        # contiguous non-overlapping rid blocks
        assert len({i.wf for i in insts}) == len(insts)
        assert all(i.wf % wl.spec.tasks_per_instance == 0 for i in insts)


def test_summarize_workflows_percentiles():
    from repro.workloads import WorkflowResult
    rs = [WorkflowResult(wf=i, name="w", ok=True, arrival_t=0.0,
                         finish_t=float(i + 1), tasks=3)
          for i in range(100)]
    s = summarize_workflows(rs)
    assert s["n"] == 100 and s["ok"] == 100 and s["fail_rate"] == 0.0
    assert s["p50"] == 50.0 and s["p95"] == 95.0 and s["p99"] == 99.0
    assert summarize_workflows([]) == {"n": 0}


def test_failed_stage_fails_instance():
    spec = WorkflowSpec(name="w", slo_s=4.0, stages=(
        _stage("a", fn="f"), _stage("b", fn="f", deps=("a",))))
    wl = WorkflowWorkload(PoissonArrivals(rate=2.0), spec, duration_s=1.0,
                          seed=1)
    store = ConfigStore()
    store.put(FunctionConfig(name="f", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.1))
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2, fail_rate=1.0), seed=7)
    n = sim.load(wl)
    sim.run()
    assert len(sim.workflow_results) == n
    assert all(not w.ok and "failed" in w.error
               for w in sim.workflow_results)
    # failed instances never submit successors
    assert not any(r.stage == "b" for r in sim.results)


# --------------------------------------------- acceptance: the routing A/B
def _ab_cell(scen, policy, seed):
    wl = build_scenario(scen, duration_s=40.0, seed=seed)
    store = ConfigStore()
    install_demo_configs(store, wl)
    # equal worker-seconds by construction: identical fixed tree, no
    # autoscaler, in both cells — the only delta is the routing policy
    sim = Simulator(build_tree(8, fanout=4, leaf_policy=policy,
                               inner_policy=policy),
                    store, SyntheticServiceModel(seed=2), seed=11)
    sim.load(wl)
    sim.run()
    return summarize_workflows(sim.workflow_results)


@pytest.mark.parametrize("scen,seed", [("ml_pipeline", 13),
                                       ("etl_fanout", 9)])
def test_workflow_aware_beats_deadline_aware_on_e2e_p95(scen, seed):
    """The ISSUE-7 acceptance criterion: at equal worker-seconds,
    DAG-aware routing (eager critical-path cold starts + affinity
    tie-break + sibling waterfill) beats stage-blind deadline_aware on
    end-to-end workflow p95 on both canonical workflow scenarios."""
    blind = _ab_cell(scen, "deadline_aware", seed)
    aware = _ab_cell(scen, "workflow_aware", seed)
    # the service model's intrinsic 0.2%-per-task failure rate fails a
    # few instances in both cells; p95 is over completed instances
    assert aware["fail_rate"] < 0.05 and blind["fail_rate"] < 0.05
    assert aware["p95"] < blind["p95"], (scen, seed, blind, aware)

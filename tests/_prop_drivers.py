"""Shared op-sequence drivers for the scheduling-core property suites.

Each driver takes one integer seed, builds a random operation sequence
from it, mirrors every step against a flat reference model, and asserts
the invariants after each op. ``tests/test_scheduling.py`` runs them over
fixed seeds (tier-1, no optional deps); ``tests/test_property.py`` wraps
the same drivers in hypothesis ``@given(integers())`` so CI explores the
seed space — one body, two harnesses, so the properties can never drift
between the lanes.
"""
import itertools
import random

from repro.core.scheduling import FnQueues, Instance
from repro.core.types import Request

FNS = ("a", "b", "c")


def digest_sim(sim) -> str:
    """sha256[:16] over a run's full result + telemetry streams — THE
    byte-identity projection every golden/equivalence suite compares.
    One definition: this delegates to
    ``repro.core.simulator.stream_digest`` (which also accepts a
    ``repro.parallel.MergedRun``), so the suites can never drift apart
    on which fields "byte-identical" covers."""
    from repro.core.simulator import stream_digest
    return stream_digest(sim)


def run_fnqueues_ops(seed: int, n_ops: int = 200) -> int:
    """Global-FIFO ordering + deadline-heap consistency of FnQueues under
    arbitrary interleaved push / serve / expire / drain sequences.

    A flat list of (request, timeout) in push order is the reference:
    iteration order, per-fn depths, expiry sets (strict ``now - arrival >
    timeout``, in arrival order), and drains must all agree with it at
    every step. Returns the number of ops checked."""
    rng = random.Random(seed)
    q = FnQueues()
    ref = []                   # live (req, timeout_s) in arrival order
    now = 0.0
    rid = itertools.count()
    for _ in range(n_ops):
        op = rng.random()
        now += rng.random() * 0.1
        if op < 0.55:                                      # push
            r = Request(fn=rng.choice(FNS), arrival_t=now, rid=next(rid))
            timeout = rng.choice([0.05, 0.2, 0.5, 2.0])
            q.push(r, timeout_s=timeout)
            ref.append((r, timeout))
        elif op < 0.75 and len(q):                         # serve a head
            fn = rng.choice(q.active_fns())
            head = q.scan_head(fn)
            q.pop_head(fn)
            q.mark_served(head)
            ref = [e for e in ref if e[0] is not head]
        elif op < 0.95:                                    # flush timeouts
            expired = q.pop_expired(now)
            want = [r for r, to in ref if now - r.arrival_t > to]
            assert [r.rid for r in expired] == [r.rid for r in want]
            gone = set(id(r) for r in want)
            ref = [e for e in ref if id(e[0]) not in gone]
            # deadline-heap consistency: nothing live is past its deadline
            assert not any(now - r.arrival_t > to for r, to in ref)
        else:                                              # drain (failover)
            drained = q.drain_all()
            assert [r.rid for r in drained] == [e[0].rid for e in ref]
            ref = []
        # global FIFO: iteration equals the reference, in arrival order
        assert len(q) == len(ref)
        assert [r.rid for r in q] == [e[0].rid for e in ref]
        for fn in FNS:
            assert q.depth(fn) == sum(e[0].fn == fn for e in ref)
        assert sorted(q.active_fns()) == sorted(
            {e[0].fn for e in ref})
    return n_ops


def run_replica_index_ops(seed: int, n_ops: int = 150) -> int:
    """FunctionReplicaSet index <-> iid-map agreement (plus the
    incremental memory and slots_total counters) on a simulator worker
    under random add / busy-churn / remove / clear sequences."""
    from repro.core.simulator import _Worker
    rng = random.Random(seed)
    w = _Worker("w", capacity_slots=10 ** 9,
                memory_mb=rng.choice([None, 65536.0]))
    iids = itertools.count()
    live = []
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.5:                                       # start a replica
            inst = Instance(iid=f"w/i{next(iids)}", fn=rng.choice(FNS),
                            slots=rng.choice([0, 1, 2, 4]),
                            memory_mb=rng.choice([128.0, 256.0, 1536.0]))
            w.add_instance(inst)
            live.append(inst)
        elif op < 0.75 and live:                           # reap one
            # the platform only reaps *idle* replicas (reap/idle_check
            # both require busy == 0) — model exactly that
            idle = [i for i in live if i.busy == 0]
            if idle:
                inst = idle[rng.randrange(len(idle))]
                live.remove(inst)
                w.remove_instance(inst)
        elif op < 0.97:                                    # occupancy churn
            if live:
                inst = rng.choice(live)
                delta = 1 if inst.busy == 0 or rng.random() < 0.6 else -1
                w.note_busy(inst, delta)
        else:                                              # worker failure
            w.clear_instances()
            live = []
        # index <-> iid-map agreement
        assert w.total_instances == len(live)
        in_sets = {i.iid for rs in w.replica_sets.values()
                   for i in rs.instances}
        assert set(w.iid_index) == in_sets == {i.iid for i in live}
        for fn, rs in w.replica_sets.items():
            assert all(i.fn == fn for i in rs.instances)
            assert abs(rs.mem_mb
                       - sum(i.memory_mb for i in rs.instances)) < 1e-6
        # incremental counters match flat rescans
        assert abs(w.memory_used_mb
                   - sum(i.memory_mb for i in live)) < 1e-6
        flat_slots = sum((i.slots if i.slots > 0 else max(i.busy, 1))
                        for i in live) or 1
        assert w.slots_total() == flat_slots
        assert w.inflight() == sum(i.busy for i in live)
    return n_ops


def run_event_backend_ops(seed: int, n_ops: int = 400) -> int:
    """ISSUE-5 invariant: every event-queue backend drains an arbitrary
    interleaved push/pop stream in identical ``(t, seq)`` order.

    One :class:`~repro.core.events.EventEngine` per registered backend is
    fed the same operation sequence (pushes at mixed horizons — near-now
    jitter, mid-range, far future — the simulator's actual shape, plus a
    bulk-load prefix to exercise the sharded backend's staged/sealed
    regimes and ``pop(until)`` horizons); after every op all engines must
    agree on the popped entries, the pending count, and the
    pending-real accounting. Returns the number of ops checked."""
    from repro.core.events import EventEngine, list_event_backends

    rng = random.Random(seed)
    engines = [EventEngine(b, background=("tick",))
               for b in list_event_backends()]
    ref = engines[0]
    now = 0.0
    # bulk-load prefix in nondecreasing time order (the sim.load pattern)
    t = 0.0
    for i in range(rng.randrange(0, 100)):
        t += rng.random() * 0.2
        kind = "tick" if rng.random() < 0.1 else "ev"
        for e in engines:
            e.push(t, kind, i)
    for i in range(n_ops):
        op = rng.random()
        if op < 0.55:                                      # push
            horizon = rng.choice([0.01, 0.5, 10.0, 1000.0])
            tt = now + rng.random() * horizon
            kind = "tick" if rng.random() < 0.1 else "ev"
            for e in engines:
                e.push(tt, kind, i)
        elif op < 0.85:                                    # pop
            popped = [e.pop() for e in engines]
            assert all(p == popped[0] for p in popped), (seed, i, popped)
            if popped[0] is not None:
                now = max(now, popped[0][0])
        else:                                              # pop with horizon
            until = now + rng.random() * 5.0
            popped = [e.pop(until=until) for e in engines]
            assert all(p == popped[0] for p in popped), (seed, i, popped)
            if popped[0] is not None:
                now = max(now, popped[0][0])
        assert all(len(e) == len(ref) for e in engines)
        assert all(e.pending_real == ref.pending_real for e in engines)
    while True:                                            # drain the rest
        popped = [e.pop() for e in engines]
        assert all(p == popped[0] for p in popped)
        if popped[0] is None:
            break
    assert all(len(e) == 0 and e.pending_real == 0 for e in engines)
    return n_ops


def run_push_bulk_ops(seed: int, n_ops: int = 80) -> int:
    """ISSUE-8 invariant: ``push_bulk``/``pop_batch`` on every backend
    are order-identical to per-entry ``push``/``pop`` on the single-heap
    reference, under arbitrary interleavings of scalar pushes, bulk runs
    (sorted / shuffled / tied / numpy / list / with payloads, small
    enough for the per-entry sealed path and large enough for the
    vectorized one), horizon pops, and greedy batch pops. After every op
    the engines agree on length and pending-real accounting; at the end
    both drain to the same byte-identical stream. Returns ops checked."""
    import numpy as np

    from repro.core.events import EventEngine

    rng = random.Random(seed)
    nprng = np.random.default_rng(seed)
    eng = EventEngine("sharded", background=("tick",))
    ref = EventEngine("single_heap", background=("tick",))
    t_hi = 0.0
    # bulk-load prefix: whole-horizon sorted runs (the load_bulk shape),
    # sealed by a pop burst so later runs hit the sealed insert paths
    for _ in range(rng.randrange(0, 3)):
        run = np.sort(nprng.uniform(0.0, 50.0, rng.randrange(0, 2000)))
        eng.push_bulk(run, "arrival", None)
        ref.push_bulk(run, "arrival", None)
    for _ in range(rng.randrange(0, 60)):
        a, b = eng.pop(), ref.pop()
        assert a == b, (seed, "prefix", a, b)
        if a is not None:
            t_hi = max(t_hi, a[0])
    for op in range(n_ops):
        r = rng.random()
        if r < 0.35:                                       # bulk run
            m = rng.randrange(0, 200)
            horizon = rng.choice([0.01, 0.5, 5.0, 40.0])
            ts = t_hi + np.sort(nprng.uniform(0.0, horizon, m))
            if rng.random() < 0.3:                         # unsorted jitter
                ts = t_hi + nprng.uniform(0.0, horizon, m)
            elif rng.random() < 0.3:                       # tie-heavy
                ts = t_hi + np.repeat(
                    nprng.uniform(0.0, horizon, max(m // 4, 1)), 4)[:m]
            if rng.random() < 0.5:
                ts = ts.tolist()
            pl = None if rng.random() < 0.5 else [
                f"p{op}-{i}" for i in range(m)]
            kind = "tick" if rng.random() < 0.15 else "ev"
            eng.push_bulk(ts, kind, pl)
            ref.push_bulk(ts, kind, pl)
        elif r < 0.5:                                      # scalar push
            t = t_hi + rng.random() * 3.0
            eng.push(t, "ev", op)
            ref.push(t, "ev", op)
        elif r < 0.7:                                      # single pop
            until = None if rng.random() < 0.5 else t_hi + rng.random()
            a, b = eng.pop(until), ref.pop(until)
            assert a == b, (seed, op, a, b)
            if a is not None:
                t_hi = max(t_hi, a[0])
        else:                        # pop_batch vs sequential ref pops
            k = rng.randrange(1, 600)
            until = None if rng.random() < 0.5 else t_hi + rng.random() * 2.0
            batch = eng.pop_batch(k, until)
            for e in batch:
                assert e == ref.pop(until), (seed, op, e)
            if len(batch) < k:       # greedy: ref must be blocked too
                assert ref.pop(until) is None, (seed, op)
            if batch:
                t_hi = max(t_hi, batch[-1][0])
        assert len(eng) == len(ref), (seed, op, len(eng), len(ref))
        assert eng.pending_real == ref.pending_real, (seed, op)
    while True:                                            # full drain
        a, b = eng.pop(), ref.pop()
        assert a == b, (seed, "drain", a, b)
        if a is None:
            break
    assert len(eng) == 0 and eng.pending_real == 0
    return n_ops


def _random_workflow_spec(rng: random.Random):
    """A random declaration-order DAG: 2-7 stages, each depending on a
    random subset of earlier stages (so topology is valid by
    construction), with mixed fan-out widths and conditional branches."""
    from repro.workloads import SizeDist, StageSpec, WorkflowSpec
    n = rng.randrange(2, 8)
    stages = []
    for i in range(n):
        deps = tuple(s.name for s in stages if rng.random() < 0.4)
        stages.append(StageSpec(
            name=f"s{i}", fn=rng.choice(FNS), deps=deps,
            fanout=rng.choice([1, 1, 2, 4]),
            size=SizeDist.uniform(8, 32),
            weight=rng.choice([0.5, 1.0, 2.0]),
            prob=rng.choice([1.0, 1.0, 1.0, 0.5])))
    return WorkflowSpec(name="prop", stages=tuple(stages),
                        slo_s=rng.choice([None, 5.0]))


def run_workflow_dag_ops(seed: int) -> int:
    """ISSUE-7 invariants for workflow DAG execution: on a random DAG,
    every active stage runs exactly ``fanout`` tasks and every inactive
    conditional stage runs none; a join never fires before its last
    active transitive predecessor finishes; every instance completes;
    and the same seed reproduces byte-identical result + stage-log
    streams. Returns the number of instances checked."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.core.types import FunctionConfig
    from repro.workloads import PoissonArrivals, WorkflowWorkload

    rng = random.Random(seed)
    spec = _random_workflow_spec(rng)
    policy = rng.choice(["workflow_aware", "deadline_aware",
                         "warm_least_loaded"])

    def run():
        wl = WorkflowWorkload(
            PoissonArrivals(rate=6.0), spec, duration_s=3.0,
            seed=seed, prewarm_next=bool(seed % 2))
        store = ConfigStore()
        for fn in FNS:
            store.put(FunctionConfig(name=fn, arch="tiny_lm",
                                     concurrency=2, cold_start_s=0.1))
        sim = Simulator(build_tree(4, fanout=2, leaf_policy=policy,
                                   inner_policy=policy),
                        store,
                        SyntheticServiceModel(seed=2, fail_rate=0.0),
                        seed=7)
        n = sim.load(wl)
        sim.run()
        return sim, n

    sim, n = run()
    insts = {i.wf: i for i in sim.workflows.instances.values()}
    by_stage = {}
    for r in sim.results:
        assert r.ok, r
        by_stage.setdefault((r.wf, r.stage), []).append(r)
    # effective deps of a stage resolve through skipped conditionals:
    # the finish time a join actually waits on is the latest finishing
    # *active* transitive predecessor
    for wf, inst in insts.items():
        ran = {s.name: by_stage.get((wf, s.name), ())
               for s in spec.stages}
        for s in spec.stages:
            want = s.fanout if s.name in inst.active else 0
            assert len(ran[s.name]) == want, (wf, s.name, want)

        def active_preds(name, acc):
            for d in spec.stage(name).deps:
                if d in inst.active:
                    acc.add(d)
                else:
                    active_preds(d, acc)
            return acc
        for s in spec.stages:
            if s.name not in inst.active:
                continue
            preds = active_preds(s.name, set())
            if not preds:
                continue
            gate = max(r.finish_t for p in preds for r in ran[p])
            first = min(r.arrival_t for r in ran[s.name])
            assert first >= gate - 1e-9, (wf, s.name, first, gate)
    # every instance completes ok (fail_rate 0, no timeouts at this load)
    assert len(sim.workflow_results) == n
    assert all(w.ok for w in sim.workflow_results)
    # determinism: same seed => byte-identical streams
    sim2, _ = run()
    assert digest_sim(sim2) == digest_sim(sim)
    assert sim2.workflows.stage_log == sim.workflows.stage_log
    return n


def run_memory_cap_trial(seed: int) -> None:
    """One randomized memory-capped simulation in which every instance
    add/remove checks the capacity invariant (used by both the tier-1
    placement suite and the hypothesis lane)."""
    from repro.core import simulator as S
    from repro.core.config_store import ConfigStore
    from repro.core.placement import list_placers
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.workloads import build_scenario, install_demo_configs

    rng = random.Random(seed)
    scenario = rng.choice(["multi_tenant", "flash_crowd", "steady"])
    over = {"multi_tenant": dict(rps=150.0, memory_skew=True),
            "flash_crowd": dict(burst_rps=600.0),
            "steady": dict(rps=120.0)}[scenario]
    cap = rng.choice([512, 1024, 2048, 4096])
    wl = build_scenario(scenario, duration_s=4.0, seed=rng.randrange(100),
                        **over)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_tree(4, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=rng.randrange(100),
                    worker_memory_mb=cap,
                    placer=rng.choice(list_placers()))
    sim.load(wl)

    orig_add, orig_rm = S._Worker.add_instance, S._Worker.remove_instance

    def checked(w):
        flat = sum(i.memory_mb for i in w.iid_index.values())
        assert abs(w.memory_used_mb - flat) < 1e-6
        if w.memory_mb is not None:
            assert w.memory_used_mb <= w.memory_mb + 1e-9, \
                (w.name, w.memory_used_mb, w.memory_mb)

    def add(self, inst):
        orig_add(self, inst)
        checked(self)

    def rm(self, inst):
        orig_rm(self, inst)
        checked(self)
    S._Worker.add_instance, S._Worker.remove_instance = add, rm
    try:
        sim.run()
    finally:
        S._Worker.add_instance, S._Worker.remove_instance = orig_add, orig_rm
    for w in sim.workers.values():
        assert w.memory_used_mb <= cap + 1e-9


def run_gateway_ops(seed: int, n_ops: int = 300) -> int:
    """ISSUE-9 invariants for the front-door gateway under random
    arrival / settle / retry-consult interleavings: token buckets stay
    within ``[0, burst]``; per-tenant admits over the run never exceed
    the bucket contract ``burst + rate * elapsed``; the inflight
    counters mirror a flat outstanding set (per class, each within its
    admission ceiling); and the same seed replays a byte-identical
    ``(rid, verdict)`` stream. Returns the number of ops checked."""
    from repro.core.gateway import (PRIORITIES, Gateway, GatewayConfig,
                                    TenantQuota)

    def trial():
        rng = random.Random(seed)
        quotas = {}
        for fn in FNS:
            if rng.random() < 0.75:
                quotas[fn] = TenantQuota(
                    rate=rng.choice([0.0, 1.0, 5.0, 50.0]),
                    burst=rng.choice([1.0, 2.0, 8.0]),
                    priority=rng.choice(PRIORITIES))
        cfg = GatewayConfig(
            quotas=quotas,
            default_quota=rng.choice([None,
                                      TenantQuota(rate=20.0, burst=4.0)]),
            max_inflight=rng.choice([None, 2, 4, 16]),
            batch_share=rng.choice([0.0, 0.25, 0.5, 1.0]))
        gw = Gateway(cfg, record=True)
        now = 0.0
        rid = itertools.count()
        outstanding = []          # reference model: admitted, unsettled
        admit_ts = {}             # fn -> admit times (bucket contract)
        bucket_t0 = {}            # fn -> first rate-limited consult
        for _ in range(n_ops):
            op = rng.random()
            now += rng.random() * rng.choice([0.01, 0.1, 1.0])
            if op < 0.7 or not outstanding:                # arrival
                fn = rng.choice(FNS)
                r = Request(fn=fn, arrival_t=now, rid=next(rid),
                            priority=rng.choice([None, "interactive",
                                                 "batch"]))
                if quotas.get(fn, cfg.default_quota) is not None:
                    bucket_t0.setdefault(fn, now)
                if gw.admit(r, now) is None:
                    outstanding.append(r)
                    admit_ts.setdefault(fn, []).append(now)
                    limit = gw._limit(gw.priority_of(r))
                    if limit is not None:   # ceiling honoured at admit
                        assert gw.inflight_by_pri[r._gw_pri] <= limit
            elif op < 0.85:                                # settle
                gw.release(outstanding.pop(rng.randrange(len(outstanding))),
                           now)
            else:                                          # retry consult
                gw.admit(rng.choice(outstanding), now, retry=True)
            for b in gw._buckets.values():
                assert -1e-9 <= b.level <= b.burst + 1e-9
            # inflight accounting mirrors the flat outstanding set
            assert gw.inflight == len(outstanding)
            by_pri = {p: 0 for p in PRIORITIES}
            for r in outstanding:
                by_pri[r._gw_pri] += 1
            assert gw.inflight_by_pri == by_pri
            assert gw.admitted_total == sum(len(v)
                                            for v in admit_ts.values())
        # token-bucket contract over the whole run, per tenant
        for fn, ts in admit_ts.items():
            quota = quotas.get(fn, cfg.default_quota)
            if quota is not None:
                budget = quota.burst + quota.rate * (ts[-1] - bucket_t0[fn])
                assert len(ts) <= budget + 1e-6, (fn, len(ts), budget)
        return gw.decision_records()

    records = trial()
    assert trial() == records     # same seed => byte-identical verdicts
    return n_ops


class _DetServiceModel:
    """RNG-free service model for partition-equality trials: duration is
    a pure function of the request, so a partition's requests cost the
    same whether or not other partitions' requests interleave (the
    shared-RNG ``SyntheticServiceModel`` cannot make that guarantee —
    its sample stream depends on the global arrival interleaving)."""

    def sample(self, cfg, *, batch_size, queue_len, prompt, cold, fn_cost):
        base = 0.004 + 0.0008 * (prompt + cfg.gen_tokens) * fn_cost
        base *= 1.0 + 0.30 * max(batch_size - 1, 0)
        return base, True


def run_partition_merge_ops(seed: int, n_partitions: int = 0) -> int:
    """ISSUE-10 invariants for the parallel runner: on a random
    multi-tenant scenario, (1) the K-partition merged stream
    byte-equals the serial run on the union tree (results, telemetry,
    decision logs, summary, counters); (2) same seed + same K ⇒
    byte-identical merged output across repeated runs; (3) forcing
    window barriers changes nothing and the barrier history satisfies
    its invariants (strictly increasing barrier times, all partitions
    drained at the final barrier). Returns the number of tenant
    streams exercised.

    Construction: tenant streams route through a ``tenant_hash`` root
    (no RNG, crc32 — the exact assignment ``partition_streams`` uses)
    into per-partition ``round_robin`` branches (no RNG), served by an
    RNG-free service model — so the serial union run and the partition
    runs consume identical randomness per request and byte-equality is
    exact, not approximate."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import LBNode, build_leaf
    from repro.core.simulator import Simulator, stream_digest, summarize
    from repro.core.types import FunctionConfig
    from repro.parallel import partition_streams, run_partitioned
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)

    rng = random.Random(seed)
    K = n_partitions or rng.choice([2, 3, 4])
    n_streams = rng.randrange(K, 3 * K + 1)
    rates = [rng.choice([5.0, 10.0, 20.0]) for _ in range(n_streams)]
    sizes = [rng.choice([8, 16, 24]) for _ in range(n_streams)]
    wpl = rng.choice([2, 3])               # workers per partition leaf
    dur = 2.0

    def streams():
        return [MixedWorkload(PoissonArrivals(rate=rates[j]),
                              [FunctionProfile(fn=f"t{j}",
                                               size=SizeDist.const(sizes[j]))],
                              duration_s=dur, seed=500 + j,
                              rid_base=j * 1_000_000)
                for j in range(n_streams)]

    def make_store(fns):
        store = ConfigStore()
        for fn in fns:
            store.put(FunctionConfig(name=fn, arch="tiny_lm", concurrency=2,
                                     cold_start_s=0.05, idle_timeout_s=5.0))
        return store

    def branch(k):
        return build_leaf(f"p{k}", [f"p{k}w{i}" for i in range(wpl)],
                          "round_robin")

    # serial reference: all streams through the union tree
    all_streams = streams()
    serial = Simulator(
        LBNode("root", "tenant_hash", children=[branch(k) for k in range(K)]),
        make_store([s.profiles[0].fn for s in all_streams]),
        _DetServiceModel(), seed=7, record_decisions=True,
        iid_scope="worker")
    for s in all_streams:
        serial.load(s)
    serial.run()

    def build(k, n):
        mine = partition_streams(streams(), n)[k]
        sim = Simulator(LBNode("root", "tenant_hash", children=[branch(k)]),
                        make_store([s.profiles[0].fn for s in mine]),
                        _DetServiceModel(), seed=7, record_decisions=True,
                        iid_scope="worker")
        for s in mine:
            sim.load(s)
        return sim

    # (1) merged K-partition run byte-equals the serial union run
    merged = run_partitioned(build, K, mode="inline")
    assert stream_digest(merged) == stream_digest(serial), seed
    assert merged.routing_log() == serial.routing_log(), seed
    assert merged.placement_log() == serial.placement_log(), seed
    assert merged.gateway_log() == serial.gateway_log(), seed
    assert merged.fault_log() == serial.fault_log(), seed
    ms, ss = merged.summary(), summarize(serial.results)
    assert set(ms) == set(ss), seed
    for key in ms:
        if isinstance(ss[key], float):
            # counts/percentiles/makespans are exact; only ``mean`` sums
            # floats in partition order instead of record order
            assert abs(ms[key] - ss[key]) <= 1e-9 * max(1.0, abs(ss[key])), \
                (seed, key, ms[key], ss[key])
        else:
            assert ms[key] == ss[key], (seed, key)
    assert merged.counters["arrivals_seen"] == serial.arrivals_seen, seed
    assert merged.counters["events_processed"] == serial.events_processed
    assert merged.counters["arrivals_by_fn"] == serial.arrivals_by_fn, seed

    # (2) run-twice determinism, full and summary collects
    again = run_partitioned(build, K, mode="inline")
    assert stream_digest(again) == stream_digest(merged), seed
    assert again.routing_log() == merged.routing_log(), seed
    summary = run_partitioned(build, K, mode="inline", collect="summary")
    again_s = run_partitioned(build, K, mode="inline", collect="summary")
    assert summary.digest() == again_s.digest(), seed
    assert summary.summary() == merged.summary(), seed
    assert summary.counters == merged.counters, seed

    # (3) forced window barriers: same bytes + barrier invariants
    win = run_partitioned(build, K, mode="inline",
                          window_s=rng.choice([0.1, 0.25, 0.5]))
    assert stream_digest(win) == stream_digest(serial), seed
    assert win.barriers, seed
    ts = [b["t"] for b in win.barriers]
    assert ts == sorted(ts) and len(set(ts)) == len(ts), seed
    assert all(p == 0 for p in win.barriers[-1]["pending"]), seed
    return n_streams

"""Per-function scheduling core: index units + byte-identity regression.

The ISSUE-3 refactor replaced the flat worker queue / instance lists with
``repro.core.scheduling`` (per-function FIFO queues merged by global
arrival order, replica sets, iid index, deadline heap). The contract is
that *semantics did not move*: the digests pinned below were produced by
the pre-refactor flat-scan simulator and must keep matching the indexed
one — across timeouts, hedging, unlimited concurrency, mixed tenants, a
queue_len-sensitive service model, and a fully autoscaled run.
"""

import pytest

from repro.core.config_store import ConfigStore
from repro.core.router import build_tree
from repro.core.scheduling import FnQueues, FunctionReplicaSet, Instance
from repro.core.simulator import Simulator, SyntheticServiceModel
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario, install_demo_configs


# --------------------------------------------------------------- FnQueues
def _req(fn, t, rid):
    return Request(fn=fn, arrival_t=t, rid=rid)


def test_fnqueues_preserves_global_arrival_order():
    q = FnQueues()
    reqs = [_req("a", 0.0, 0), _req("b", 0.1, 1), _req("a", 0.2, 2),
            _req("c", 0.3, 3), _req("b", 0.4, 4)]
    for r in reqs:
        q.push(r, timeout_s=10.0)
    assert len(q) == 5
    assert q.depth("a") == 2 and q.depth("b") == 2 and q.depth("c") == 1
    assert [r.rid for r in q] == [0, 1, 2, 3, 4]
    assert sorted(q.active_fns()) == ["a", "b", "c"]


def test_fnqueues_scan_pop_restore_cycle():
    q = FnQueues()
    for i in range(4):
        q.push(_req("a", 0.1 * i, i), timeout_s=10.0)
    head = q.scan_head("a")
    assert head.rid == 0
    q.pop_head("a")
    q.mark_served(head)                  # rid 0 leaves the queue
    second = q.scan_head("a")
    q.pop_head("a")
    q.restore("a", [second])             # rid 1 processed but kept
    assert len(q) == 3
    assert [r.rid for r in q] == [1, 2, 3]


def test_fnqueues_expiry_matches_flat_scan_semantics():
    q = FnQueues()
    q.push(_req("a", 0.0, 0), timeout_s=1.0)
    q.push(_req("b", 0.5, 1), timeout_s=1.0)
    q.push(_req("a", 2.0, 2), timeout_s=1.0)
    assert not q.has_expired(0.9)
    assert q.pop_expired(0.9) == []
    # strict '>': a request exactly at its deadline is not yet expired
    assert q.pop_expired(1.0) == []
    expired = q.pop_expired(1.6)
    assert [r.rid for r in expired] == [0, 1]    # arrival order, both fns
    assert len(q) == 1 and q.depth("a") == 1
    assert [r.rid for r in q] == [2]


def test_fnqueues_drain_all_in_arrival_order():
    q = FnQueues()
    for i, fn in enumerate(["x", "y", "x", "z"]):
        q.push(_req(fn, 0.1 * i, i), timeout_s=5.0)
    drained = q.drain_all()
    assert [r.rid for r in drained] == [0, 1, 2, 3]
    assert len(q) == 0 and q.pop_expired(99.0) == []


# ------------------------------------------------------ FunctionReplicaSet
def test_replica_set_pick_packs_densest_ready_first():
    rs = FunctionReplicaSet("fn")
    a = Instance(iid="i0", fn="fn", slots=4, busy=1, ready_t=0.0)
    b = Instance(iid="i1", fn="fn", slots=4, busy=3, ready_t=0.0)
    warm = Instance(iid="i2", fn="fn", slots=4, busy=0, ready_t=5.0)
    rs.instances += [a, b, warm]
    assert rs.pick(now=1.0) is b          # densest ready wins
    b.busy = 4
    assert rs.pick(now=1.0) is a          # full instance skipped
    assert rs.pick(now=6.0) in (a, warm)  # warm becomes eligible later


def test_replica_set_warming_and_free_slot_accounting():
    rs = FunctionReplicaSet("fn")
    rs.instances.append(Instance(iid="i0", fn="fn", slots=2, busy=1,
                                 ready_t=0.0))
    rs.instances.append(Instance(iid="i1", fn="fn", slots=3, busy=0,
                                 ready_t=4.0))
    assert rs.ready_free_slots(now=1.0) == 1
    assert rs.warming_free(now=1.0) == 3
    assert rs.next_ready_after(now=1.0) == 4.0
    assert rs.next_ready_after(now=5.0) is None
    assert rs.inflight() == 1
    assert rs.idle_ready(now=1.0) is None
    assert rs.idle_ready(now=5.0) is rs.instances[1]


# ----------------------------------------- byte-identity vs the flat scan
class QueueLenModel:
    """Deterministic model that *uses* queue_len — catches any drift in
    the queue-length snapshot the dispatch scan hands to sample()."""

    def __init__(self, seed=0):
        import random
        self.rng = random.Random(seed)

    def sample(self, cfg, *, batch_size, queue_len, prompt, cold, fn_cost):
        base = 0.004 + 0.0001 * (prompt + cfg.gen_tokens)
        base *= 1.0 + 0.01 * queue_len + 0.1 * max(batch_size - 1, 0)
        base *= self.rng.lognormvariate(0.0, 0.05)
        return base, self.rng.random() >= 0.001


from _prop_drivers import digest_sim as _digest  # noqa: E402  (shared def)


def _scenario_sim(scenario, model, *, workers=8, sim_kw=None, cfg_over=None,
                  **over):
    wl = build_scenario(scenario, **over)
    store = ConfigStore()
    install_demo_configs(store, wl)
    if cfg_over:
        for fn in wl.fns():
            c = store.get(fn)
            store.put(FunctionConfig(**{**c.__dict__, **cfg_over}))
    sim = Simulator(build_tree(workers, fanout=4), store, model,
                    seed=7, **(sim_kw or {}))
    sim.load(wl)
    sim.run()
    return sim


# digests recorded from the pre-refactor flat-scan simulator (seed PR 2
# tree) on the exact configurations below; the indexed scheduling core
# must not move a single byte of the result/telemetry stream.
# Exception: "hedged" has been re-recorded for the hedge-telemetry
# bugfixes (a winning clone resolves the primary's telemetry row, and
# losing attempts now resolve their own rows instead of staying at the
# latency=0.0/ok=True placeholder — both covered by the digest); the
# result stream is unchanged.
GOLDEN = {
    "steady": "90ac57f36c579d36",
    "multi_tenant": "ec5034f85267151c",
    "timeouts": "f76ce8e2854a36ad",
    "hedged": "9faa3bd780d5e7b0",
    "unlimited": "080aa05e2b950234",
    "queue_len_model": "1b2f33ae54ee62d1",
}

CASES = {
    "steady": lambda: _scenario_sim(
        "steady", SyntheticServiceModel(seed=2), rps=300.0, duration_s=8.0,
        seed=3),
    "multi_tenant": lambda: _scenario_sim(
        "multi_tenant", SyntheticServiceModel(seed=2), rps=400.0,
        duration_s=8.0, seed=3),
    "timeouts": lambda: _scenario_sim(
        "flash_crowd", SyntheticServiceModel(seed=2), duration_s=8.0, seed=3,
        burst_rps=2000.0, workers=4,
        cfg_over=dict(timeout_s=0.4, max_instances_per_worker=2)),
    "hedged": lambda: _scenario_sim(
        "steady", SyntheticServiceModel(seed=2), rps=150.0, duration_s=8.0,
        seed=3, sim_kw=dict(hedge_after_s=0.05)),
    "unlimited": lambda: _scenario_sim(
        "multi_tenant", SyntheticServiceModel(seed=2), rps=400.0,
        duration_s=8.0, seed=3, cfg_over=dict(concurrency=0)),
    "queue_len_model": lambda: _scenario_sim(
        "multi_tenant", QueueLenModel(seed=4), rps=500.0, duration_s=8.0,
        seed=3, workers=4),
}


@pytest.mark.parametrize("case", sorted(GOLDEN))
def test_results_byte_identical_to_flat_scan(case):
    assert _digest(CASES[case]()) == GOLDEN[case]


@pytest.mark.slow
def test_autoscaled_run_byte_identical_to_flat_scan():
    """Full control loop (grow/shrink/prewarm/reroute) over the indexed
    core still reproduces the flat-scan result stream."""
    from repro.autoscale import Autoscaler, build_pool
    wl = build_scenario("flash_crowd", duration_s=20.0, seed=3, base_rps=12.0,
                        burst_rps=1000.0, mean_burst_s=2.0, mean_calm_s=10.0)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(1, 2), store, SyntheticServiceModel(seed=2),
                    seed=7, worker_capacity_slots=1)
    scaler = Autoscaler("reactive", interval_s=0.25, window_s=2.0,
                        min_replicas=1, max_replicas=8, workers_per_replica=2,
                        cooldown_s=2.0)
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    sim.run()
    assert _digest(sim) == "12db0fa01285116e"


# ------------------------------------------------- index-consistency paths
@pytest.fixture
def store():
    s = ConfigStore()
    s.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                         cold_start_s=0.05, idle_timeout_s=2.0))
    return s


def test_iid_index_tracks_start_and_reap(store):
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5)
    sim.submit(Request(fn="fn", arrival_t=0.0))
    sim.run()
    for w in sim.workers.values():
        # idle timeout has long passed by end of run: everything reaped,
        # and the iid index never leaks reaped instances
        assert w.iid_index == {}
        assert w.total_instances == 0
        assert all(not rs.instances for rs in w.replica_sets.values())


@pytest.mark.parametrize("merge_path", [False, True])
def test_zero_cold_start_backlog_scan_does_not_strand(store, merge_path):
    """Crash regression: a zero-cold instance started mid-scan is *ready*
    capacity, not warming. Counting its free slots as warming sent a
    later queued request down the wait-on-warming branch with no warming
    instance to wait on (`next_ready_after -> None` -> TypeError in
    _poke). Covers both the single-fn fast path and the multi-fn merge."""
    store.put(FunctionConfig(name="blk", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.0, idle_timeout_s=0.4))
    store.put(FunctionConfig(name="zc", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.0, max_instances_per_worker=1,
                             idle_timeout_s=30.0, timeout_s=5.0))
    store.put(FunctionConfig(name="other", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.5, timeout_s=5.0))
    sim = Simulator(build_tree(1, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    worker_capacity_slots=1)
    n = 0
    sim.submit(Request(fn="blk", arrival_t=0.0)); n += 1
    for i in range(3):      # backlog behind blk's capacity-pinned instance
        sim.submit(Request(fn="zc", arrival_t=0.05 + 0.01 * i)); n += 1
    if merge_path:          # second queued fn => multi-fn merge scan
        sim.submit(Request(fn="other", arrival_t=0.1)); n += 1
    # blk's instance idle-reaps ~0.45s; this arrival triggers the backlog
    # scan that starts (and immediately saturates) the zero-cold replica
    sim.submit(Request(fn="zc", arrival_t=1.0)); n += 1
    res = sim.run()
    assert len(res) == n
    zc = [r for r in res if r.fn == "zc"]
    assert all(r.ok for r in zc) and len(zc) == 4


def test_worker_instances_view_matches_replica_sets(store):
    sim = Simulator(build_tree(2, fanout=2), store,
                    SyntheticServiceModel(seed=2), seed=5)
    w = next(iter(sim.workers.values()))
    assert sim.prewarm(w.name, "fn")
    assert [i.iid for i in w.instances["fn"]] == \
        [i.iid for i in w.replica_sets["fn"].instances]
    assert w.iid_index[w.instances["fn"][0].iid] is w.instances["fn"][0]


# ------------------------------------ op-sequence property drivers (ISSUE 4)
# Fixed-seed runs of the shared drivers keep these invariants in the
# tier-1 lane even without hypothesis; tests/test_property.py wraps the
# same drivers in @given(integers()) to explore the seed space in CI.
@pytest.mark.parametrize("seed", range(5))
def test_fnqueues_fifo_and_deadline_heap_under_interleaved_ops(seed):
    from _prop_drivers import run_fnqueues_ops
    assert run_fnqueues_ops(seed) > 0


@pytest.mark.parametrize("seed", range(5))
def test_replica_index_agrees_with_iid_map_under_churn(seed):
    from _prop_drivers import run_replica_index_ops
    assert run_replica_index_ops(seed) > 0


@pytest.mark.parametrize("seed", range(5))
def test_gateway_accounting_under_interleaved_ops(seed):
    from _prop_drivers import run_gateway_ops
    assert run_gateway_ops(seed) > 0

"""Optimizers vs independent numpy references; schedules; state dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import AdamW, Adafactor, SGDM, make_optimizer
from repro.train.schedule import constant, inverse_sqrt, warmup_cosine


def _numpy_adamw(params, grads, steps, lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, wd=0.0):
    p = {k: v.astype(np.float64) for k, v in params.items()}
    m = {k: np.zeros_like(v, np.float64) for k, v in params.items()}
    v_ = {k: np.zeros_like(v, np.float64) for k, v in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k].astype(np.float64)
            m[k] = b1 * m[k] + (1 - b1) * g
            v_[k] = b2 * v_[k] + (1 - b2) * g * g
            mh = m[k] / (1 - b1 ** t)
            vh = v_[k] / (1 - b2 ** t)
            p[k] -= lr * (mh / (np.sqrt(vh) + eps) + wd * p[k])
    return p


def test_adamw_matches_numpy(rng):
    params = {"a": jax.random.normal(rng, (5, 3)),
              "b": jax.random.normal(rng, (4,))}
    grads = {"a": jax.random.normal(jax.random.PRNGKey(9), (5, 3)),
             "b": jax.random.normal(jax.random.PRNGKey(8), (4,))}
    opt = AdamW(lr=1e-2, b1=0.9, b2=0.95, weight_decay=0.0)
    state = opt.init(params)
    p = params
    for _ in range(5):
        p, state = opt.update(grads, state, p)
    ref = _numpy_adamw({k: np.asarray(v) for k, v in params.items()},
                       {k: np.asarray(v) for k, v in grads.items()}, 5)
    for k in p:
        np.testing.assert_allclose(np.asarray(p[k]), ref[k], rtol=1e-5, atol=1e-6)


def test_adamw_bf16_state_dtype(rng):
    params = {"w": jax.random.normal(rng, (8, 8), jnp.bfloat16)}
    opt = AdamW(lr=1e-3, state_dtype="bfloat16")
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    p2, s2 = opt.update({"w": jnp.ones((8, 8), jnp.bfloat16)}, state, params)
    assert p2["w"].dtype == jnp.bfloat16 and s2["v"]["w"].dtype == jnp.bfloat16


def test_adafactor_factored_state(rng):
    params = {"mat": jax.random.normal(rng, (64, 32)),
              "vec": jax.random.normal(rng, (16,))}
    opt = Adafactor(lr=1e-3)
    state = opt.init(params)
    assert state["stats"]["mat"]["r"].shape == (64,)
    assert state["stats"]["mat"]["c"].shape == (32,)
    assert state["stats"]["vec"]["v"].shape == (16,)
    g = {"mat": jnp.ones((64, 32)), "vec": jnp.ones((16,))}
    p2, s2 = opt.update(g, state, params)
    assert jnp.all(jnp.isfinite(p2["mat"]))
    # memory win: factored stats << full second moment
    assert (state["stats"]["mat"]["r"].size + state["stats"]["mat"]["c"].size
            < params["mat"].size)


def test_sgdm_descends(rng):
    w = jnp.array([5.0])
    opt = SGDM(lr=0.1, momentum=0.9)
    st = opt.init({"w": w})
    p = {"w": w}
    for _ in range(50):
        g = {"w": 2 * p["w"]}
        p, st = opt.update(g, st, p)
    assert abs(float(p["w"][0])) < 0.2


def test_schedules():
    import jax.numpy as jnp
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(100))) < 0.11
    g = inverse_sqrt(1.0, 100)
    assert abs(float(g(jnp.asarray(100))) - 1.0) < 1e-6
    assert abs(float(g(jnp.asarray(400))) - 0.5) < 1e-6
    assert float(constant(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


def test_make_optimizer_uses_cfg_dtype():
    from repro.configs import get_config
    opt = make_optimizer("adamw", 1e-4, get_config("grok1_314b"))
    assert opt.state_dtype == "bfloat16"

"""Hypothesis property tests on system invariants.

``hypothesis`` is an *optional* dev dependency: the whole module is
skipped (not errored) when it is absent so the tier-1 suite stays green
on minimal images. Install it locally to run these properties.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.distributed import compression as C
from repro.distributed.sharding import DECODE_RULES, TRAIN_RULES, resolve_spec

MESH = jax.sharding.AbstractMesh((16, 16), ("data", "model"))

NAME_POOL = ["act_batch", "act_seq", "act_kv_seq", "act_kv_heads", "act_mlp",
             "act_vocab", "w_embed", "w_qdim", "w_mlp", "w_expert", None]


@given(st.lists(st.tuples(st.sampled_from(NAME_POOL),
                          st.sampled_from([1, 2, 8, 16, 56, 64, 128, 504, 4096])),
                min_size=1, max_size=5))
@settings(max_examples=200, deadline=None)
def test_resolver_invariants(dims_names):
    """Divisibility always holds; no mesh axis appears twice in a spec."""
    names = tuple(n for n, _ in dims_names)
    shape = tuple(d for _, d in dims_names)
    for rules in (TRAIN_RULES, DECODE_RULES):
        spec = resolve_spec(MESH, shape, names, rules)
        used = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                assert a in ("data", "model")
                used.append(a)
                size *= 16
            assert dim % size == 0
        assert len(used) == len(set(used))


@given(st.integers(1, 2**31 - 1), st.integers(4, 256))
@settings(max_examples=100, deadline=None)
def test_int8_quantization_bound(seed, n):
    """|dequant(quant(x)) - x| <= scale/2 elementwise (round-to-nearest)."""
    x = np.random.default_rng(seed).normal(0, 3, n).astype(np.float32)
    q, scale = C.quantize_int8(jnp.asarray(x))
    back = np.asarray(C.dequantize_int8(q, scale))
    assert np.all(np.abs(back - x) <= float(scale) / 2 + 1e-6)


@given(st.integers(1, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_error_feedback_identity(seed):
    """g_sent + new_err == g + old_err exactly (nothing lost, only delayed)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, 64).astype(np.float32))
    err = jnp.asarray(rng.normal(0, 0.1, 64).astype(np.float32))
    q, scale, new_err = C.ef_compress_int8(g, err)
    sent = C.dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(sent + new_err),
                               np.asarray(g + err), rtol=1e-5, atol=1e-5)
    sent_tk, new_err_tk = C.ef_compress_topk(g, err, 0.1)
    np.testing.assert_allclose(np.asarray(sent_tk + new_err_tk),
                               np.asarray(g + err), rtol=1e-6, atol=1e-6)


@given(st.integers(1, 2**31 - 1), st.floats(0.05, 0.9))
@settings(max_examples=50, deadline=None)
def test_topk_keeps_largest(seed, frac):
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, 100).astype(np.float32))
    mask = np.asarray(C.topk_mask(x, frac))
    kept = np.abs(np.asarray(x))[mask > 0]
    dropped = np.abs(np.asarray(x))[mask == 0]
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-6


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 6),
       st.integers(2, 8), st.integers(1, 3))
@settings(max_examples=60, deadline=None)
def test_moe_dispatch_conservation(seed, B, S, E, k):
    """Every routed token lands in <=k slots; no slot holds 2 tokens; gates
    of surviving slots sum to <=1 per token."""
    from dataclasses import replace
    from repro.configs import get_config, reduced
    from repro.models import moe as M
    k = min(k, E)
    cfg = reduced(get_config("moonshot_v1_16b"))
    cfg = replace(cfg, moe=replace(cfg.moe, num_experts=E, top_k=k,
                                   capacity_factor=1.0))
    rng = jax.random.PRNGKey(seed)
    D = 8
    x = jax.random.normal(rng, (B, S, D))
    p = {"router": jax.random.normal(rng, (D, E)),
         "wg": jnp.zeros((E, D, 4)), "wi": jnp.zeros((E, D, 4)),
         "wo": jnp.zeros((E, 4, D))}
    y = M.moe_forward(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()


# ------------------------------------------------------ LB-tree invariants
TREE_POLICIES = ["random", "round_robin", "hash", "least_loaded", "pow2",
                 "warm_affinity"]


@given(st.integers(1, 48), st.integers(2, 8), st.sampled_from(TREE_POLICIES),
       st.sampled_from(TREE_POLICIES), st.integers(0, 10**6))
@settings(max_examples=80, deadline=None)
def test_route_always_returns_known_worker(n, fanout, leaf_pol, inner_pol,
                                           seed):
    """route() must land on a member of all_workers() for any tree shape,
    policy mix, and request stream."""
    import random
    from repro.core.router import StateView, build_tree
    from repro.core.types import Request
    tree = build_tree(n, fanout=fanout, leaf_policy=leaf_pol,
                      inner_policy=inner_pol)
    workers = set(tree.all_workers())
    assert len(workers) == n
    view, rng = StateView(), random.Random(seed)
    for i in range(25):
        w, hops = tree.route(Request(fn="fn", arrival_t=0.0, rid=i),
                             view, rng, 0.0)
        assert w in workers
        assert hops >= 1


@given(st.integers(1, 24), st.integers(2, 6), st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_replicate_yields_k_times_unique_workers(n, fanout, k):
    """replicate(tree, k) must hold exactly k*n workers, all unique ids."""
    from repro.core.router import build_tree, replicate
    tree = build_tree(n, fanout=fanout)
    grown = replicate(tree, times=k) if k > 1 else tree
    workers = grown.all_workers()
    assert len(workers) == k * n
    assert len(set(workers)) == k * n


@given(st.integers(1, 24), st.integers(2, 6),
       st.lists(st.integers(1, 4), min_size=1, max_size=4))
@settings(max_examples=60, deadline=None)
def test_add_remove_branch_round_trip_preserves_workers(n, fanout, sizes):
    """Adding branches then removing them restores the exact worker set."""
    from repro.core.router import build_leaf, build_tree
    tree = build_tree(n, fanout=fanout)
    before = sorted(tree.all_workers())
    for i, size in enumerate(sizes):
        tree.add_branch(build_leaf(
            f"x-b{i}", [f"x-b{i}-w{j}" for j in range(size)]))
    grown = sorted(tree.all_workers())
    assert len(grown) == n + sum(sizes)
    assert len(set(grown)) == len(grown)
    for i in range(len(sizes)):
        tree.remove_branch(f"x-b{i}")
    assert sorted(tree.all_workers()) == before


@given(st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_simulator_concurrency_never_exceeded(seed):
    """No instance ever holds more than `concurrency` busy slots (c>0)."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel, poisson_load
    from repro.core.types import FunctionConfig
    c = (seed % 4) + 1
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=c,
                             cold_start_s=0.05))
    sim = Simulator(build_tree(4, fanout=2), store,
                    SyntheticServiceModel(seed=seed), seed=seed)
    poisson_load(sim, fn="fn", rps=80, duration_s=3, seed=seed)

    max_seen = 0
    orig = Simulator._start_service

    def spy(self, w, inst, req, cfg, queue_len):
        nonlocal max_seen
        orig(self, w, inst, req, cfg, queue_len)
        max_seen = max(max_seen, inst.busy)
    Simulator._start_service = spy
    try:
        sim.run()
    finally:
        Simulator._start_service = orig
    assert max_seen <= c


# ------------------------------------ scheduling core + placement (ISSUE 4)
# The op-sequence drivers live in tests/_prop_drivers.py and are also run
# over fixed seeds by the tier-1 suites (test_scheduling / test_placement);
# here hypothesis explores the seed space and shrinks failures to a seed.


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_fnqueues_global_fifo_and_deadline_heap(seed):
    """FnQueues keeps exact global-FIFO order, per-fn depths, and a
    consistent deadline heap under arbitrary interleaved push / serve /
    expire / drain sequences."""
    from _prop_drivers import run_fnqueues_ops
    assert run_fnqueues_ops(seed) > 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_replica_set_index_matches_iid_map(seed):
    """FunctionReplicaSet lists, the worker iid index, and the
    incremental memory/slots/inflight counters agree with flat rescans
    after random add / busy-churn / remove / clear sequences."""
    from _prop_drivers import run_replica_index_ops
    assert run_replica_index_ops(seed) > 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_worker_memory_capacity_never_exceeded(seed):
    """End-to-end placement invariant: no worker's placed-replica memory
    ever exceeds its capacity, for random scenarios, placers, and caps."""
    from _prop_drivers import run_memory_cap_trial
    run_memory_cap_trial(seed)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_event_backends_drain_in_identical_order(seed):
    """Every registered EventEngine backend (single_heap, sharded, and
    any future addition) pops arbitrary interleaved push/pop streams in
    exactly the same (t, seq) order, with agreeing pending counts."""
    from _prop_drivers import run_event_backend_ops
    assert run_event_backend_ops(seed) > 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_push_bulk_pop_batch_match_per_entry_reference(seed):
    """ISSUE-8 bulk ingest: push_bulk/pop_batch on every backend are
    order-identical to per-entry push/pop on the single-heap reference
    under arbitrary interleavings (sorted/shuffled/tied runs, numpy or
    list, payloads or not, horizon pops, greedy batch pops)."""
    from _prop_drivers import run_push_bulk_ops
    assert run_push_bulk_ops(seed) > 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_workflow_dag_execution(seed):
    """ISSUE-7 workflow invariants on random DAGs: active stages run
    exactly their fan-out width, skipped conditionals run nothing,
    joins wait for their last active transitive predecessor, every
    instance completes, and same-seed runs are byte-identical."""
    from _prop_drivers import run_workflow_dag_ops
    assert run_workflow_dag_ops(seed) > 0


@given(st.integers(0, 10**6), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_data_stream_deterministic(step, seed):
    from repro.data.pipeline import DataConfig, TokenStream
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4, seed=seed)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b1, b2 = s1.batch(step), s2.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 512 and b1["tokens"].min() >= 0
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_partition_merge_byte_equivalence(seed):
    """ISSUE-10 parallel-runner invariants: the K-partition merged
    stream byte-equals the serial union run (results, telemetry,
    decision logs, summary, counters); same seed + same K ⇒
    byte-identical output across runs; forced window barriers change
    nothing and the barrier history is well-formed."""
    from _prop_drivers import run_partition_merge_ops
    assert run_partition_merge_ops(seed) > 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_gateway_accounting(seed):
    """ISSUE-9 gateway invariants: buckets within [0, burst], admits
    within the bucket contract, inflight mirrors the outstanding set
    per priority class, same seed => byte-identical verdicts."""
    from _prop_drivers import run_gateway_ops
    assert run_gateway_ops(seed) > 0

"""Roofline analyzer: HLO collective parsing, ring factors, term math."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.telemetry import roofline as R

HLO = """
ENTRY %main {
  %ag = bf16[16,2048]{1,0} all-gather(bf16[2,2048]{1,0} %p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p1), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[128,64]{1,0} reduce-scatter(f32[1024,64]{1,0} %p2), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[64]{0} collective-permute(bf16[64]{0} %p3), source_target_pairs={{0,1}}
  %a2a = s32[256]{0} all-to-all(s32[256]{0} %p4), replica_groups={{0,1,2,3}}
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = R.parse_collectives(HLO)
    assert st.ops == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                      "collective-permute": 1, "all-to-all": 1}
    ag = 16 * 2048 * 2
    ar = 1024 * 4
    rs = 128 * 64 * 4
    cp = 64 * 2
    a2a = 256 * 4
    assert st.raw_bytes["all-gather"] == ag
    expected = (ag * 7 / 8            # group of 8
                + 2 * ar * 15 / 16    # iota [16,16] => group size 16
                + rs * 1 / 2
                + cp
                + a2a * 3 / 4)
    assert abs(st.link_bytes - expected) < 1e-6


def test_ring_factor_all_reduce_doubles():
    one = R.parse_collectives(
        "%ar = f32[100]{0} all-reduce(f32[100]{0} %x), replica_groups={{0,1}}\n")
    assert one.link_bytes == pytest.approx(2 * 400 * 0.5)


def test_model_flops_modes():
    cfg = get_config("qwen3_32b")
    n = cfg.active_param_count()
    assert R.model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6 * n * 256 * 4096)
    assert R.model_flops(cfg, SHAPES["prefill_32k"]) == pytest.approx(
        2 * n * 32 * 32768)
    assert R.model_flops(cfg, SHAPES["decode_32k"]) == pytest.approx(2 * n * 128)
    moe = get_config("grok1_314b")
    assert R.model_flops(moe, SHAPES["train_4k"]) < \
        6 * moe.param_count() * 256 * 4096 / 2


def test_report_derivation():
    rep = R.RooflineReport(
        arch="a", shape="train_4k", mesh="single", n_devices=256,
        flops_pd=197e12, bytes_pd=819e9 * 2, coll_link_bytes_pd=50e9 * 0.5,
        coll_ops={}, coll_raw_bytes={}, mem={"peak_gib": 1.0},
        model_flops=197e12 * 256 * 0.5).derive()
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(2.0)
    assert rep.t_collective == pytest.approx(0.5)
    assert rep.bottleneck == "memory"
    assert rep.useful_flops_ratio == pytest.approx(0.5)
    assert rep.roofline_fraction == pytest.approx(0.25)   # 0.5 ideal / 2.0


def test_small_compiled_program_end_to_end():
    """Full analyze() on a real (single-device) compiled program."""
    from repro.configs import SHAPES

    def f(x, w):
        return jnp.tanh(x @ w).sum()
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    cfg = get_config("tiny_lm")
    rep = R.analyze(comp, arch="tiny_lm", shape=SHAPES["decode_32k"],
                    mesh_name="single", n_devices=1, cfg=cfg)
    assert rep.flops_pd >= 2 * 128 * 256 * 512
    assert rep.t_compute > 0 and rep.bottleneck in ("compute", "memory",
                                                    "collective")

"""Checkpoint manager: roundtrip, retention, commit atomicity, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager


@pytest.fixture
def tree_():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32),
                    "m": {"w": jnp.full((3, 4), 0.5)}}}


def test_roundtrip(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(42, tree_)
    assert mgr.latest_step() == 42
    restored = mgr.restore(42, tree_)
    for a, b in zip(jax.tree.leaves(tree_), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_and_wait(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, tree_)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_k_retention(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree_)
    assert mgr.all_steps() == [3, 4]


def test_uncommitted_step_ignored(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, tree_)
    # fake a torn write: step dir without MANIFEST
    os.makedirs(tmp_path / "step_000000009")
    assert mgr.latest_step() == 5


def test_corrupted_manifest_skipped(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, tree_)
    mgr.save(6, tree_)
    shutil.rmtree(tmp_path / "step_000000006")
    assert mgr.latest_step() == 5
    step, restored = mgr.restore_latest(tree_)
    assert step == 5 and restored is not None


def test_restore_latest_empty(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore_latest(tree_)
    assert step is None and restored is None


def test_elastic_restore_new_sharding(tmp_path, tree_):
    """Restore with explicit shardings (single-device 'mesh change' path)."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, tree_)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(lambda _: jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()), tree_)
    restored = mgr.restore(3, tree_, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree_), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_extra_metadata(tmp_path, tree_):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(8, tree_, extra={"mesh": [16, 16], "arch": "qwen3_32b"})
    with open(tmp_path / "step_000000008" / "MANIFEST.json") as f:
        man = json.load(f)
    assert man["extra"]["arch"] == "qwen3_32b"

"""Workload scenario subsystem: determinism, golden metrics, trace replay.

Determinism-first harness: every arrival process must produce a
byte-identical ``RequestResult`` stream when re-run with the same seed
(ISSUE acceptance criterion), golden request counts pin the generator
outputs, and rate-envelope checks make sure each shape actually has the
statistical signature it claims (bursty is bursty, diurnal peaks peak).
"""
import math

import pytest

from repro.core.config_store import ConfigStore
from repro.core.router import build_tree
from repro.core.simulator import Simulator, SyntheticServiceModel, summarize
from repro.core.types import FunctionConfig
from repro.workloads import (ARRIVALS, BurstyArrivals, DiurnalArrivals,
                             FunctionProfile, MixedWorkload, PoissonArrivals,
                             SizeDist, TraceArrivals, build_scenario,
                             get_arrival, iats_from_times,
                             install_demo_configs, list_scenarios, read_trace,
                             write_trace)

TRACE_IATS = [0.05, 0.2, 0.01, 0.7, 0.013, 0.5]

# one representative instance of every registered arrival process; the
# registry test below guarantees this stays in sync with ARRIVALS.
PROCESSES = {
    "poisson": lambda: PoissonArrivals(120.0),
    "bursty": lambda: BurstyArrivals(rate_on=800.0, rate_off=40.0,
                                     mean_on_s=0.5, mean_off_s=2.0),
    "diurnal": lambda: DiurnalArrivals(base_rate=120.0, amplitude=0.9,
                                       period_s=4.0),
    "trace": lambda: TraceArrivals(TRACE_IATS, loop=True),
}


def _store():
    s = ConfigStore()
    s.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                         cold_start_s=0.2))
    return s


def _run(workload, store=None):
    sim = Simulator(build_tree(4, fanout=2), store or _store(),
                    SyntheticServiceModel(seed=2), seed=7)
    sim.load(workload)
    return sim.run()


# ------------------------------------------------------- determinism
@pytest.mark.parametrize("kind", sorted(PROCESSES))
def test_arrival_process_deterministic_results(kind):
    """Same seed => byte-identical RequestResult stream, twice in a row."""
    def once():
        wl = MixedWorkload(PROCESSES[kind](),
                           [FunctionProfile("fn", size=SizeDist.const(16))],
                           duration_s=4.0, seed=11)
        return _run(wl)
    a, b = once(), once()
    assert len(a) > 0
    assert a == b
    assert repr(a) == repr(b)          # byte-identical, rids included


def test_mixed_workload_deterministic_results():
    """The 5th shape — weighted multi-function mix — is deterministic too."""
    def once():
        wl = build_scenario("multi_tenant", duration_s=3.0, seed=5)
        store = ConfigStore()
        install_demo_configs(store, wl)
        return _run(wl, store)
    a, b = once(), once()
    assert len(a) > 0
    assert repr(a) == repr(b)


def test_request_stream_byte_identical():
    wl1 = build_scenario("flash_crowd", duration_s=6.0, seed=9)
    wl2 = build_scenario("flash_crowd", duration_s=6.0, seed=9)
    assert repr(wl1.generate()) == repr(wl2.generate())


def test_different_seeds_differ():
    a = build_scenario("steady", duration_s=2.0, seed=1).generate()
    b = build_scenario("steady", duration_s=2.0, seed=2).generate()
    assert [r.arrival_t for r in a] != [r.arrival_t for r in b]


def test_rid_assignment_modes():
    wl = build_scenario("steady", duration_s=1.0, seed=1)
    rids = [r.rid for r in wl.generate()]
    assert rids == list(range(len(rids)))          # deterministic base 0
    off = build_scenario("steady", duration_s=1.0, seed=1, rid_base=1000)
    assert [r.rid for r in off.generate()][0] == 1000
    legacy = build_scenario("steady", duration_s=1.0, seed=1, rid_base=None)
    r1 = legacy.generate()[0].rid                  # process-global counter
    r2 = build_scenario("steady", duration_s=1.0, seed=1,
                        rid_base=None).generate()[0].rid
    assert r2 > r1


def test_mix_rng_independent_of_arrivals():
    """Adding a function to the mix must not perturb arrival times."""
    one = MixedWorkload(PoissonArrivals(100.0),
                        [FunctionProfile("a")], duration_s=3.0, seed=4)
    two = MixedWorkload(PoissonArrivals(100.0),
                        [FunctionProfile("a"), FunctionProfile("b")],
                        duration_s=3.0, seed=4)
    assert ([r.arrival_t for r in one.generate()]
            == [r.arrival_t for r in two.generate()])


# ---------------------------------------------------- golden metrics
def test_golden_request_counts():
    """Pin the exact per-scenario request counts for a fixed seed; any
    change to the generators' RNG consumption shows up here first."""
    counts = {name: len(build_scenario(name, duration_s=5.0, seed=3)
                        .generate())
              for name in ("steady", "flash_crowd", "daily_cycle",
                           "multi_tenant")}
    assert counts == {"steady": 1009, "flash_crowd": 306,
                      "daily_cycle": 922, "multi_tenant": 1523}


def test_poisson_rate_envelope():
    times = [r.arrival_t
             for r in MixedWorkload(PoissonArrivals(200.0),
                                    [FunctionProfile("fn")],
                                    duration_s=20.0, seed=2).generate()]
    n = len(times)
    assert abs(n - 200 * 20) < 4 * math.sqrt(200 * 20)   # ~4 sigma
    iats = iats_from_times(times)
    mean = sum(iats) / n
    cv = (sum((x - mean) ** 2 for x in iats) / n) ** 0.5 / mean
    assert 0.8 < cv < 1.2                                # memoryless


def test_bursty_is_burstier_than_poisson():
    proc = BurstyArrivals(rate_on=2000.0, rate_off=10.0,
                          mean_on_s=0.3, mean_off_s=5.0)
    wl = MixedWorkload(proc, [FunctionProfile("fn")],
                       duration_s=60.0, seed=2)
    iats = iats_from_times([r.arrival_t for r in wl.generate()])
    mean = sum(iats) / len(iats)
    cv = (sum((x - mean) ** 2 for x in iats) / len(iats)) ** 0.5 / mean
    assert cv > 1.5, "MMPP on/off must be over-dispersed vs Poisson"
    n = len(iats)
    expect = proc.mean_rate() * 30.0
    assert 0.3 * expect < n < 3.0 * expect


def test_diurnal_peak_vs_trough():
    """Default phase peaks at t=P/4 and troughs at t=3P/4."""
    period = 40.0
    wl = MixedWorkload(DiurnalArrivals(base_rate=150.0, amplitude=0.9,
                                       period_s=period),
                       [FunctionProfile("fn")], duration_s=period, seed=2)
    times = [r.arrival_t for r in wl.generate()]
    peak = sum(1 for t in times if period * 0.125 <= t < period * 0.375)
    trough = sum(1 for t in times if period * 0.625 <= t < period * 0.875)
    assert peak > 3 * trough, (peak, trough)


def test_mixed_workload_weights_and_sizes():
    wl = build_scenario("multi_tenant", rps=400.0, duration_s=20.0, seed=6)
    reqs = wl.generate()
    share = {fn: sum(r.fn == fn for r in reqs) / len(reqs)
             for fn in ("chat", "embed", "batch")}
    assert abs(share["chat"] - 0.6) < 0.05
    assert abs(share["embed"] - 0.3) < 0.05
    assert abs(share["batch"] - 0.1) < 0.05
    assert {r.size for r in reqs if r.fn == "batch"} <= {256, 512, 1024}
    assert all(8 <= r.size <= 64 for r in reqs if r.fn == "embed")
    assert all(r.size >= 1 for r in reqs)


# ------------------------------------------------------ trace replay
def test_trace_round_trip(tmp_path):
    """write IAT file -> TraceArrivals replays it exactly (bit-exact)."""
    path = str(tmp_path / "trace.iat")
    write_trace(path, TRACE_IATS)
    assert read_trace(path) == TRACE_IATS
    wl = build_scenario("trace_replay", path=path)
    times = [r.arrival_t for r in wl.generate()]
    expect, t = [], 0.0
    for iat in TRACE_IATS:
        t += iat
        expect.append(t)
    assert times == expect
    assert iats_from_times(times) == pytest.approx(TRACE_IATS, abs=1e-12)


def test_trace_comments_and_looping(tmp_path):
    path = str(tmp_path / "trace.iat")
    with open(path, "w") as fh:
        fh.write("# azure-style IAT trace\n0.5\n\n1.0  # tail comment\n")
    assert read_trace(path) == [0.5, 1.0]
    wl = build_scenario("trace_replay", path=path, loop=True,
                        duration_s=6.0)
    times = [r.arrival_t for r in wl.generate()]
    assert times == [0.5, 1.5, 2.0, 3.0, 3.5, 4.5, 5.0]


def test_trace_replay_through_simulator(tmp_path):
    path = str(tmp_path / "trace.iat")
    write_trace(path, [0.01] * 50)
    res = _run(build_scenario("trace_replay", path=path))
    assert len(res) == 50
    assert summarize(res)["fail_rate"] == 0.0


# --------------------------------------------------------- registry
def test_registries_complete():
    assert sorted(ARRIVALS) == ["bursty", "diurnal", "poisson", "trace"]
    assert sorted(PROCESSES) == sorted(ARRIVALS)
    assert set(list_scenarios()) >= {"steady", "flash_crowd", "daily_cycle",
                                     "multi_tenant", "trace_replay"}
    proc = get_arrival("poisson", rate=5.0)
    assert isinstance(proc, PoissonArrivals) and proc.rate == 5.0
    with pytest.raises(KeyError):
        get_arrival("nope")
    with pytest.raises(KeyError):
        build_scenario("nope")


def test_install_demo_configs_preserves_existing():
    store = ConfigStore()
    store.put(FunctionConfig(name="chat", arch="small_lm", concurrency=2))
    wl = build_scenario("multi_tenant", duration_s=1.0)
    install_demo_configs(store, wl)
    assert store.get("chat").arch == "small_lm"      # not overwritten
    assert store.get("chat").concurrency == 2
    assert set(store.list()) == {"chat", "embed", "batch"}

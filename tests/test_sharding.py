"""Logical-axis resolver: priority, divisibility fallback, no-reuse,
emergent per-arch sharding choices."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DECODE_RULES, LONG_DECODE_RULES,
                                        PREFILL_RULES, TRAIN_RULES,
                                        resolve_spec)


@pytest.fixture(scope="module")
def mesh2x2():
    # 1 real device; build an abstract mesh over a 2x2 device grid is not
    # possible — use explicit mesh construction from the single device via
    # AbstractMesh for pure spec resolution.
    return jax.sharding.AbstractMesh((2, 2), ("data", "model"))


@pytest.fixture(scope="module")
def mesh_prod():
    return jax.sharding.AbstractMesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def mesh_multi():
    return jax.sharding.AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_weight_rules(mesh_prod):
    # wq flat [K, D, H*hd]: FSDP on D, TP on flat q dim
    spec = resolve_spec(mesh_prod, (62, 7168, 7168),
                        ("w_layers", "w_embed", "w_qdim"), TRAIN_RULES)
    assert spec == P(None, "data", "model")


def test_divisibility_fallback_replicates(mesh_prod):
    # 504-way vocab doesn't divide 16 => replicated (hubert head)
    spec = resolve_spec(mesh_prod, (504, 1280), ("w_vocab", "w_embed"),
                        TRAIN_RULES)
    assert spec == P(None, "data")


def test_priority_kv_heads_before_kv_seq(mesh_prod):
    # kv=16 divides => heads sharded, seq not (moonshot decode)
    spec = resolve_spec(mesh_prod, (48, 128, 32768, 16, 128),
                        ("w_layers", "act_batch", "act_kv_seq",
                         "act_kv_heads", None), DECODE_RULES)
    assert spec == P(None, "data", None, "model")
    # kv=8 fails => flash-decode fallback: seq sharded (qwen3 decode)
    spec = resolve_spec(mesh_prod, (64, 128, 32768, 8, 128),
                        ("w_layers", "act_batch", "act_kv_seq",
                         "act_kv_heads", None), DECODE_RULES)
    assert spec == P(None, "data", "model")


def test_no_axis_reuse_within_tensor(mesh_prod):
    # once model is used by expert dim, moe ff can't reuse it (moonshot EP)
    spec = resolve_spec(mesh_prod, (48, 64, 2048, 1408),
                        ("w_layers", "w_expert", "w_embed", "w_moe_mlp"),
                        TRAIN_RULES)
    assert spec == P(None, "model", "data")
    # grok: 8 experts fail => ff takes model (TP fallback)
    spec = resolve_spec(mesh_prod, (64, 8, 6144, 32768),
                        ("w_layers", "w_expert", "w_embed", "w_moe_mlp"),
                        TRAIN_RULES)
    assert spec == P(None, None, "data", "model")


def test_multipod_batch_uses_pod_and_data(mesh_multi):
    spec = resolve_spec(mesh_multi, (256, 4096), ("act_batch", "act_seq"),
                        TRAIN_RULES)
    assert spec == P(("pod", "data"))


def test_multipod_degrades_on_single_pod(mesh_prod):
    spec = resolve_spec(mesh_prod, (256, 4096), ("act_batch", "act_seq"),
                        TRAIN_RULES)
    assert spec == P("data")


def test_long_decode_context_parallel(mesh_multi):
    # 500k cache seq over every axis; batch=1 replicated
    spec = resolve_spec(mesh_multi, (9, 1, 524288, 8, 128),
                        ("w_layers", "act_batch", "act_kv_seq",
                         "act_kv_heads", None), LONG_DECODE_RULES)
    assert spec == P(None, None, ("pod", "data", "model"))


def test_prefill_shards_cache(mesh_prod):
    spec = resolve_spec(mesh_prod, (88, 32, 32768, 8, 128),
                        ("w_layers", "act_batch", "act_kv_seq",
                         "act_kv_heads", None), PREFILL_RULES)
    assert spec == P(None, "data", "model")


def test_unconstrained_for_constraint_mode(mesh_prod):
    spec = resolve_spec(mesh_prod, (32, 4096, 56, 128),
                        ("act_batch", "act_seq", "act_heads", None),
                        TRAIN_RULES, for_constraint=True)
    assert spec[2] is P.UNCONSTRAINED       # 56 heads: GSPMD's choice
    spec2 = resolve_spec(mesh_prod, (32, 4096, 56, 128),
                         ("act_batch", "act_seq", "act_heads", None),
                         TRAIN_RULES)
    assert spec2 == P("data")               # concrete mode replicates


def test_spec_always_valid_shapes(mesh_prod):
    """Resolved axis sizes always divide the dim."""
    import itertools
    names = ["act_batch", "act_kv_seq", "act_kv_heads", "act_mlp", None]
    for dims in itertools.product([1, 8, 16, 56, 128, 4096], repeat=3):
        spec = resolve_spec(mesh_prod, dims, tuple(names[:3]), DECODE_RULES)
        for d, entry in zip(dims, tuple(spec) + (None,) * 3):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= dict(data=16, model=16)[a]
            assert d % size == 0

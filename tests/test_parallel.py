"""Parallel simulation runner suite (ISSUE 10).

Five contracts:

1. **Serial equivalence** — ``run_partitioned`` with ``parallelism=1``
   is byte-identical to a plain serial run (results, telemetry,
   decision logs); the K-partition merged stream byte-equals the serial
   union run on partition-friendly scenarios (the shared op-sequence
   driver in ``tests/_prop_drivers.py``, run here over fixed seeds and
   by tests/test_property.py under hypothesis).
2. **Transport equivalence** — process mode and inline mode execute the
   same driver protocol against identical simulators, so their merged
   output is byte-identical.
3. **Coupling** — a K=1 barrier-coupled run with a global ceiling
   byte-equals the serial gateway run with that same ``max_inflight``;
   ``split_ceiling`` apportions exactly (sum, floor-of-1, determinism);
   ``Gateway.set_ceiling`` only gates *new* admissions.
4. **Primitives** — ``ResultSink`` folds a result stream into the exact
   ``part_summary`` partial + results-stream digest; ``merge_fleet_samples``
   combines per-partition metrics order-independently;
   ``conservative_window`` derives the documented lookahead.
5. **Determinism** — same seed + same partition count ⇒ byte-identical
   merged output across repeated runs (driver property 2).
"""
import multiprocessing
from types import SimpleNamespace

import numpy as np
import pytest

from repro.autoscale import Autoscaler
from repro.autoscale.metrics import (FnSample, MetricsSample,
                                     merge_fleet_samples)
from repro.core.config_store import ConfigStore
from repro.core.gateway import Gateway, GatewayConfig
from repro.core.router import build_tree, tenant_index
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  part_summary, stream_digest, summarize)
from repro.core.types import FunctionConfig, Request
from repro.parallel import (ResultSink, conservative_window,
                            partition_streams, run_partitioned,
                            split_ceiling)
from repro.parallel.partition import maybe_attach_sink
from repro.workloads import (FunctionProfile, MixedWorkload, PoissonArrivals,
                             SizeDist)

FORK = "fork" in multiprocessing.get_all_start_methods()


# ------------------------------------------------------- split_ceiling


def test_split_ceiling_proportional_and_exact():
    assert split_ceiling(10, [1.0, 1.0]) == [5, 5]
    assert split_ceiling(10, [3.0, 1.0]) == [8, 2]
    # remainder ties break toward the lower partition index
    assert split_ceiling(3, [1.0, 1.0]) == [2, 1]
    for total, demands in [(7, [5.0, 2.0, 1.0]), (16, [0.1, 9.9, 3.0, 3.0]),
                           (100, [1e-9, 1.0, 2.0])]:
        alloc = split_ceiling(total, demands)
        assert sum(alloc) == total
        assert all(isinstance(a, int) for a in alloc)
        assert alloc == split_ceiling(total, demands)   # deterministic


def test_split_ceiling_floor_of_one():
    """When the ceiling covers every partition, an idle partition keeps
    one slot — otherwise it could never regenerate the occupancy that
    wins quota back."""
    assert split_ceiling(4, [100.0, 0.0, 0.0, 0.0]) == [1, 1, 1, 1]
    alloc = split_ceiling(8, [50.0, 0.0, 1.0, 0.0])
    assert sum(alloc) == 8 and min(alloc) >= 1
    # total < K: the floor is unaffordable, lowest-remainder loses out
    assert split_ceiling(2, [1.0, 1.0, 1.0]) == [1, 1, 0]
    # degenerate demand: even split
    assert split_ceiling(6, [0.0, 0.0, 0.0]) == [2, 2, 2]
    assert split_ceiling(5, []) == []


# ------------------------------------------------ lookahead derivation


def _store(**cold_by_fn):
    store = ConfigStore()
    for fn, cold in cold_by_fn.items():
        store.put(FunctionConfig(name=fn, arch="tiny_lm",
                                 cold_start_s=cold))
    return store


def _sim(store, **kw):
    return Simulator(build_tree(2, fanout=2), store,
                     SyntheticServiceModel(seed=1), seed=1, **kw)


def test_conservative_window_derivation():
    # shortest cold start across registered functions
    assert conservative_window(_sim(_store(a=0.05, b=0.2))) == 0.05
    # unset cold_start_s falls back to the simulator default
    sim = _sim(_store(a=None))
    assert conservative_window(sim) == sim.cold_default
    # an attached autoscaler caps the window at its tick period
    sim = _sim(_store(a=0.5))
    sim.attach_autoscaler(Autoscaler("reactive", interval_s=0.25))
    assert conservative_window(sim) == 0.25
    # floored at 1 ms so instant cold starts can't spin the barrier loop
    assert conservative_window(_sim(_store(a=0.0))) == 1e-3


# ---------------------------------------------------- stream bucketing


def test_partition_streams_matches_tenant_hash():
    streams = [MixedWorkload(PoissonArrivals(rate=5.0),
                             [FunctionProfile(fn=f"t{j}")],
                             duration_s=1.0, seed=j)
               for j in range(11)]
    buckets = partition_streams(streams, 3)
    assert len(buckets) == 3
    assert sum(len(b) for b in buckets) == len(streams)
    for k, bucket in enumerate(buckets):
        for s in bucket:
            assert tenant_index(s.profiles[0].fn, 3) == k
    # custom key override
    by_seed = partition_streams(streams, 2, key=lambda s: f"s{s.seed}")
    assert sum(len(b) for b in by_seed) == len(streams)


# --------------------------------------------------------- ResultSink


def _small_run(**kw):
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=2,
                             cold_start_s=0.05, timeout_s=0.5))
    sim = Simulator(build_tree(4, fanout=2), store,
                    SyntheticServiceModel(seed=3), seed=9, **kw)
    wl = MixedWorkload(PoissonArrivals(rate=150.0),
                       [FunctionProfile(fn="fn", size=SizeDist.const(16))],
                       duration_s=1.5, seed=42)
    sim.load(wl)
    return sim


def test_result_sink_matches_list_reference():
    """Folding rows through a sink reproduces the ``part_summary``
    partial and the results-only stream digest of the retained list —
    including failed rows (timeouts) in the hash and counts."""
    sim = _small_run()
    sim.run()
    assert len(sim.results) > 0
    sink = ResultSink()
    for r in sim.results:
        sink.append(r)
    assert len(sink) == len(sim.results)
    ref = part_summary(sim.results)
    got = sink.part()
    for key in ("n", "ok", "served", "cold", "t0", "t1"):
        assert got[key] == ref[key], key
    np.testing.assert_array_equal(got["lat"], ref["lat"])
    # results-only digest == stream_digest with the side streams empty
    shim = SimpleNamespace(results=sim.results, telemetry=[],
                           workflow_results=[])
    assert sink.digest() == stream_digest(shim)


def test_result_sink_substitution_is_transparent():
    """A sim run with the sink swapped in produces the same summary and
    result digest as the same-seed run with the real list."""
    ref = _small_run(collect_telemetry=False)
    ref.run()
    sim = _small_run(collect_telemetry=False)
    sink = maybe_attach_sink(sim)
    assert sink is not None and sim.results is sink
    sim.run()
    assert sink.digest() == stream_digest(ref)
    from repro.core.simulator import merge_part_summaries
    assert merge_part_summaries([sink.part()]) == summarize(ref.results)


def test_maybe_attach_sink_refuses_illegal_states():
    # an autoscaler slices sim.results[last:] per tick: needs the list
    sim = _small_run()
    sim.attach_autoscaler(Autoscaler("reactive", interval_s=0.5))
    assert maybe_attach_sink(sim) is None
    assert isinstance(sim.results, list)
    # rows already recorded: folding would miss them
    sim2 = _small_run()
    sim2.run()
    assert maybe_attach_sink(sim2) is None


# --------------------------------------------- K=1 serial equivalence


def _k1_build(k, n, **kw):
    assert (k, n) == (0, 1)
    return _small_run(record_decisions=True, **kw)


def test_parallelism_1_byte_identical_to_serial():
    serial = _small_run(record_decisions=True)
    serial.run()
    merged = run_partitioned(_k1_build, 1)
    assert merged.mode == "inline"           # K=1 never forks
    assert stream_digest(merged) == stream_digest(serial)
    assert merged.digest() == stream_digest(serial)
    assert merged.routing_log() == serial.routing_log()
    assert merged.placement_log() == serial.placement_log()
    assert merged.gateway_log() == serial.gateway_log()
    assert merged.fault_log() == serial.fault_log()
    assert merged.summary() == summarize(serial.results)
    assert merged.counters["arrivals_seen"] == serial.arrivals_seen
    assert merged.counters["events_processed"] == serial.events_processed
    # forcing window barriers changes nothing but the barrier history
    win = run_partitioned(_k1_build, 1, window_s=0.2)
    assert stream_digest(win) == stream_digest(serial)
    assert win.barriers and win.barriers[-1]["pending"] == [0]


def test_coupled_k1_equals_serial_gateway_run():
    """A K=1 coupled run IS a serial gateway run: the barrier loop
    apportions the whole ceiling to the only partition, so the windowed
    run must byte-equal the plain run with ``max_inflight`` set from
    the start (resume-exactness of ``run(until)`` + ceiling no-op)."""
    M = 3
    serial = _small_run(record_decisions=True,
                        gateway=GatewayConfig(max_inflight=M))
    serial.run()
    assert serial.gateway.shed_total > 0     # the ceiling binds
    merged = run_partitioned(
        lambda k, n: _k1_build(k, n, gateway=GatewayConfig(max_inflight=M)),
        1, max_inflight=M)
    assert stream_digest(merged) == stream_digest(serial)
    assert merged.gateway_log() == serial.gateway_log()
    assert merged.counters["gw_admitted"] == serial.gateway.admitted_total
    assert merged.counters["gw_shed"] == serial.gateway.shed_total
    assert all(b["ceilings"] == [M] for b in merged.barriers)


# ------------------------------------------------ transport equality


def _det_build(k, n):
    """Partition builder for the K=2 transport test (module-level so the
    closure forks cleanly): deterministic service, tenant_hash root."""
    from _prop_drivers import _DetServiceModel
    from repro.core.router import LBNode, build_leaf
    streams = [MixedWorkload(PoissonArrivals(rate=20.0),
                             [FunctionProfile(fn=f"t{j}",
                                              size=SizeDist.const(16))],
                             duration_s=1.0, seed=500 + j,
                             rid_base=j * 1_000_000)
               for j in range(4)]
    mine = partition_streams(streams, n)[k]
    store = ConfigStore()
    for s in mine:
        store.put(FunctionConfig(name=s.profiles[0].fn, arch="tiny_lm",
                                 concurrency=2, cold_start_s=0.05))
    sim = Simulator(
        LBNode("root", "tenant_hash",
               children=[build_leaf(f"p{k}", [f"p{k}w0", f"p{k}w1"],
                                    "round_robin")]),
        store, _DetServiceModel(), seed=7, record_decisions=True,
        iid_scope="worker", collect_telemetry=False)
    for s in mine:
        sim.load(s)
    return sim


@pytest.mark.skipif(not FORK, reason="fork start method unavailable")
def test_process_mode_matches_inline():
    inline = run_partitioned(_det_build, 2, mode="inline")
    proc = run_partitioned(_det_build, 2, mode="process")
    assert proc.mode == "process"
    assert stream_digest(proc) == stream_digest(inline)
    assert proc.digests == inline.digests
    assert proc.routing_log() == inline.routing_log()
    assert proc.counters == inline.counters
    assert proc.summary() == inline.summary()
    # summary collect ships partials instead of rows, same projection
    sproc = run_partitioned(_det_build, 2, mode="process",
                            collect="summary")
    assert sproc.digests == inline.digests
    assert sproc.results == []
    assert sproc.summary() == inline.summary()


# ------------------------------------------------- Gateway.set_ceiling


def test_set_ceiling_only_gates_new_admits():
    gw = Gateway(GatewayConfig(max_inflight=4))
    reqs = [Request(fn="f", arrival_t=0.0, rid=i) for i in range(4)]
    for r in reqs[:3]:
        assert gw.admit(r, 0.0) is None
    gw.set_ceiling(1)
    assert gw.inflight == 3                  # existing admits keep slots
    assert gw.admit(reqs[3], 0.1) is not None    # new admit sees ceiling 1
    for r in reqs[:3]:
        gw.release(r, 0.2)
    assert gw.admit(reqs[3], 0.3) is None    # below the new ceiling again
    gw.set_ceiling(None)                     # uncapped
    for i in range(10, 20):
        assert gw.admit(Request(fn="f", arrival_t=0.4, rid=i), 0.4) is None


# ---------------------------------------------- windowed metrics merge


def test_merge_fleet_samples():
    a = MetricsSample(t=1.0, replicas=2, workers=4, queue=3, inflight=5,
                      arrivals=10, completions=8, cold_starts=1,
                      fns=(FnSample(fn="a", queue=3, inflight=5, arrivals=10,
                                    completions=8, warm=2, p95_est=0.3,
                                    shed=1, goodput=7),), unhealthy=1)
    b = MetricsSample(t=2.0, replicas=1, workers=2, queue=1, inflight=2,
                      arrivals=4, completions=3, cold_starts=0,
                      fns=(FnSample(fn="b", queue=1, inflight=2, arrivals=4,
                                    completions=3, warm=1, p95_est=0.1),
                           FnSample(fn="a", queue=0, inflight=0, arrivals=1,
                                    completions=1, warm=1, p95_est=0.5)))
    m = merge_fleet_samples([a, None, b])
    assert (m.t, m.replicas, m.workers) == (2.0, 3, 6)
    assert (m.queue, m.inflight, m.arrivals) == (4, 7, 14)
    assert (m.completions, m.cold_starts, m.unhealthy) == (11, 1, 1)
    assert [f.fn for f in m.fns] == ["a", "b"]       # re-sorted by name
    fa = m.fn("a")
    assert (fa.arrivals, fa.completions, fa.warm) == (11, 9, 3)
    assert fa.p95_est == 0.5                         # max, not sum
    assert (fa.shed, fa.goodput) == (1, 7)
    # order-independent and None-tolerant
    assert merge_fleet_samples([b, a]) == m
    assert merge_fleet_samples([]).workers == 0
    assert merge_fleet_samples([None]).t == 0.0


# ------------------------------------ op-sequence property driver (ISSUE 10)
# Fixed-seed runs keep the partition-merge invariants in the tier-1 lane
# even without hypothesis; tests/test_property.py wraps the same driver
# in @given(integers()) to explore the seed space in CI.
@pytest.mark.parametrize("seed", range(3))
def test_partition_merge_byte_equivalence(seed):
    from _prop_drivers import run_partition_merge_ops
    assert run_partition_merge_ops(seed) > 0

"""Front-door gateway suite (ISSUE 9).

Five contracts:

1. **Mechanics** — token buckets refill continuously and never go
   negative; priority resolution (request stamp > tenant quota >
   interactive); per-class admission ceilings (batch sheds first,
   against its *own* occupancy); release is exactly-once.
2. **Terminal sheds** — ``rate limited`` / ``admission rejected`` are
   final platform answers: recorded before routing, never retried.
3. **Off = absent** — a ``GatewayConfig(enabled=False)`` (or no gateway
   at all) run is byte-identical to the pre-gateway simulator; the
   goldens in tests/test_scheduling.py pin the digests themselves.
4. **Determinism + replay** — same seed ⇒ byte-identical verdict
   sequence; a recorded verdict log replays byte-for-byte through
   ``ReplayGateway`` and raises loudly on divergence.
5. **The noisy-neighbor A/B** — under a 10x batch flood the gateway
   holds every interactive tenant's p95 within SLO and beats the
   no-gateway baseline's goodput on the same fleet (equal
   worker-seconds); on a memory-tight fleet it un-starves tenants the
   flood had pinned to *zero* completions.
"""
import pytest

from repro.autoscale import Autoscaler, build_pool
from repro.autoscale.replay import ReplayGateway
from repro.core.config_store import ConfigStore
from repro.core.gateway import (ADMISSION_REJECTED, RATE_LIMITED, Gateway,
                                GatewayConfig, TenantQuota, TokenBucket)
from repro.core.router import build_leaf
from repro.core.simulator import (RETRYABLE_ERRORS, Simulator,
                                  SyntheticServiceModel, summarize)
from repro.core.types import FunctionConfig, Request
from repro.workloads import build_scenario

from _prop_drivers import digest_sim as _digest

# -------------------------------------------------------------- mechanics


def test_token_bucket_continuous_refill():
    b = TokenBucket(rate=2.0, burst=3.0)
    assert [b.take(0.0) for _ in range(4)] == [True, True, True, False]
    assert b.level == pytest.approx(0.0)       # empty, never negative
    assert not b.take(0.4)                     # 0.8 tokens: still short
    assert b.take(0.5)                         # 1.0 accrued
    # refill caps at burst regardless of idle time
    b2 = TokenBucket(rate=100.0, burst=2.0)
    assert b2.take(0.0) and b2.take(100.0)
    assert b2.level == pytest.approx(1.0)


def test_priority_resolution_order():
    gw = Gateway(GatewayConfig(
        quotas={"f": TenantQuota(rate=10.0, priority="batch")}))
    stamped = Request(fn="f", arrival_t=0.0, rid=0, priority="interactive")
    quota_only = Request(fn="f", arrival_t=0.0, rid=1)
    unknown = Request(fn="g", arrival_t=0.0, rid=2)
    assert gw.priority_of(stamped) == "interactive"   # stamp wins
    assert gw.priority_of(quota_only) == "batch"      # quota default
    assert gw.priority_of(unknown) == "interactive"   # global default


def test_admission_ceiling_is_per_class():
    """Batch is capped at ``batch_share * max_inflight`` against its
    *own* occupancy — interactive backlog must not starve batch out of
    its share, and a batch flood cannot occupy interactive headroom."""
    gw = Gateway(GatewayConfig(max_inflight=4, batch_share=0.5))
    mk = lambda i, pri: Request(fn="f", arrival_t=0.0, rid=i,  # noqa: E731
                                priority=pri)
    assert gw.admit(mk(0, "batch"), 0.0) is None
    assert gw.admit(mk(1, "batch"), 0.0) is None
    assert gw.admit(mk(2, "batch"), 0.0) == ADMISSION_REJECTED
    # interactive has its own ceiling (4), untouched by batch occupancy
    for i in range(4):
        assert gw.admit(mk(10 + i, "interactive"), 0.0) is None
    assert gw.admit(mk(14, "interactive"), 0.0) == ADMISSION_REJECTED
    # ... and batch stays saturated even though interactive is too
    assert gw.inflight_by_pri == {"interactive": 4, "batch": 2}
    assert gw.inflight == 6


def test_release_exactly_once():
    gw = Gateway(GatewayConfig(max_inflight=2))
    r = Request(fn="f", arrival_t=0.0, rid=0)
    assert gw.admit(r, 0.0) is None
    gw.release(r, 1.0)
    gw.release(r, 1.0)                  # double-release: no-op
    assert gw.inflight == 0
    assert gw.inflight_by_pri["interactive"] == 0
    shed = Request(fn="f", arrival_t=0.0, rid=1)
    gw2 = Gateway(GatewayConfig(default_quota=TenantQuota(rate=0.0,
                                                          burst=0.0)))
    assert gw2.admit(shed, 0.0) == RATE_LIMITED
    gw2.release(shed, 1.0)              # shed was never admitted: no-op
    assert gw2.inflight == 0


def test_retry_consult_only_rechecks_saturation():
    """A retry already holds its slot and paid its token: it is refused
    only when its class is saturated, and an admitted retry must not
    double-count inflight or burn a second token."""
    gw = Gateway(GatewayConfig(
        quotas={"f": TenantQuota(rate=0.0, burst=1.0)}, max_inflight=8))
    r = Request(fn="f", arrival_t=0.0, rid=0)
    assert gw.admit(r, 0.0) is None                   # spends the only token
    assert gw.admit(r, 1.0, retry=True) is None       # no second token needed
    assert gw.inflight == 1
    assert gw.admitted_total == 1


# --------------------------------------------------------- terminal sheds


def _leaf_sim(gateway, **over):
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.0, timeout_s=8.0))
    return Simulator(build_leaf("b", ["w0"], "least_loaded"), store,
                     SyntheticServiceModel(seed=2), seed=5,
                     gateway=gateway, **over)


def test_rate_limit_shed_is_terminal_not_retryable():
    assert RATE_LIMITED not in RETRYABLE_ERRORS
    assert ADMISSION_REJECTED not in RETRYABLE_ERRORS
    sim = _leaf_sim(GatewayConfig(
        quotas={"fn": TenantQuota(rate=1.0, burst=2.0)}), retry_budget=3)
    for i in range(5):                  # burst of 5 at t=0: 2 tokens
        sim.submit(Request(fn="fn", arrival_t=0.0, rid=i))
    res = sim.run()
    shed = [r for r in res if not r.ok]
    assert len(shed) == 3
    assert all(r.error == RATE_LIMITED for r in shed)
    # a shed is a final answer: recorded before routing, never retried
    assert all(r.instance == "-" and r.finish_t == r.arrival_t
               for r in shed)
    assert sim.retries_scheduled == 0
    assert sim.gateway.summary()["shed_by_error"] == {RATE_LIMITED: 3}


def test_shed_accounting_reconciles_with_arrivals():
    sim = _leaf_sim(GatewayConfig(
        quotas={"fn": TenantQuota(rate=10.0, burst=1.0)}))
    for i in range(20):
        sim.submit(Request(fn="fn", arrival_t=0.01 * i, rid=i))
    sim.run()
    gw = sim.gateway
    assert gw.admitted_total + gw.shed_total == sim.arrivals_seen
    assert gw.inflight == 0             # every admit was released
    assert gw.inflight_by_pri == {"interactive": 0, "batch": 0}


def test_hedge_clones_bypass_the_gateway():
    """Hedge clones are the platform's own speculation — they must not
    spend tenant tokens or admission slots (the primary already holds
    its slot; a winning clone releases *that* slot via its handle)."""
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=1,
                             cold_start_s=0.0))
    sim = Simulator(build_leaf("b", ["w0", "w1"], "least_loaded"), store,
                    SyntheticServiceModel(seed=2), seed=5,
                    hedge_after_s=0.02,
                    gateway=GatewayConfig(
                        quotas={"fn": TenantQuota(rate=1.0, burst=1.0)}))
    sim.set_straggler("w0", 50.0)
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    res = sim.run()
    assert len(res) == 1 and res[0].ok
    assert res[0].worker == "w1"        # the clone won
    assert sim.hedges_seen == 1
    gw = sim.gateway
    assert gw.admitted_total == 1       # the primary, once
    assert gw.shed_total == 0
    assert gw.inflight == 0             # winner's release hit the primary


# ----------------------------------------------------------- off = absent


def _steady_sim(gateway):
    from repro.workloads import install_demo_configs
    wl = build_scenario("steady", rps=200.0, duration_s=4.0, seed=3)
    store = ConfigStore()
    install_demo_configs(store, wl)
    sim = Simulator(build_pool(1, 2), store, SyntheticServiceModel(seed=2),
                    seed=7, gateway=gateway)
    sim.load(wl)
    sim.run()
    return sim


def test_disabled_config_is_byte_identical_to_no_gateway():
    base = _steady_sim(None)
    off = _steady_sim(GatewayConfig(enabled=False))
    assert off.gateway is None          # disabled config attaches nothing
    assert _digest(off) == _digest(base)
    # an enabled-but-unlimited gateway changes no routing/service byte
    # either — it only adds accounting
    unlimited = _steady_sim(GatewayConfig())
    assert unlimited.gateway is not None
    assert _digest(unlimited) == _digest(base)
    assert unlimited.gateway.admitted_total == unlimited.arrivals_seen


# --------------------------------------------------- determinism + replay


def test_same_seed_byte_identical_verdicts():
    a = _noisy_sim(gateway=True, record=True)
    b = _noisy_sim(gateway=True, record=True)
    assert a.gateway.decision_records() == b.gateway.decision_records()
    assert a.gateway_log() == b.gateway_log()
    assert a.gateway_log()              # non-empty: verdicts were logged
    assert _digest(a) == _digest(b)


def test_recorded_verdicts_replay_byte_identically():
    live = _noisy_sim(gateway=True, record=True)
    records = live.gateway.decision_records()
    assert any(r["verdict"] != "admit" for r in records)
    replay = _noisy_sim(gateway=ReplayGateway(records))
    assert _digest(replay) == _digest(live)
    assert replay.gateway.summary() == live.gateway.summary()


def test_replay_divergence_raises():
    gw = ReplayGateway([{"rid": 7, "verdict": "admit"}])
    with pytest.raises(ValueError, match="diverged"):
        gw.admit(Request(fn="f", arrival_t=0.0, rid=8), 0.0)


# ------------------------------------------------- the noisy-neighbor A/B
#
# Calibrated rig (same fleet both arms — equal worker-seconds): two
# memory-capped workers, a 10x Poisson batch flood over two interactive
# tenants, hedging on. The flood's per-worker replica cap (1) means the
# baseline never *starves* the interactive tenants of memory — instead
# it queues itself to the 8 s timeout horizon, and every queued request
# crosses the 0.6 s hedge threshold: ~14k clones double the flood's
# service demand and halve the fleet's useful capacity. The gateway's
# batch admission ceiling keeps the flood's outstanding work at 6, so
# its queue never builds, nothing hedges, and the same fleet clears
# ~1.65x the goodput with the flood's own p95 down from 8 s to 43 ms.

_CONC = {"chat": 4, "embed": 2, "flood": 2}
_SLO = {"chat": 0.5, "embed": 1.0, "flood": 5.0}


def _noisy_sim(*, gateway, record=False, mem=1536, flood_maxi=1,
               batch_limit=6):
    gw_kw = {}
    if gateway is True:
        # max_inflight * batch_share = the batch admission ceiling
        gw_kw = dict(flood_rate=400.0, flood_burst=8.0,
                     max_inflight=4 * batch_limit, batch_share=0.25)
    wl = build_scenario("noisy_neighbor", gateway=gateway is True,
                        seed=3, duration_s=12.0, **gw_kw)
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(
            name=p.fn, arch="tiny_lm", concurrency=_CONC[p.fn],
            cold_start_s=0.2, timeout_s=8.0,
            idle_timeout_s=1.0 if p.fn == "flood" else 10.0,
            max_instances_per_worker=(flood_maxi if p.fn == "flood"
                                      else 8)))
    sim = Simulator(build_pool(1, 2, leaf_policy="warm_least_loaded",
                               inner_policy="round_robin"),
                    store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                    seed=11, hedge_after_s=0.6, worker_memory_mb=mem,
                    record_decisions=record)
    if not isinstance(gateway, bool) and gateway is not None:
        sim.attach_gateway(gateway)
    sim.load(wl)
    sim.run()
    return sim


def _per_fn(sim):
    out = {}
    for fn in _SLO:
        rows = [r for r in sim.results if r.fn == fn]
        lat = sorted(r.latency for r in rows if r.ok)
        out[fn] = dict(
            offered=len(rows), ok=len(lat),
            p95=lat[int(0.95 * len(lat))] if lat else None,
            slo_ok=sum(1 for r in rows
                       if r.ok and r.latency <= _SLO[r.fn]))
    return out


def test_noisy_neighbor_gateway_wins_goodput_and_holds_slo():
    """The acceptance A/B: same fleet, same seed, gateway on vs off."""
    base = _noisy_sim(gateway=False)
    gated = _noisy_sim(gateway=True)
    assert sorted(base.workers) == sorted(gated.workers)  # equal fleet
    gp_base = summarize(base.results)["goodput"]
    gp_gw = summarize(gated.results)["goodput"]
    assert gp_gw >= 1.2 * gp_base, (gp_gw, gp_base)
    pf_base, pf_gw = _per_fn(base), _per_fn(gated)
    # every non-flood tenant's p95 holds within SLO under the flood
    for fn in ("chat", "embed"):
        assert pf_gw[fn]["p95"] <= _SLO[fn], (fn, pf_gw[fn])
        assert pf_gw[fn]["ok"] >= 0.95 * pf_gw[fn]["offered"]
    # the baseline flood queues to the timeout horizon and mass-hedges;
    # the admission ceiling collapses both
    assert pf_base["flood"]["p95"] > _SLO["flood"]
    assert pf_gw["flood"]["p95"] < 0.5
    assert base.hedges_seen > 1000
    assert gated.hedges_seen < 50
    assert gated.gateway.shed_by_error[ADMISSION_REJECTED] > 0


def test_noisy_neighbor_gateway_unstarves_pinned_tenants():
    """On a roomier fleet with no per-worker replica cap the flood wins
    every memory slot at t=0 and pins ``embed`` to *zero* completions
    for the whole run; the batch admission ceiling bounds the flood's
    replica footprint, so both interactive tenants come back within SLO
    — and the flood itself drops from the 8 s timeout horizon to tens
    of milliseconds. The *interactive* class's SLO-goodput is what the
    isolation buys (the aggregate win is the A/B test above)."""
    base = _noisy_sim(gateway=False, mem=2048, flood_maxi=8)
    gated = _noisy_sim(gateway=True, mem=2048, flood_maxi=8,
                       batch_limit=5)
    pf_base, pf_gw = _per_fn(base), _per_fn(gated)
    assert pf_base["embed"]["ok"] == 0          # fully starved
    for fn in ("chat", "embed"):
        assert pf_gw[fn]["ok"] >= 0.95 * pf_gw[fn]["offered"]
        assert pf_gw[fn]["p95"] <= _SLO[fn]
    assert pf_gw["flood"]["p95"] < 0.5
    inter = lambda pf: pf["chat"]["slo_ok"] + pf["embed"]["slo_ok"]  # noqa: E731
    assert inter(pf_gw) > 1.25 * inter(pf_base)


# ------------------------------------------------------- control plane


def test_gateway_verdict_log_records_arrival_sheds():
    sim = _noisy_sim(gateway=True, record=True)
    log = sim.gateway_log().splitlines()
    assert log
    assert all(line.startswith("t=") and " rid=" in line for line in log)
    assert any("verdict=admission rejected" in line for line in log)
    assert any("verdict=admit" in line for line in log)
    # one verdict line per offered (non-hedge) arrival
    assert len([ln for ln in log if " arrival " in ln]) == sim.arrivals_seen


def test_fn_samples_carry_shed_and_goodput():
    wl = build_scenario("noisy_neighbor", gateway=True, seed=3,
                        duration_s=4.0, flood_rate=40.0, flood_burst=8.0,
                        max_inflight=64, batch_share=0.25)
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(name=p.fn, arch="tiny_lm",
                                 concurrency=_CONC[p.fn], cold_start_s=0.2,
                                 timeout_s=8.0))
    sim = Simulator(build_pool(1, 2), store,
                    SyntheticServiceModel(seed=2, fail_rate=0.0), seed=11)
    scaler = Autoscaler("reactive", interval_s=0.25, window_s=16.0,
                        min_replicas=1, max_replicas=1)
    sim.attach_autoscaler(scaler)
    sim.load(wl)
    sim.run()
    rows = [f for s in scaler.window.samples for f in s.fns]
    flood = [f for f in rows if f.fn == "flood"]
    assert sum(f.shed for f in flood) > 0
    assert sum(f.goodput for f in flood) > 0
    # with a gateway attached, per-fn arrivals are the *admitted* delta
    assert (sum(f.arrivals for f in flood)
            <= sim.gateway.admitted_by_fn["flood"])
    # interactive tenants were never shed in this shape
    assert sum(f.shed for f in rows if f.fn == "chat") == 0


def test_scenario_carries_gateway_config_and_load_attaches_once():
    wl = build_scenario("noisy_neighbor", seed=1, flood_rate=10.0,
                        max_inflight=16)
    assert isinstance(wl.gateway, GatewayConfig)
    assert wl.gateway.quotas["flood"].rate == 10.0
    assert wl.gateway.quotas["flood"].priority == "batch"
    store = ConfigStore()
    for p in wl.profiles:
        store.put(FunctionConfig(name=p.fn, arch="tiny_lm", concurrency=4,
                                 cold_start_s=0.1))
    sim = Simulator(build_pool(1, 2), store, SyntheticServiceModel(seed=2),
                    seed=5)
    sim.load(wl)
    assert sim.gateway is not None
    assert sim.gateway.config is wl.gateway
    # an explicitly attached gateway is not overwritten by load()
    sim2 = Simulator(build_pool(1, 2), store, SyntheticServiceModel(seed=2),
                     seed=5, gateway=GatewayConfig(max_inflight=4))
    gw = sim2.gateway
    sim2.load(build_scenario("noisy_neighbor", seed=1))
    assert sim2.gateway is gw


def test_custom_admission_policy_subclass():
    """The override point the README documents: subclass + ``decide``
    carries a bespoke policy while admit/release keep the bookkeeping."""
    class BlockTenant(Gateway):
        def decide(self, req, now, *, retry):
            if req.fn == "fn" and not retry:
                return ADMISSION_REJECTED
            return super().decide(req, now, retry=retry)

    sim = _leaf_sim(BlockTenant(GatewayConfig()))
    sim.submit(Request(fn="fn", arrival_t=0.0, rid=0))
    res = sim.run()
    assert len(res) == 1 and not res[0].ok
    assert res[0].error == ADMISSION_REJECTED
    assert sim.gateway.shed_total == 1 and sim.gateway.inflight == 0


def test_priority_stamped_from_function_profile():
    wl = build_scenario("noisy_neighbor", seed=1, duration_s=0.5)
    reqs = list(wl.requests())
    pri = {r.fn: r.priority for r in reqs}
    assert pri["flood"] == "batch"
    assert pri["chat"] == "interactive"

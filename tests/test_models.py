"""Per-arch smoke: reduced config, one forward/train step on CPU, finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import assigned_archs, get_config, reduced
from repro.models import build_model


def make_batch(cfg, rng, B=2, S=32):
    if cfg.frontend == "frames":
        return {"frames": jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16),
                "labels": jnp.zeros((B, S), jnp.int32),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.frontend == "patches":
        b["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", assigned_archs())
def test_smoke_forward_and_grads(arch, rng):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, attn_block=16)
    params = model.init_params(rng)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch
    x, _ = model.forward_seq(params, batch, want_cache=False)
    assert x.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    g = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))(params, batch)
    gsum = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
               for l in jax.tree.leaves(g))
    assert np.isfinite(gsum) and gsum > 0, arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3_32b", "jamba15_large", "falcon_mamba_7b"])
def test_unroll_matches_scan(arch, rng):
    cfg = reduced(get_config(arch))
    scan_m = build_model(cfg, attn_block=16)
    unroll_m = build_model(cfg, attn_block=16, unroll=True)
    params = scan_m.init_params(rng)
    batch = make_batch(cfg, rng)
    l1 = scan_m.loss_fn(params, batch)[0]
    l2 = unroll_m.loss_fn(params, batch)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)


def test_abstract_params_match_init(rng):
    cfg = reduced(get_config("qwen3_32b"))
    model = build_model(cfg)
    abs_p = model.abstract_params()
    real_p = model.init_params(rng)
    ja, jr = jax.tree.leaves(abs_p), jax.tree.leaves(real_p)
    assert len(ja) == len(jr)
    for a, r in zip(ja, jr):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_param_axes_structure():
    cfg = reduced(get_config("jamba15_large"))
    model = build_model(cfg)
    axes = model.param_axes()
    abs_p = model.abstract_params()
    for ax, leaf in zip(jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)),
                        jax.tree.leaves(abs_p)):
        assert len(ax) == len(leaf.shape)


def test_input_specs_cover_all_shapes():
    from repro.configs import SHAPES
    cfg = reduced(get_config("phi3_vision"))
    model = build_model(cfg)
    for s in SHAPES.values():
        specs, axes = model.input_specs(s)
        assert set(specs) == set(axes)


def test_vlm_patch_scatter(rng):
    cfg = reduced(get_config("phi3_vision"))
    model = build_model(cfg)
    params = model.init_params(rng)
    batch = make_batch(cfg, rng)
    x1 = model.embed_input(params, batch)
    b2 = dict(batch)
    b2["patch_embeds"] = batch["patch_embeds"] + 1.0
    x2 = model.embed_input(params, b2)
    np_ = cfg.num_patches
    assert not np.allclose(np.asarray(x1[:, :np_], np.float32),
                           np.asarray(x2[:, :np_], np.float32))
    np.testing.assert_array_equal(np.asarray(x1[:, np_:], np.float32),
                                  np.asarray(x2[:, np_:], np.float32))


def test_gemma3_local_global_slots():
    cfg = get_config("gemma3_12b")
    model = build_model(cfg)
    kinds = [sk.is_local for sk in model.slots]
    assert kinds == [True] * 5 + [False]
    assert model.slots[0].theta == 10000.0 and model.slots[5].theta == 1000000.0


def test_jamba_interleave_slots():
    cfg = get_config("jamba15_large")
    model = build_model(cfg)
    assert [sk.kind for sk in model.slots] == ["mamba"] * 7 + ["attn"]
    assert [sk.is_moe for sk in model.slots] == [False, True] * 4

"""Data pipeline: determinism, dp sharding, prefetch, memmap."""
import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, TokenStream


def test_dp_ranks_disjoint_batches():
    cfgs = [DataConfig(vocab_size=256, seq_len=16, global_batch=8, seed=1,
                       dp_rank=r, dp_size=2) for r in range(2)]
    b0, b1 = TokenStream(cfgs[0]).batch(3), TokenStream(cfgs[1]).batch(3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenStream(DataConfig(vocab_size=128, seq_len=8, global_batch=2))
    b = s.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_orders_batches():
    s = TokenStream(DataConfig(vocab_size=128, seq_len=8, global_batch=2, seed=5))
    pf = Prefetcher(s, start_step=10)
    try:
        got = pf.next()
        np.testing.assert_array_equal(got["tokens"], s.batch(10)["tokens"])
        got2 = pf.next()
        np.testing.assert_array_equal(got2["tokens"], s.batch(11)["tokens"])
    finally:
        pf.stop()


def test_memmap_stream(tmp_path):
    data = (np.arange(10000) % 97).astype(np.int32)
    path = tmp_path / "toks.bin"
    data.tofile(path)
    s = TokenStream(DataConfig(vocab_size=97, seq_len=32, global_batch=4,
                               kind="memmap", path=str(path)))
    b = s.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 97

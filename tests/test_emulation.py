"""RQ-B emulation pipeline: model fits, simulator adapter, fidelity metric."""
import numpy as np
import pytest

from repro.core.config_store import ConfigStore
from repro.core.emulation import (EmulatedServiceModel, MLPWorkerModel,
                                  RidgeWorkerModel, fidelity_report,
                                  telemetry_matrix)
from repro.core.router import build_tree
from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                  poisson_load, summarize)
from repro.core.types import FunctionConfig, TelemetryRecord


def _synth_records(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    recs = []
    for _ in range(n):
        q = rng.integers(0, 10)
        b = rng.integers(1, 8)
        cold = rng.random() < 0.1
        pt = rng.integers(8, 64)
        lat = float(np.exp(0.02 * q + 0.08 * b + 1.2 * cold + 0.01 * pt
                           + rng.normal(0, 0.05)) * 0.01)
        recs.append(TelemetryRecord(fn="fn", t=0.0, queue_len=int(q),
                                    inflight=int(b - 1), batch_size=int(b),
                                    cold=cold, prompt_tokens=int(pt),
                                    gen_tokens=8, fn_cost=1.0, latency=lat,
                                    ok=rng.random() > 0.01))
    return recs


def test_ridge_recovers_structure():
    recs = _synth_records()
    X, y, ok = telemetry_matrix(recs)
    model = RidgeWorkerModel.fit(X, y, ok)
    rng = np.random.default_rng(1)
    # predictions ordered correctly: cold >> warm, batch 8 > batch 1
    f_warm = np.array([0, 0, 1, 0, 16, 8, 1.0], np.float32)
    f_cold = np.array([0, 0, 1, 1, 16, 8, 1.0], np.float32)
    p_warm = np.median([model.predict(f_warm, rng)[0] for _ in range(50)])
    p_cold = np.median([model.predict(f_cold, rng)[0] for _ in range(50)])
    assert p_cold > 2.0 * p_warm
    assert model.fail_rate == pytest.approx(0.01, abs=0.01)


def test_mlp_beats_or_matches_ridge_rmse():
    recs = _synth_records()
    X, y, ok = telemetry_matrix(recs)
    ridge = RidgeWorkerModel.fit(X, y, ok)
    mlp = MLPWorkerModel.fit(X, y, ok, steps=300)
    rng = np.random.default_rng(2)

    def rmse(m):
        errs = []
        for i in range(0, len(X), 7):
            pred, _ = m.predict(X[i], rng)
            errs.append((np.log(pred + 1e-6) - np.log(y[i] + 1e-6)) ** 2)
        return float(np.sqrt(np.mean(errs)))
    assert rmse(mlp) < rmse(ridge) * 1.3


def test_emulated_sim_fidelity():
    """Paper Fig. 2 loop closed: real sim -> fit -> emulated sim -> compare."""
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    real = Simulator(build_tree(8, fanout=4), store,
                     SyntheticServiceModel(seed=2), seed=5)
    poisson_load(real, fn="fn", rps=150, duration_s=15, seed=4)
    real_res = real.run()

    X, y, ok = telemetry_matrix([r for r in real.telemetry if r.latency > 0])
    model = RidgeWorkerModel.fit(X, y, ok)
    emu = Simulator(build_tree(8, fanout=4), store,
                    EmulatedServiceModel(model, seed=0), seed=5)
    poisson_load(emu, fn="fn", rps=150, duration_s=15, seed=4)
    emu_res = emu.run()

    rep = fidelity_report(np.array([r.latency for r in real_res if r.ok]),
                          np.array([r.latency for r in emu_res if r.ok]))
    assert rep["p50_rel_err"] < 0.25
    assert rep["p95_rel_err"] < 0.35
    assert rep["mean_rel_err"] < 0.25


@pytest.mark.slow
def test_emulation_scales_to_1000_workers():
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    recs = _synth_records(1000)
    X, y, ok = telemetry_matrix(recs)
    model = RidgeWorkerModel.fit(X, y, ok)
    sim = Simulator(build_tree(1024, fanout=16), store,
                    EmulatedServiceModel(model), seed=1)
    n = poisson_load(sim, fn="fn", rps=2000, duration_s=5, seed=4)
    s = summarize(sim.run())
    assert s["n"] == n and s["fail_rate"] < 0.05


def test_fidelity_report_identity():
    x = np.random.default_rng(0).lognormal(0, 0.3, 5000)
    rep = fidelity_report(x, x)
    assert rep["ks"] < 1e-9 and rep["p99_rel_err"] < 1e-9

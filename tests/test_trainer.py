"""Training loop behaviour: loss decreases, grad-accum equivalence."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import build_model
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = replace(reduced(get_config("qwen3_32b")), dtype="float32")
    model = build_model(cfg, attn_block=16)
    params = model.init_params(jax.random.PRNGKey(0))
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8, seed=3))
    return cfg, model, params, stream


@pytest.mark.slow
def test_loss_decreases(setup):
    cfg, model, params, stream = setup
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, opt, accum=1))
    state = opt.init(params)
    losses = []
    for i in range(30):
        params, state, metrics = step(params, state, stream.batch(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]
    assert np.isfinite(losses).all()


def test_grad_accum_equivalent(setup):
    """accum=4 must produce (nearly) the same update as accum=1."""
    cfg, model, params, stream = setup
    opt = AdamW(lr=1e-3)
    batch = stream.batch(0)
    s1 = jax.jit(make_train_step(model, opt, accum=1, grad_acc_dtype="float32"))
    s4 = jax.jit(make_train_step(model, opt, accum=4, grad_acc_dtype="float32"))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-5)


def test_grad_transform_hook(setup):
    cfg, model, params, stream = setup
    opt = AdamW(lr=0.0)
    calls = []

    def gt(grads):
        calls.append(1)
        return jax.tree.map(jnp.zeros_like, grads)

    step = make_train_step(model, opt, accum=1, grad_transform=gt)
    p2, _, m = step(params, opt.init(params), stream.batch(0))
    assert calls
    assert float(m["grad_norm"]) == 0.0


def test_metrics_shape(setup):
    cfg, model, params, stream = setup
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt, accum=2))
    _, _, m = step(params, opt.init(params), stream.batch(0))
    assert set(m) == {"loss", "grad_norm"}
    assert np.isfinite(float(m["grad_norm"]))

"""Benchmark harness — one benchmark per paper claim/figure + system perf.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_tree_scaling       paper Fig.1 claim: replicate-and-front scales
  bench_lb_policies        stateless vs stateful LBs (paper §II)
  bench_concurrency        RQ-A (paper §III.A) — per-policy instance counts
  bench_emulation          RQ-B (paper §III.B) — fidelity + emulation speedup
  bench_serving_engine     real-model worker throughput (Fig.2 step 1 rig)
  bench_kernels            Pallas kernel microbench (interpret) vs oracle
  bench_workload_scenarios named traffic shapes + >=1M-request bursty probe
  bench_autoscaler_scenarios autoscaler policy menu vs static replicate
  bench_fault_scenarios    chaos layer: zone outage A/B + retry storm
  bench_gateway            front-door gateway: noisy-neighbor flood A/B
  bench_workflows          DAG workflows: stage-blind vs DAG-aware routing
  bench_sim_throughput     simulator events/s (testbed capacity)
  roofline_table           dry-run artifacts summary (if sweep has run)
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# every _row also lands here; main() dumps them to benchmarks/out/ as the
# JSON artifact CI uploads (gitignored locally)
ROWS = []
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def _row(name, us, derived="", **metrics):
    """Emit one benchmark row. ``derived`` stays the human-facing
    ``k=v;k=v`` string; ``metrics`` kwargs land as structured numeric
    fields under ``row["metrics"]`` in the JSON artifact so CI gates
    read typed values instead of regex-parsing the display string."""
    print(f"{name},{us:.2f},{derived}")
    row = {"name": name, "us_per_call": round(us, 2), "derived": derived}
    if metrics:
        row["metrics"] = metrics
    ROWS.append(row)


def bench_tree_scaling():
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree, replicate
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      poisson_load, summarize)
    from repro.core.types import FunctionConfig
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    base = build_tree(8, fanout=4)
    for times in (1, 2, 4, 8):
        tree = base if times == 1 else replicate(base, times=times)
        sim = Simulator(tree, store, SyntheticServiceModel(seed=2), seed=7)
        rps = 300 * times
        poisson_load(sim, fn="fn", rps=rps, duration_s=10, seed=3)
        t0 = time.perf_counter()
        s = summarize(sim.run())
        wall = time.perf_counter() - t0
        _row(f"tree_scaling_x{times}", 1e6 * s["p99"],
             f"workers={8*times};rps={rps};p50_ms={s['p50']*1e3:.1f};"
             f"fail={s['fail_rate']:.3f};sim_wall_s={wall:.1f}")


def bench_lb_policies():
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      poisson_load, summarize)
    from repro.core.types import FunctionConfig
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    for pol in ("random", "round_robin", "least_loaded", "pow2",
                "warm_affinity"):
        sim = Simulator(build_tree(16, fanout=4, leaf_policy=pol), store,
                        SyntheticServiceModel(seed=2), seed=7)
        poisson_load(sim, fn="fn", rps=500, duration_s=10, seed=3)
        s = summarize(sim.run())
        _row(f"lb_policy_{pol}", 1e6 * s["p99"],
             f"p50_ms={s['p50']*1e3:.2f};cold={s['cold_rate']:.3f}")


def bench_concurrency():
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      poisson_load, summarize)
    from repro.core.types import FunctionConfig
    for name, c in (("lambda_c1", 1), ("knative_c8", 8), ("azure_unlim", 0)):
        store = ConfigStore()
        store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=c,
                                 cold_start_s=0.25, idle_timeout_s=8.0,
                                 max_instances_per_worker=16))
        sim = Simulator(build_tree(16, fanout=4), store,
                        SyntheticServiceModel(seed=2), seed=7)
        poisson_load(sim, fn="fn", rps=400, duration_s=20, seed=11)
        s = summarize(sim.run())
        inst = sum(w.instances_started for w in sim.workers.values())
        _row(f"concurrency_{name}", 1e6 * s["p99"],
             f"instances={inst};p50_ms={s['p50']*1e3:.1f};"
             f"cold={s['cold_rate']:.3f}")


def bench_emulation():
    from repro.core.config_store import ConfigStore
    from repro.core.emulation import (EmulatedServiceModel, RidgeWorkerModel,
                                      fidelity_report, telemetry_matrix)
    from repro.core.router import build_tree
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      poisson_load)
    from repro.core.types import FunctionConfig
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.2))
    real = Simulator(build_tree(8, fanout=4), store,
                     SyntheticServiceModel(seed=2), seed=5)
    poisson_load(real, fn="fn", rps=150, duration_s=15, seed=4)
    t0 = time.perf_counter()
    real_res = real.run()
    t_real = time.perf_counter() - t0
    X, y, ok = telemetry_matrix([r for r in real.telemetry if r.latency > 0])
    t0 = time.perf_counter()
    model = RidgeWorkerModel.fit(X, y, ok)
    t_fit = time.perf_counter() - t0
    emu = Simulator(build_tree(8, fanout=4), store,
                    EmulatedServiceModel(model, seed=0), seed=5)
    poisson_load(emu, fn="fn", rps=150, duration_s=15, seed=4)
    t0 = time.perf_counter()
    emu_res = emu.run()
    t_emu = time.perf_counter() - t0
    rep = fidelity_report(np.array([r.latency for r in real_res if r.ok]),
                          np.array([r.latency for r in emu_res if r.ok]))
    _row("emulation_fidelity", 1e6 * t_fit,
         f"p50_err={rep['p50_rel_err']:.3f};p95_err={rep['p95_rel_err']:.3f};"
         f"p99_err={rep['p99_rel_err']:.3f};ks={rep['ks']:.3f}")
    _row("emulation_speed", 1e6 * t_emu / max(len(emu_res), 1),
         f"vs_groundtruth_us={1e6*t_real/max(len(real_res),1):.1f}")


def bench_serving_engine():
    from repro.core.config_store import ConfigStore, ImageRegistry
    from repro.core.router import build_tree
    from repro.core.types import FunctionConfig, Request
    from repro.serving.engine import Engine
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             gen_tokens=4))
    eng = Engine(build_tree(1, fanout=2), store, ImageRegistry(), max_len=64)
    # warm (exclude compile)
    eng.submit(Request(fn="fn", arrival_t=0.0, size=8))
    eng.run()
    t0 = time.perf_counter()
    n = 8
    for i in range(n):
        eng.submit(Request(fn="fn", arrival_t=0.0, size=8))
    res = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(4 for _ in res)
    _row("serving_engine_warm", 1e6 * wall / n,
         f"tok_per_s={toks/wall:.1f};batched_slots=4")
    w = list(eng.workers.values())[0]
    inst = w.instances["fn"][0]
    _row("serving_cold_start", 1e6 * inst.cold_start_s, "compile+init")


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref as R
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mamba_scan import mamba_scan
    rng = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    for name, fn in (
            ("flash_attn_interpret",
             lambda: flash_attention(q, k, v, causal=True, block_q=128,
                                     block_k=128)),
            ("flash_attn_ref_xla",
             lambda: R.flash_attention_ref(q, k, v, causal=True))):
        fn()
        t0 = time.perf_counter()
        fn()
        _row(name, 1e6 * (time.perf_counter() - t0),
             f"S={S};flops={4*H*hd*S*S*B//2}")
    dt = jax.nn.softplus(jax.random.normal(ks[0], (1, 256, 128))) * 0.1
    x = jax.random.normal(ks[1], (1, 256, 128))
    Bc = jax.random.normal(ks[2], (1, 256, 16))
    A = -jnp.exp(jax.random.normal(ks[0], (128, 16)) * 0.2)
    D = jnp.ones(128)
    t0 = time.perf_counter()
    mamba_scan(dt, x, Bc, Bc, A, D, chunk=64, block_d=64)
    _row("mamba_scan_interpret", 1e6 * (time.perf_counter() - t0),
         "S=256;DI=128;N=16")


def bench_workload_scenarios():
    """Named workload shapes (repro.workloads) end-to-end, then a ≥1M-
    request bursty multi-function capacity probe reporting events/s.
    REPRO_EVENT_BACKEND selects the event-queue backend (default
    single_heap) — CI runs this bench once per backend and fails if
    `sharded` regresses events/s on the capacity probe."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      summarize)
    from repro.core.types import FunctionConfig
    from repro.workloads import (BurstyArrivals, FunctionProfile,
                                 MixedWorkload, SizeDist, build_scenario,
                                 install_demo_configs)
    backend = os.environ.get("REPRO_EVENT_BACKEND", "single_heap")
    for name in ("steady", "flash_crowd", "daily_cycle", "multi_tenant"):
        wl = build_scenario(name, duration_s=10.0, seed=3)
        store = ConfigStore()
        install_demo_configs(store, wl)
        sim = Simulator(build_tree(16, fanout=4), store,
                        SyntheticServiceModel(seed=2), seed=7,
                        event_backend=backend)
        n = sim.load(wl)
        t0 = time.perf_counter()
        s = summarize(sim.run())
        wall = time.perf_counter() - t0
        eps = sim.events_processed / max(wall, 1e-9)
        _row(f"scenario_{name}", 1e6 * s["p99"],
             f"n={n};p50_ms={s['p50']*1e3:.1f};cold={s['cold_rate']:.3f};"
             f"fail={s['fail_rate']:.3f};events_per_s={eps:.0f}",
             n=n, events_per_s=eps, fail_rate=s["fail_rate"])
    # capacity probe: MMPP bursts over a three-tenant mix, ≥1M requests
    store = ConfigStore()
    for fn in ("chat", "embed", "batch"):
        store.put(FunctionConfig(name=fn, arch="tiny_lm", concurrency=8,
                                 cold_start_s=0.1, idle_timeout_s=30.0,
                                 max_instances_per_worker=16))
    profiles = [
        FunctionProfile("chat", weight=6.0, size=SizeDist.lognormal(24, 0.5)),
        FunctionProfile("embed", weight=3.0, size=SizeDist.uniform(8, 48)),
        FunctionProfile("batch", weight=1.0, size=SizeDist.const(96)),
    ]
    wl = MixedWorkload(
        BurstyArrivals(rate_on=40000.0, rate_off=10000.0,
                       mean_on_s=1.0, mean_off_s=3.0),
        profiles, duration_s=64.0, seed=3)
    sim = Simulator(build_tree(512, fanout=16), store,
                    SyntheticServiceModel(seed=2), seed=7,
                    event_backend=backend)
    t0 = time.perf_counter()
    n = sim.load(wl)
    t_gen = time.perf_counter() - t0
    assert n >= 1_000_000, f"capacity probe must drive >=1M requests, got {n}"
    t0 = time.perf_counter()
    s = summarize(sim.run())
    wall = time.perf_counter() - t0
    _row("scenario_bursty_1m", 1e6 * wall / n,
         f"requests={n};events={sim.events_processed};"
         f"events_per_s={sim.events_processed/wall:.0f};"
         f"req_per_s={n/wall:.0f};gen_s={t_gen:.1f};"
         f"p99_ms={s['p99']*1e3:.1f};fail={s['fail_rate']:.4f}",
         requests=n, events=sim.events_processed,
         events_per_s=sim.events_processed / wall, req_per_s=n / wall)


def bench_workload_generation():
    """ISSUE-8 micro-probe: scalar (`requests()`) vs vectorized
    (`generate_bulk`) generation requests/s, per arrival kind, at the
    ~1M-request scale. CI gates bulk >= 10x scalar on the poisson row
    (WORKLOAD_GEN_PROBE_S scales the horizon for local runs)."""
    from repro.workloads import (BurstyArrivals, DiurnalArrivals,
                                 FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist, TraceArrivals)
    dur = float(os.environ.get("WORKLOAD_GEN_PROBE_S", "50"))
    rate = 20000.0                         # 20k rps x 50 s = 1M requests
    profiles = [
        FunctionProfile("interactive", weight=3.0,
                        size=SizeDist.lognormal(24, 0.6), slo_p95_s=0.5),
        FunctionProfile("batch", weight=1.0,
                        size=SizeDist.uniform(64, 512)),
        FunctionProfile("ping", weight=1.0, size=SizeDist.const(4)),
    ]
    kinds = {
        "poisson": PoissonArrivals(rate),
        "bursty": BurstyArrivals(rate_on=3.0 * rate, rate_off=rate / 3.0,
                                 mean_on_s=0.5, mean_off_s=1.0),
        "diurnal": DiurnalArrivals(base_rate=rate, amplitude=0.8,
                                   period_s=dur),
        "trace": TraceArrivals([1.0 / rate] * 997, loop=True),
    }
    speedups = {}
    for name in sorted(kinds):
        wl = MixedWorkload(kinds[name], profiles, duration_s=dur, seed=3)
        t0 = time.perf_counter()
        n_scalar = sum(1 for _ in wl.requests())
        t_scalar = time.perf_counter() - t0
        t0 = time.perf_counter()
        batch = wl.generate_bulk()
        t_bulk = time.perf_counter() - t0
        scalar_rps = n_scalar / t_scalar
        bulk_rps = len(batch) / t_bulk
        speedups[name] = bulk_rps / scalar_rps
        _row(f"workload_gen_{name}", 1e6 * t_bulk / max(1, len(batch)),
             f"n_scalar={n_scalar};n_bulk={len(batch)};"
             f"scalar_req_per_s={scalar_rps:.0f};"
             f"bulk_req_per_s={bulk_rps:.0f};"
             f"speedup={bulk_rps / scalar_rps:.1f}x",
             n_bulk=len(batch), scalar_req_per_s=scalar_rps,
             bulk_req_per_s=bulk_rps, speedup=bulk_rps / scalar_rps)
    _row("workload_gen_speedup_min", 0.0,
         f"min_over_kinds={min(speedups.values()):.1f}x",
         min_over_kinds=min(speedups.values()))


def bench_autoscaler_scenarios():
    """Autoscaler policy menu vs the paper's static replicate recipe under
    `flash_crowd` and `daily_cycle` (repro.autoscale). Reports p95,
    fail/cold rates, and worker-seconds (the replica-seconds cost proxy:
    branches are uniform, so the two are proportional). ``slo_aware``
    scales against the scenario's per-function `slo_p95_s` targets and
    additionally reports per-function p95 vs SLO."""
    from repro.autoscale import Autoscaler, build_pool, get_autoscaler
    from repro.core.config_store import ConfigStore
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      summarize)
    from repro.workloads import build_scenario, install_demo_configs
    shapes = {
        "flash_crowd": dict(duration_s=30.0, seed=3, base_rps=12.0,
                            burst_rps=1000.0, mean_burst_s=2.0,
                            mean_calm_s=10.0),
        "daily_cycle": dict(duration_s=60.0, seed=3, mean_rps=150.0,
                            period_s=60.0),
    }
    for shape, overrides in shapes.items():
        for policy in ("static", "reactive", "target_concurrency",
                       "predictive", "slo_aware"):
            wl = build_scenario(shape, **overrides)
            store = ConfigStore()
            install_demo_configs(store, wl)
            # static = provisioned once at 3 branches (replicate recipe);
            # scalers start at 1 branch and may grow to 8
            branches = 3 if policy == "static" else 1
            sim = Simulator(build_pool(branches, 2), store,
                            SyntheticServiceModel(seed=2), seed=7,
                            worker_capacity_slots=1)
            pol = (get_autoscaler("slo_aware", slo_p95_s=wl.slo_targets())
                   if policy == "slo_aware" else policy)
            scaler = Autoscaler(pol, interval_s=0.25, window_s=2.0,
                                min_replicas=1, max_replicas=8,
                                workers_per_replica=2, cooldown_s=2.0)
            sim.attach_autoscaler(scaler)
            n = sim.load(wl)
            t0 = time.perf_counter()
            results = sim.run()
            s = summarize(results)
            wall = time.perf_counter() - t0
            sm = scaler.summary()
            extra = ""
            if policy == "slo_aware":
                parts = []
                for fn, slo in sorted(wl.slo_targets().items()):
                    lat = np.array([r.latency for r in results
                                    if r.ok and r.fn == fn])
                    p95 = float(np.percentile(lat, 95)) if len(lat) else 0.0
                    parts.append(f"{fn}={p95*1e3:.0f}/{slo*1e3:.0f}ms")
                extra = ";fn_p95_vs_slo=" + ",".join(parts)
            _row(f"autoscale_{shape}_{policy}", 1e6 * s["p95"],
                 f"n={n};p95_ms={s['p95']*1e3:.1f};"
                 f"fail={s['fail_rate']:.4f};cold={s['cold_rate']:.3f};"
                 f"worker_s={sm['worker_seconds']:.0f};"
                 f"max_replicas={sm['max_replicas_seen']};"
                 f"ups={sm['scale_ups']};downs={sm['scale_downs']};"
                 f"sim_wall_s={wall:.1f}{extra}")


def bench_placement():
    """Placement x routing matrix on the memory-skewed `multi_tenant`
    scenario (heterogeneous per-tenant replica footprints, memory-capped
    workers, slo_aware autoscaling). Reports per-function p95 vs SLO,
    worker-seconds, and cold rate — the ISSUE-4 acceptance surface:
    best_fit_memory + deadline_aware should meet every SLO at lower cost
    than the first_fit + least_loaded baseline. The matrix cells live in
    examples/placement_study.py (one definition for CI and the study)."""
    from repro.core.simulator import summarize
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "examples"))
    from placement_study import CELLS, run_cell
    for placer, leaf, inner in CELLS:
        t0 = time.perf_counter()
        sim, scaler, results, per_fn = run_cell(placer, leaf, inner)
        wall = time.perf_counter() - t0
        s = summarize(results)
        sm = scaler.summary()
        parts = [f"{fn}={p95*1e3:.0f}/{slo*1e3:.0f}ms"
                 for fn, (p95, slo) in per_fn.items()]
        _row(f"placement_{placer}_{leaf}", 1e6 * s["p95"],
             f"n={len(results)};fail={s['fail_rate']:.4f};"
             f"cold={s['cold_rate']:.3f};"
             f"worker_s={sm['worker_seconds']:.0f};"
             f"fn_p95_vs_slo={','.join(parts)};sim_wall_s={wall:.1f}")


def bench_fault_scenarios():
    """Chaos-layer A/B (repro.core.faults): the seeded `zone_outage`
    scenario across {spread, spread_zones} x {no retry, retry budget 2},
    plus a `retry_storm` probe of the storm guard. The acceptance shape
    (tests/test_faults.py): failure-domain-aware placement + a retry
    budget rides through the outage; the zone-blind no-retry cell loses
    its warm capacity and its in-flight work in one event."""
    from repro.autoscale import build_pool
    from repro.core.config_store import ConfigStore
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      summarize)
    from repro.core.types import FunctionConfig
    from repro.workloads import build_scenario

    def _sim(wl, *, zones, branches, wpb, placer, retry_budget, prewarm,
             **sim_kw):
        # memory-capped one-replica workers: a pre-warmed steady state
        # where *placement* decided which zones hold each function's
        # warm capacity, and the surviving zone has no memory headroom
        # to rebuild the dead zone's share. The zone-blind cell pays the
        # outage in dead in-flight work plus a function stranded with no
        # warm capacity anywhere; spread_zones keeps half of every
        # function's replicas in the surviving zone and rides through.
        store = ConfigStore()
        for p in wl.profiles:
            store.put(FunctionConfig(name=p.fn, arch="tiny_lm",
                                     concurrency=4, cold_start_s=1.0,
                                     timeout_s=8.0))
        sim = Simulator(build_pool(branches, wpb,
                                   leaf_policy="warm_least_loaded",
                                   inner_policy="deadline_aware"),
                        store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                        seed=7, zones=zones, placer=placer,
                        worker_memory_mb=600, cold_start_default_s=1.0,
                        retry_budget=retry_budget, **sim_kw)
        for p in wl.profiles:
            for _ in range(prewarm):
                sim.place_prewarm(p.fn)
        sim.load(wl)
        return sim

    for placer in ("spread", "spread_zones"):
        for retry_budget in (0, 2):
            wl = build_scenario("zone_outage", seed=3)
            sim = _sim(wl, zones=2, branches=2, wpb=4, placer=placer,
                       retry_budget=retry_budget, prewarm=4)
            t0 = time.perf_counter()
            results = sim.run()
            s = summarize(results)
            wall = time.perf_counter() - t0
            parts = []
            for fn, slo in sorted(wl.slo_targets().items()):
                rows = [r for r in results if r.fn == fn]
                att = (sum(1 for r in rows if r.ok and r.latency <= slo)
                       / max(1, len(rows)))
                parts.append(f"{fn}={att:.3f}")
            fstats = sim.faults.summary()
            _row(f"fault_zone_outage_{placer}_retry{retry_budget}",
                 1e6 * s["p95"],
                 f"n={s['n']};fail={s['fail_rate']:.4f};"
                 f"slo_attainment={','.join(parts)};"
                 f"retries={sim.retries_scheduled};"
                 f"zone_outages={fstats['zone_outages']};"
                 f"sim_wall_s={wall:.1f}")

    # retry storm: 2 of 3 zones fail at once under heavy load; the storm
    # guard caps concurrent retries and sheds the rest of the blast wave
    # instead of re-offering all of it to the lone surviving zone
    wl = build_scenario("retry_storm", seed=3, rps=1500.0)
    sim = _sim(wl, zones=3, branches=3, wpb=2, placer="spread_zones",
               retry_budget=3, retry_storm_cap=32, prewarm=3)
    t0 = time.perf_counter()
    s = summarize(sim.run())
    wall = time.perf_counter() - t0
    _row("fault_retry_storm", 1e6 * s["p95"],
         f"n={s['n']};fail={s['fail_rate']:.4f};"
         f"retries={sim.retries_scheduled};shed={sim.retries_shed};"
         f"cap=32;sim_wall_s={wall:.1f}")


def bench_gateway():
    """ISSUE-9 acceptance probe: the `noisy_neighbor` A/B (gateway on
    vs off, same fleet — equal worker-seconds). Two rigs, the same ones
    tests/test_gateway.py enforces:

    `noisy` — the flood is capped at one replica per worker, so the
    baseline queues it to the 8 s timeout horizon and ~14k hedge clones
    double its service demand; the gateway's batch admission ceiling
    keeps its outstanding work at 6, nothing hedges, and the same fleet
    clears ~1.65x the goodput.

    `pinned` — a roomier fleet with no replica cap: the flood wins
    every memory slot at t=0 and pins `embed` at *zero* completions;
    the admission ceiling bounds the flood's footprint and both
    interactive tenants come back within SLO."""
    from repro.autoscale import build_pool
    from repro.core.config_store import ConfigStore
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      summarize)
    from repro.core.types import FunctionConfig
    from repro.workloads import build_scenario

    CONC = {"chat": 4, "embed": 2, "flood": 2}
    SLO = {"chat": 0.5, "embed": 1.0, "flood": 5.0}

    def _sim(*, gateway, mem, flood_maxi, batch_limit):
        gw_kw = (dict(flood_rate=400.0, flood_burst=8.0,
                      max_inflight=4 * batch_limit, batch_share=0.25)
                 if gateway else {})
        wl = build_scenario("noisy_neighbor", gateway=gateway, seed=3,
                            duration_s=12.0, **gw_kw)
        store = ConfigStore()
        for p in wl.profiles:
            store.put(FunctionConfig(
                name=p.fn, arch="tiny_lm", concurrency=CONC[p.fn],
                cold_start_s=0.2, timeout_s=8.0,
                idle_timeout_s=1.0 if p.fn == "flood" else 10.0,
                max_instances_per_worker=(flood_maxi if p.fn == "flood"
                                          else 8)))
        sim = Simulator(build_pool(1, 2, leaf_policy="warm_least_loaded",
                                   inner_policy="round_robin"),
                        store, SyntheticServiceModel(seed=2, fail_rate=0.0),
                        seed=11, hedge_after_s=0.6, worker_memory_mb=mem)
        sim.load(wl)
        return sim

    rigs = {"noisy": dict(mem=1536, flood_maxi=1, batch_limit=6),
            "pinned": dict(mem=2048, flood_maxi=8, batch_limit=5)}
    for rig, kw in rigs.items():
        for gateway in (False, True):
            sim = _sim(gateway=gateway, **kw)
            t0 = time.perf_counter()
            results = sim.run()
            wall = time.perf_counter() - t0
            s = summarize(results)
            parts = []
            for fn, slo in sorted(SLO.items()):
                lat = sorted(r.latency for r in results
                             if r.fn == fn and r.ok)
                p95 = lat[int(0.95 * len(lat))] if lat else float("nan")
                parts.append(f"{fn}={p95 * 1e3:.0f}ms")
            shed = sim.gateway.shed_total if sim.gateway is not None else 0
            _row(f"gateway_{rig}_{'on' if gateway else 'off'}",
                 1e6 * s["p95"],
                 f"goodput={s['goodput']:.1f};ok={s['ok']};"
                 f"p95={','.join(parts)};hedges={sim.hedges_seen};"
                 f"shed={shed};sim_wall_s={wall:.1f}",
                 goodput=s["goodput"], ok=s["ok"],
                 hedges=sim.hedges_seen, shed=shed)


def bench_workflows():
    """ISSUE-7 acceptance probe: DAG workflows (`ml_pipeline` chain +
    conditional branch, `etl_fanout` map-reduce) routed stage-blind
    (`deadline_aware`) vs DAG-aware (`workflow_aware`) on identical
    fixed trees — equal worker-seconds, so the end-to-end workflow p95
    delta is routing-only. The acceptance shape (tests/test_workflows.py):
    eager critical-path cold starts + affinity tie-break + sibling
    waterfill beat the stage-blind baseline on both scenarios."""
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.workloads import (build_scenario, install_demo_configs,
                                 summarize_workflows)

    for scen in ("ml_pipeline", "etl_fanout"):
        for policy in ("deadline_aware", "workflow_aware"):
            wl = build_scenario(scen, duration_s=40.0, seed=13)
            store = ConfigStore()
            install_demo_configs(store, wl)
            sim = Simulator(build_tree(8, fanout=4, leaf_policy=policy,
                                       inner_policy=policy),
                            store, SyntheticServiceModel(seed=2), seed=11)
            sim.load(wl)
            t0 = time.perf_counter()
            sim.run()
            wall = time.perf_counter() - t0
            s = summarize_workflows(sim.workflow_results)
            eng = sim.workflows
            _row(f"workflow_{scen}_{policy}", 1e6 * s["p95"],
                 f"n={s['n']};tasks={eng.tasks_submitted};"
                 f"fail={s['fail_rate']:.4f};p50_ms={s['p50']*1e3:.1f};"
                 f"p99_ms={s['p99']*1e3:.1f};prewarms={eng.prewarms};"
                 f"sim_wall_s={wall:.1f}")


def bench_event_backends():
    """ISSUE-5 acceptance probe: the standalone `EventEngine` under a
    ≥10M-request event stream, once per registered backend.

    The stream is the simulator's real shape: per-tenant Poisson arrival
    streams bulk-loaded *stream by stream* (the Azure-trace multi-tenant
    ingest order — globally near-random in time, which is a binary
    heap's honest worst case: every sift walks ~log(10M) cache-hostile
    levels of a shuffled gigabyte-scale array), then drained while each
    arrival spawns the operational chain (enqueue +hop, finish +service,
    idle_check +30s). The sharded calendar queue seals the bulk load
    into sorted per-bucket runs and keeps dynamic events in small
    cache-resident bucket heaps, so its advantage grows with pending-set
    scale. Both backends must pop the identical (t, seq) stream — the
    probe cross-checks a sampled hash.

    End-to-end *simulator* events/s gains are smaller (~1.1-1.2x at 10M:
    routing/dispatch/service handlers dominate the per-event cost and
    are backend-independent); set EVENT_BACKEND_SIM_PROBE=1 to measure
    and record that full-sim probe too (~25 min extra).
    EVENT_BACKEND_PROBE_S (default 505) scales the horizon: 505 s ×
    2000 streams × 10 rps ≈ 10.1M requests ≈ 40M events."""
    import random as _random

    from repro.core.events import EventEngine

    hop_s, idle_s = 0.0005, 30.0

    def engine_probe(backend, streams, duration_s):
        eng = EventEngine(backend)
        n = 0
        t0 = time.perf_counter()
        for s in range(streams):           # tenant-by-tenant bulk ingest
            srng = _random.Random(100 + s)
            t = 0.0
            while True:
                t += srng.expovariate(10.0)
                if t >= duration_s:
                    break
                eng.push(t, "arrival", None)
                n += 1
        t_load = time.perf_counter() - t0
        drng = _random.Random(7)
        sample = 0
        pops = 0
        t0 = time.perf_counter()
        while True:
            e = eng.pop()
            if e is None:
                break
            pops += 1
            kind = e[2]
            if kind == "arrival":
                eng.push(e[0] + hop_s, "enqueue", None)
            elif kind == "enqueue":
                eng.push(e[0] + 0.004 + 0.01 * drng.random(), "finish", None)
            elif kind == "finish":
                eng.push(e[0] + idle_s, "idle_check", None)
            if not pops % 997:             # cheap cross-backend witness
                sample ^= hash((e[0], e[1]))
        wall = time.perf_counter() - t0
        return n, pops, t_load, wall, sample

    dur = float(os.environ.get("EVENT_BACKEND_PROBE_S", "505"))
    rates, hashes, scalar_e2e = {}, {}, {}
    for backend in ("single_heap", "sharded"):
        engine_probe(backend, 200, 20.0)   # warmup: page/arena state
        n, pops, t_load, wall, sample = engine_probe(backend, 2000, dur)
        if dur >= 505:
            assert n >= 10_000_000, \
                f"acceptance probe must drive >=10M requests, got {n}"
        rates[backend] = pops / wall
        hashes[backend] = sample
        # load_s covers scalar generation + ingest interleaved, so
        # end_to_end is the full generate-and-simulate rate the bulk
        # pipeline below is gated against
        scalar_e2e[backend] = pops / (t_load + wall)
        _row(f"event_engine_{backend}", 1e6 * wall / n,
             f"requests={n};events={pops};events_per_s={pops / wall:.0f};"
             f"end_to_end_events_per_s={pops / (t_load + wall):.0f};"
             f"load_s={t_load:.1f};run_s={wall:.1f}",
             requests=n, events=pops, events_per_s=pops / wall,
             end_to_end_events_per_s=pops / (t_load + wall))
    assert hashes["sharded"] == hashes["single_heap"], \
        "backends popped different (t, seq) streams"
    _row("event_engine_speedup", 0.0,
         f"sharded_over_single_heap="
         f"{rates['sharded'] / rates['single_heap']:.2f}x",
         sharded_over_single_heap=rates["sharded"] / rates["single_heap"])

    # ---- ISSUE-8 bulk mode: generate_bulk + push_bulk + pop_batch,
    # the same 10M-request Azure-style probe end to end through the
    # vectorized pipeline (own numpy determinism contract, so the
    # cross-backend hash witness is checked *within* the bulk mode)
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)

    def make_streams(streams, duration_s):
        profile = [FunctionProfile("fn", size=SizeDist.const(16))]
        return [MixedWorkload(PoissonArrivals(10.0), profile,
                              duration_s=duration_s, seed=100 + s)
                for s in range(streams)]

    def bulk_probe(backend, streams, duration_s):
        eng = EventEngine(backend)
        t0 = time.perf_counter()
        arrival_runs = [wl.generate_bulk().arrival_t
                        for wl in make_streams(streams, duration_s)]
        n = sum(len(r) for r in arrival_runs)
        t_gen = time.perf_counter() - t0
        t0 = time.perf_counter()
        for times in arrival_runs:         # tenant-by-tenant bulk ingest
            eng.push_bulk(times, "arrival", None)
        t_load = time.perf_counter() - t0
        drng = _random.Random(7).random
        sample = 0
        pops = 0
        pop_batch = eng.pop_batch
        push_bulk = eng.push_bulk
        t0 = time.perf_counter()
        while True:
            batch = pop_batch(8192)
            if not batch:
                break
            # one comprehension pass per kind beats a per-event dispatch
            # loop; batch partitions are backend-identical (greedy
            # pop_batch contract), so the strided witness below samples
            # the same global every-997th events on every backend
            enq = [e[0] + hop_s for e in batch if e[2] == "arrival"]
            fin = [e[0] + 0.004 + 0.01 * drng()
                   for e in batch if e[2] == "enqueue"]
            idle = [e[0] + idle_s for e in batch if e[2] == "finish"]
            for e in batch[(996 - pops) % 997::997]:
                sample ^= hash((e[0], e[1]))
            pops += len(batch)
            if enq:
                push_bulk(enq, "enqueue", None)
            if fin:
                push_bulk(fin, "finish", None)
            if idle:
                push_bulk(idle, "idle_check", None)
        wall = time.perf_counter() - t0
        return n, pops, t_gen, t_load, wall, sample

    # scalar generation baseline at the same request volume: the real
    # per-request path sim.load walks (one Mersenne draw chain + one
    # Request object per arrival)
    t0 = time.perf_counter()
    n_scalar_gen = 0
    for wl in make_streams(2000, dur):
        for _ in wl.requests():
            n_scalar_gen += 1
    scalar_gen_rps = n_scalar_gen / (time.perf_counter() - t0)

    bulk_e2e, bulk_hashes = {}, {}
    gen_rps = 0.0
    for backend in ("single_heap", "sharded"):
        bulk_probe(backend, 200, 20.0)     # warmup
        n, pops, t_gen, t_load, wall, sample = bulk_probe(backend, 2000,
                                                          dur)
        gen_rps = n / t_gen
        bulk_e2e[backend] = pops / (t_gen + t_load + wall)
        bulk_hashes[backend] = sample
        _row(f"event_engine_bulk_{backend}",
             1e6 * (t_gen + t_load + wall) / n,
             f"requests={n};events={pops};gen_s={t_gen:.1f};"
             f"gen_req_per_s={n / t_gen:.0f};load_s={t_load:.1f};"
             f"run_s={wall:.1f};events_per_s={pops / wall:.0f};"
             f"end_to_end_events_per_s={bulk_e2e[backend]:.0f}",
             requests=n, events=pops, events_per_s=pops / wall,
             end_to_end_events_per_s=bulk_e2e[backend])
    assert bulk_hashes["sharded"] == bulk_hashes["single_heap"], \
        "bulk pipeline popped different (t, seq) streams across backends"
    gen_speedup = gen_rps / scalar_gen_rps
    e2e_speedup = bulk_e2e["sharded"] / scalar_e2e["sharded"]
    _row("event_engine_bulk_speedup", 0.0,
         f"generation_bulk_over_scalar={gen_speedup:.1f}x;"
         f"end_to_end_bulk_sharded_over_scalar_sharded="
         f"{e2e_speedup:.2f}x;"
         f"end_to_end_bulk_sharded_over_scalar_single_heap="
         f"{bulk_e2e['sharded'] / scalar_e2e['single_heap']:.2f}x;"
         f"scalar_gen_req_per_s={scalar_gen_rps:.0f}",
         generation_bulk_over_scalar=gen_speedup,
         end_to_end_bulk_over_scalar=e2e_speedup)
    if dur >= 505:                         # ISSUE-8 acceptance gates
        assert gen_speedup >= 10.0, \
            f"bulk generation {gen_speedup:.1f}x < 10x scalar"
        assert e2e_speedup >= 3.0, \
            f"bulk end-to-end {e2e_speedup:.2f}x < 3x scalar sharded"

    if not os.environ.get("EVENT_BACKEND_SIM_PROBE"):
        return

    # ---- optional end-to-end probe: the full simulator at ≥10M requests
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.core.types import FunctionConfig
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)

    def sim_probe(backend, duration_s):
        store = ConfigStore()
        store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=16,
                                 cold_start_s=0.05, idle_timeout_s=30.0,
                                 max_instances_per_worker=8))
        wl = MixedWorkload(PoissonArrivals(20000.0),
                           [FunctionProfile("fn", size=SizeDist.const(24))],
                           duration_s=duration_s, seed=3)
        sim = Simulator(build_tree(64, fanout=8, leaf_policy="random"),
                        store, SyntheticServiceModel(seed=2), seed=7,
                        event_backend=backend, collect_telemetry=False)
        t0 = time.perf_counter()
        n = sim.load(wl)
        t_load = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim.run()
        wall = time.perf_counter() - t0
        return n, sim, t_load, wall

    sim_rates = {}
    for backend in ("single_heap", "sharded"):
        sim_probe(backend, 20.0)           # warmup
        n, sim, t_load, wall = sim_probe(backend, dur)
        fails = sum(not r.ok for r in sim.results)
        sim_rates[backend] = sim.events_processed / wall
        _row(f"event_backend_sim_{backend}", 1e6 * wall / n,
             f"requests={n};events={sim.events_processed};"
             f"events_per_s={sim.events_processed / wall:.0f};"
             f"load_s={t_load:.1f};run_s={wall:.1f};fails={fails}")
    _row("event_backend_sim_speedup", 0.0,
         f"sharded_over_single_heap="
         f"{sim_rates['sharded'] / sim_rates['single_heap']:.2f}x")


def bench_parallel_sim():
    """ISSUE-10 acceptance probe: partitioned simulation (repro.parallel)
    vs the best serial pipeline on the same ≥10M-request Azure-style
    multi-tenant workload.

    The workload is 200 per-tenant Poisson streams with heterogeneous
    request-size mixes and disjoint rid ranges — exactly the shape
    ``azure_trace_streams`` produces and ``partition_streams`` buckets.
    The serial baseline is the strongest single-process pipeline the
    repo has (sharded calendar backend + vectorized ``load_bulk`` + a
    ``ResultSink`` so 10M rows never materialize); the partitioned run
    forks 8 workers, each owning its crc32 bucket of streams and an
    8-worker subtree of the same 64-worker fleet, free-running on the
    uncoupled fast path with summary collection. Parallel events/s is
    charged the *entire* ``run_partitioned`` wall (fork + in-worker
    generation + merge); serial events/s excludes its own generation —
    the comparison is conservative toward serial.

    Acceptance (ISSUE 10): ≥ 4x merged events/s over serial, asserted
    here when the probe is full-size and the machine has ≥ 12 cores;
    CI gates ≥ 2.5x on its 4-vCPU runner from the JSON metrics. A
    small barrier-coupled run (global ``max_inflight`` re-apportioned
    at conservative-lookahead windows) rides along to keep the
    windowed regime measured.

    PARALLEL_SIM_PROBE_S (default 505) scales the horizon: 505 s ×
    200 streams × 100 rps ≈ 10.1M requests."""
    from repro.core.config_store import ConfigStore
    from repro.core.gateway import GatewayConfig
    from repro.core.router import build_tree
    from repro.core.simulator import Simulator, SyntheticServiceModel
    from repro.core.types import FunctionConfig
    from repro.parallel import partition_streams, run_partitioned
    from repro.parallel.partition import maybe_attach_sink
    from repro.workloads import (FunctionProfile, MixedWorkload,
                                 PoissonArrivals, SizeDist)

    dur = float(os.environ.get("PARALLEL_SIM_PROBE_S", "505"))
    n_streams, rps, K = 200, 100.0, 8
    ncpu = os.cpu_count() or 1
    SIZES = [SizeDist.const(16), SizeDist.const(24),
             SizeDist.uniform(8, 48), SizeDist.lognormal(24, 0.5)]

    def make_streams():
        return [MixedWorkload(PoissonArrivals(rps),
                              [FunctionProfile(f"t{s:03d}",
                                               size=SIZES[s % len(SIZES)])],
                              duration_s=dur, seed=100 + s,
                              rid_base=s * 100_000_000)
                for s in range(n_streams)]

    def store_for(fns):
        store = ConfigStore()
        for fn in fns:
            store.put(FunctionConfig(name=fn, arch="tiny_lm", concurrency=16,
                                     cold_start_s=0.05, idle_timeout_s=30.0,
                                     max_instances_per_worker=8))
        return store

    # 200 tenants land on every node under random routing, so nodes must
    # hold >200 warm instances or the default 16-slot cap thrashes cold
    # starts and everything queue-times-out
    serial = Simulator(build_tree(64, fanout=8, leaf_policy="random"),
                       store_for(f"t{s:03d}" for s in range(n_streams)),
                       SyntheticServiceModel(seed=2), seed=7,
                       event_backend="sharded", collect_telemetry=False,
                       worker_capacity_slots=256)
    sink = maybe_attach_sink(serial)
    t0 = time.perf_counter()
    n = sum(serial.load_bulk(wl) for wl in make_streams())
    t_load = time.perf_counter() - t0
    if dur >= 505:
        assert n >= 10_000_000, \
            f"acceptance probe must drive >=10M requests, got {n}"
    t0 = time.perf_counter()
    serial.run()
    t_run = time.perf_counter() - t0
    serial_eps = serial.events_processed / t_run
    _row("parallel_sim_serial", 1e6 * t_run / n,
         f"requests={n};events={serial.events_processed};"
         f"events_per_s={serial_eps:.0f};load_s={t_load:.1f};"
         f"run_s={t_run:.1f};ok={sink.part()['ok']}",
         requests=n, events=serial.events_processed,
         events_per_s=serial_eps)

    def build(k, nparts):
        mine = partition_streams(make_streams(), nparts)[k]
        sim = Simulator(build_tree(8, fanout=8, leaf_policy="random",
                                   prefix=f"p{k}"),
                        store_for(s.profiles[0].fn for s in mine),
                        SyntheticServiceModel(seed=2), seed=7,
                        event_backend="sharded", collect_telemetry=False,
                        worker_capacity_slots=256)
        for wl in mine:
            sim.load_bulk(wl)
        return sim

    t0 = time.perf_counter()
    merged = run_partitioned(build, K, collect="summary")
    wall = time.perf_counter() - t0
    ev = merged.counters["events_processed"]
    assert merged.counters["results"] == n, \
        (merged.counters["results"], n)
    par_eps = ev / wall
    speedup = par_eps / serial_eps
    _row("parallel_sim_partitioned", 1e6 * wall / n,
         f"requests={n};events={ev};partitions={K};mode={merged.mode};"
         f"events_per_s={par_eps:.0f};wall_s={wall:.1f};"
         f"ok={merged.summary()['ok']}",
         requests=n, events=ev, partitions=K, events_per_s=par_eps)
    _row("parallel_sim_speedup", 0.0,
         f"partitioned_over_serial={speedup:.2f}x;ncpu={ncpu}",
         partitioned_over_serial=speedup, ncpu=ncpu)
    if dur >= 505 and ncpu >= 12:
        assert speedup >= 4.0, \
            f"partitioned {speedup:.2f}x < 4x serial events/s"

    # barrier-coupled regime: partition-local gateways as shards of one
    # platform-wide ceiling, re-apportioned each conservative window
    def build_coupled(k, nparts):
        streams = [MixedWorkload(PoissonArrivals(200.0),
                                 [FunctionProfile(f"g{j}")],
                                 duration_s=4.0, seed=j,
                                 rid_base=j * 1_000_000)
                   for j in range(16)]
        mine = partition_streams(streams, nparts)[k]
        sim = Simulator(build_tree(4, fanout=4, leaf_policy="random",
                                   prefix=f"q{k}"),
                        store_for(s.profiles[0].fn for s in mine),
                        SyntheticServiceModel(seed=2), seed=7,
                        gateway=GatewayConfig(max_inflight=64),
                        collect_telemetry=False,
                        worker_capacity_slots=64)
        for wl in mine:
            sim.load_bulk(wl)
        return sim

    t0 = time.perf_counter()
    coupled = run_partitioned(build_coupled, 4, max_inflight=256,
                              collect="summary")
    wall = time.perf_counter() - t0
    _row("parallel_sim_coupled", 1e6 * wall
         / max(coupled.counters["results"], 1),
         f"requests={coupled.counters['results']};"
         f"barriers={len(coupled.barriers)};window_s={coupled.window_s};"
         f"admitted={coupled.counters['gw_admitted']};"
         f"shed={coupled.counters['gw_shed']};wall_s={wall:.1f}",
         barriers=len(coupled.barriers),
         admitted=coupled.counters["gw_admitted"],
         shed=coupled.counters["gw_shed"])


def bench_sim_throughput():
    from repro.core.config_store import ConfigStore
    from repro.core.router import build_tree
    from repro.core.simulator import (Simulator, SyntheticServiceModel,
                                      poisson_load)
    from repro.core.types import FunctionConfig
    store = ConfigStore()
    store.put(FunctionConfig(name="fn", arch="tiny_lm", concurrency=4,
                             cold_start_s=0.1))
    sim = Simulator(build_tree(256, fanout=16), store,
                    SyntheticServiceModel(seed=2), seed=7)
    n = poisson_load(sim, fn="fn", rps=5000, duration_s=10, seed=3)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    _row("sim_throughput", 1e6 * wall / n,
         f"requests={n};workers=256;req_per_s={n/wall:.0f}")


def roofline_table():
    import json
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if not os.path.isdir(art):
        _row("roofline_table", 0.0, "no dryrun artifacts; run repro.launch.sweep")
        return
    for f in sorted(os.listdir(art)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(art, f)) as fh:
            d = json.load(fh)
        if d.get("status") != "ok":
            continue
        r = d["report"]
        _row(f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}",
             1e6 * max(r["t_compute"], r["t_memory"], r["t_collective"]),
             f"bottleneck={r['bottleneck']};useful={r['useful_flops_ratio']:.3f};"
             f"frac={r['roofline_fraction']:.4f};peak_gib={r['mem']['peak_gib']:.1f};"
             f"fits={d.get('fits')}")


BENCHES = [bench_tree_scaling, bench_lb_policies, bench_concurrency,
           bench_emulation, bench_serving_engine, bench_kernels,
           bench_workload_scenarios, bench_workload_generation,
           bench_autoscaler_scenarios, bench_placement,
           bench_fault_scenarios, bench_gateway, bench_workflows,
           bench_event_backends, bench_parallel_sim,
           bench_sim_throughput, roofline_table]


def _usage() -> str:
    return ("usage: python benchmarks/run.py [probe-substring]\n"
            "probes: " + " ".join(b.__name__.removeprefix("bench_")
                                  for b in BENCHES))


def main(argv=None) -> None:
    # strict arg handling: a flag-like or unmatched argument used to
    # fall through as a probe name, run zero probes, and write a junk
    # artifact (results_--help.json) — reject it loudly instead
    argv = sys.argv[1:] if argv is None else argv
    only = None
    if argv:
        if argv[0] in ("-h", "--help"):
            print(_usage())
            return
        if len(argv) > 1 or argv[0].startswith("-"):
            print(f"unexpected arguments: {' '.join(argv)}\n{_usage()}",
                  file=sys.stderr)
            sys.exit(2)
        only = argv[0]
        if not any(only in b.__name__ for b in BENCHES):
            print(f"no benchmark matches {only!r}\n{_usage()}",
                  file=sys.stderr)
            sys.exit(2)
    print("name,us_per_call,derived")
    for b in BENCHES:
        if only and only not in b.__name__:
            continue
        try:
            b()
        except Exception as e:  # keep the harness robust
            _row(b.__name__ + "_ERROR", 0.0, repr(e)[:120])
    os.makedirs(OUT_DIR, exist_ok=True)
    # REPRO_EVENT_BACKEND suffixes the artifact so CI's per-backend runs
    # of the same bench don't overwrite each other
    backend = os.environ.get("REPRO_EVENT_BACKEND")
    suffix = (f"_{only}" if only else "") + (f"_{backend}" if backend else "")
    out = os.path.join(OUT_DIR, f"results{suffix}.json")
    with open(out, "w") as fh:
        json.dump({"filter": only, "backend": backend, "rows": ROWS}, fh,
                  indent=1)


if __name__ == "__main__":
    main()
